"""Dev driver: one loss_fn eval per reduced arch on CPU, no mesh."""

import sys

import jax
import jax.numpy as jnp

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.frontends import synthetic_frontend_embeds

ctx = ParallelCtx(remat="none")

archs = sys.argv[1:] or configs.list_archs()
for arch in archs:
    cfg = configs.reduced(arch)
    key = jax.random.PRNGKey(0)
    params, axes = M.init_model(cfg, key)
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size
        )
    }
    if cfg.frontend == "vision_stub":
        batch["patches"] = synthetic_frontend_embeds(cfg, B, S)
    if cfg.frontend == "audio_stub":
        batch["frames"] = synthetic_frontend_embeds(cfg, B, 24)
    loss, metrics = jax.jit(
        lambda p, b: M.loss_fn(p, b, cfg, ctx)
    )(params, batch)
    ok = bool(jnp.isfinite(loss))
    print(f"{arch:28s} loss={float(loss):9.4f} finite={ok}")
    assert ok, arch
print("ALL OK")
