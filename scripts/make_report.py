"""Generate the §Dry-run and §Roofline tables for EXPERIMENTS.md from
results/dryrun JSONs.

    PYTHONPATH=src python scripts/make_report.py [results/dryrun]
"""

import glob
import json
import sys


def fmt_bytes(b):
    return f"{b / 2**30:.1f}"


def load(outdir):
    recs = {}
    for f in glob.glob(f"{outdir}/*.json"):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"],
               r.get("tier_policy", "none"))
        recs[key] = r
    return recs


def roofline_table(recs, mesh, tier="none"):
    rows = []
    for (arch, shape, m, t), r in sorted(recs.items()):
        if m != mesh or t != tier or r["status"] != "ok":
            continue
        ro = r["roofline"]
        ma = r["memory_analysis"]
        hc = r["hlo_cost"]
        rows.append(
            f"| {arch} | {shape} | {ro['t_compute_s']:.4f} | "
            f"{ro['t_memory_s']:.4f} | {ro['t_collective_s']:.4f} | "
            f"**{ro['dominant']}** | {ro['model_flops']:.2e} | "
            f"{ro['useful_ratio']:.3f} | {ro['roofline_fraction']:.3f} | "
            f"{fmt_bytes(ma['temp_bytes'] + ma['argument_bytes'])} |"
        )
    header = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "bottleneck | MODEL_FLOPS | useful ratio | roofline frac | "
        "per-dev GiB |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    return header + "\n".join(rows)


def dryrun_summary(recs, mesh):
    ok = sum(1 for (a, s, m, t), r in recs.items()
             if m == mesh and t == "none" and r["status"] == "ok")
    tot = sum(1 for (a, s, m, t), r in recs.items()
              if m == mesh and t == "none")
    return ok, tot


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(outdir)
    meshes = sorted({m for (_, _, m, _) in recs}) or ["16x16", "2x16x16"]
    for mesh in meshes:
        ok, tot = dryrun_summary(recs, mesh)
        print(f"\n## Mesh {mesh}: {ok}/{tot} cells compile\n")
        print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
