#!/usr/bin/env bash
# Tier-1 test lane.
#
#   scripts/run_tier1.sh            # full tier-1 (the ROADMAP command)
#   scripts/run_tier1.sh --fast     # fast lane: skips @pytest.mark.slow
#   scripts/run_tier1.sh [pytest args...]   # extra args pass through
set -euo pipefail
cd "$(dirname "${BASH_SOURCE[0]}")/.."
export PYTHONPATH="src${PYTHONPATH:+:${PYTHONPATH}}"

extra=()
if [[ "${1:-}" == "--fast" ]]; then
  shift
  extra=(-m "not slow")
fi
exec python -m pytest -x -q "${extra[@]}" "$@"
