"""Dev driver: prefill+decode must agree with teacher-forced forward."""

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.frontends import synthetic_frontend_embeds

ctx = ParallelCtx(remat="none")

archs = sys.argv[1:] or configs.list_archs()
for arch in archs:
    cfg = configs.reduced(arch)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S, MAXS = 2, 8, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    extra = {}
    if cfg.frontend == "vision_stub":
        extra["patches"] = synthetic_frontend_embeds(cfg, B, S)
    if cfg.frontend == "audio_stub":
        extra["frames"] = synthetic_frontend_embeds(cfg, B, 16)
    batch.update(extra)

    # teacher-forced logits over S+1 tokens
    full = {"tokens": toks[:, : S + 1], **extra}
    logits_full, _ = jax.jit(lambda p, b: M.forward(p, b, cfg, ctx))(
        params, full
    )

    # prefill on S tokens, then decode token S
    caches, logits_pre = M.prefill(params, batch, cfg, ctx, max_seq=MAXS)
    err_pre = float(
        jnp.abs(logits_pre - logits_full[:, S - 1, :]).max()
    )

    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    logits_dec, caches = M.decode_step(
        params, toks[:, S], caches, S + npfx, cfg, ctx
    )
    err_dec = float(jnp.abs(logits_dec - logits_full[:, S, :]).max())
    status = "OK " if (err_pre < 2e-2 and err_dec < 2e-2) else "FAIL"
    print(f"{arch:28s} prefill_err={err_pre:9.2e} decode_err={err_dec:9.2e} {status}")
    assert status == "OK ", arch
print("ALL OK")
