"""Dev driver for the serving path. Gates per arch:

1. prefill+decode must agree with the teacher-forced forward (the original
   consistency check, kept);
2. the continuous-batching engine must emit token-for-token the same greedy
   stream as the naive one-shot loop (batched M.prefill + scalar-t
   M.decode_step) in BOTH cache layouts — the paged physical page pool
   (decode through the paged pallas kernel over the live
   `KVPager.block_table()`) and the per-slot contiguous baseline — and,
   on attention-only archs, with chunked prefill interleaving prompt
   chunks between decode steps. Slot batching, per-slot positions, page
   scatter/gather, tier paging and chunking must all be invisible to the
   sampled tokens.
3. with `--pool-dtype int8` the engine lanes run over BLOCK-QUANTIZED
   page pools (per-page int8 payload + (scale, zero) arrays,
   quantize-on-insert / dequantize-in-kernel). Quantization is lossy by
   design, so the gate is the documented drift bound rather than
   equality: at least `INT8_TOKEN_AGREEMENT` of the greedy tokens must
   match the fp naive stream in lockstep position (greedy divergence
   cascades, so agreement is dominated by how late the first flip
   happens; archs without self-attention KV quantize nothing and must
   stay exact). `--pool-dtype fp` (the default) is the bit-exact safety
   net and keeps the strict token-for-token gate on all 10 archs.

    PYTHONPATH=src python scripts/dev_serve.py [arch ...]
    PYTHONPATH=src python scripts/dev_serve.py --paged --interpret a b
        # the CI paged-engine-parity lane: paged/chunked engines only,
        # pallas kernels in interpret mode
    PYTHONPATH=src python scripts/dev_serve.py --paged --pool-dtype int8 \
        --interpret a b
        # the CI quantized lane: same engines over int8 pools,
        # drift-bounded token agreement
    PYTHONPATH=src python scripts/dev_serve.py --paged --prefix-cache \
        --interpret a b
        # the CI prefix-cache parity lane (attention-only archs): two
        # waves of identical prompts through one engine — wave 2 must
        # hit the radix trie (mapping the cached prompt pages instead
        # of re-storing them) and replay wave 1's tokens bit-for-bit
    PYTHONPATH=src python scripts/dev_serve.py --fleet 2 --interpret a b
        # the CI fleet-parity lane: (1) N engines behind the
        # round-robin FleetRouter must replay the single-engine greedy
        # token stream bit-for-bit on a staggered-arrival trace —
        # placement, per-engine clocks and queue routing must all be
        # invisible to the sampled tokens; (2) on attention-only archs,
        # a shared-prefix stream served under prefix-aware placement
        # must emit the SAME tokens as under round-robin (token parity)
        # with a STRICTLY higher aggregate prefix_hit_rate — the
        # router-side radix index keeps each system prompt's pages on
        # one engine instead of cold-missing on all of them
    PYTHONPATH=src python scripts/dev_serve.py --fault-plan chaos_smoke \
        --fleet 2 --interpret a b
        # the CI chaos-parity lane: the SAME staggered trace served
        # fault-free and under a named deterministic FaultPlan
        # (`serving.faults.PLANS`: chaos_smoke = engine 1 killed at
        # decode step 3 + 10% substrate transfer flaking, seed 0) must
        # emit BIT-IDENTICAL greedy tokens — the watchdog re-routes the
        # dead engine's queue and re-adopts its in-flight slots by
        # teacher-forced refill, retries re-price flaky transfers —
        # with every pool drained fully free (zero refcounts) and
        # `pool_bytes_used == ledger.placement_bytes()` on both engines
    PYTHONPATH=src python scripts/dev_serve.py --speculative ngram \
        --interpret a b
        # the CI speculative-parity lane (attention-only archs): the
        # paged engine with speculative decoding on (--speculative
        # ngram: self-speculative n-gram proposer; --speculative draft:
        # self-draft model proposer) must replay the plain greedy
        # engine's token stream BIT-FOR-BIT on fp pools — proposers and
        # the k-candidate verify cell may only change how many tokens
        # each sweep commits, never which tokens. Also reports the mean
        # acceptance length per verify step.
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python scripts/dev_serve.py --mesh dp2tp2 --interpret a b
        # the CI sharded-parity lane: the paged engine jitted over a
        # forced dp x tp host mesh (KV heads over the model axis, slots
        # over data, block tables replicated) must replay the meshless
        # single-device token stream bit-for-bit (fp pools; int8 is
        # drift-bounded), and the substrate's measured placement bytes
        # must equal the pager's pool accounting under the mesh
"""

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, kernels
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.frontends import synthetic_frontend_embeds
from repro.runtime.serve import chunked_prefill_supported
from repro.serving import (
    EngineConfig,
    INT8_TOKEN_AGREEMENT,
    Request,
    ServingEngine,
)

ctx = ParallelCtx(remat="none")

B, S, GEN = 2, 8, 6
MAXS = S + GEN
PAGE = 4


def naive_greedy(cfg, params, prompts, extras):
    """The pre-engine serve loop: batched prefill, scalar-t decode."""
    batch = {"tokens": prompts, **extras}
    caches, logits = M.prefill(params, batch, cfg, ctx, max_seq=MAXS)
    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(GEN - 1):
        logits, caches = M.decode_step(
            params, tok, caches, S + npfx + i, cfg, ctx
        )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.stack(out, axis=1))


def engine_greedy(cfg, params, prompts, *, paged, chunk=None,
                  pool_dtype="fp"):
    ecfg = EngineConfig(
        n_slots=B, max_seq=MAXS, prefill_buckets=(S,),
        page_tokens=PAGE, hot_window=8, local_budget_frac=0.5,
        admission="greedy", paged=paged, prefill_chunk=chunk,
        pool_dtype=pool_dtype,
    )
    engine = ServingEngine.build(cfg, ctx, ecfg, params=params)
    reqs = [
        Request(request_id=i, tokens=np.asarray(prompts[i]),
                max_new_tokens=GEN, arrival=0.0)
        for i in range(B)
    ]
    engine.run(reqs)
    return np.stack([np.asarray(r.output) for r in reqs]), engine


def engine_prefix_greedy(cfg, params, prompts, *, pool_dtype="fp"):
    """Two waves of the SAME prompts through ONE engine with the shared-
    prefix radix cache on: wave 1 populates the trie (cold misses), wave
    2 must hit it — mapping the cached prompt pages instead of storing
    duplicates — while emitting bit-identical greedy tokens."""
    ecfg = EngineConfig(
        n_slots=B, max_seq=MAXS, prefill_buckets=(S,),
        page_tokens=PAGE, hot_window=8, local_budget_frac=0.5,
        admission="greedy", paged=True, pool_dtype=pool_dtype,
        prefix_cache=True,
    )
    engine = ServingEngine.build(cfg, ctx, ecfg, params=params)
    waves, hits = [], 0
    for wave in range(2):
        reqs = [
            Request(request_id=wave * B + i, tokens=np.asarray(prompts[i]),
                    max_new_tokens=GEN, arrival=0.0)
            for i in range(B)
        ]
        stats = engine.run(reqs)
        waves.append(np.stack([np.asarray(r.output) for r in reqs]))
        hits = stats.prefix["hits"]
    return waves, hits, engine


def fleet_parity(cfg, params, n_engines):
    """Gate 1 of the fleet lane: round-robin fleet vs single engine,
    token-for-token on a staggered-arrival trace."""
    from repro.serving.fleet import FleetConfig, FleetRouter

    ecfg = EngineConfig(
        n_slots=B, max_seq=MAXS, prefill_buckets=(S,),
        page_tokens=PAGE, hot_window=8, local_budget_frac=0.5,
        admission="greedy", paged=True, pool_dtype="fp",
    )
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (2 * n_engines * B, S), 0, cfg.vocab_size
    ))

    def mk():
        return [Request(request_id=i, tokens=toks[i], max_new_tokens=GEN,
                        arrival=0.2 * i) for i in range(len(toks))]

    single = ServingEngine.build(cfg, ctx, ecfg, params=params)
    ref = mk()
    single.run(ref)
    router = FleetRouter.build(
        cfg, ctx, ecfg, FleetConfig(n_engines=n_engines,
                                    policy="round_robin"),
        params=params,
    )
    got = mk()
    stats = router.run(got)
    mismatch = sum(int(a.output != b.output) for a, b in zip(got, ref))
    balanced = min(stats.routed) > 0
    return mismatch, balanced, stats


def fleet_prefix(cfg, params, n_engines):
    """Gate 2 (attention-only archs): prefix-aware placement must beat
    round-robin's aggregate prefix_hit_rate on a shared-prefix stream
    at token parity."""
    from repro.serving.fleet import FleetConfig, FleetRouter
    from repro.serving.queue import shared_prefix_stream

    SP, GENP = 32, 4
    ecfg = EngineConfig(
        n_slots=B, max_seq=SP + GENP, prefill_buckets=(SP,),
        page_tokens=PAGE, hot_window=8, local_budget_frac=0.5,
        admission="greedy", paged=True, prefix_cache=True,
        pool_dtype="fp",
    )

    def stream():
        return shared_prefix_stream(
            6 * n_engines, cfg.vocab_size, seed=3,
            system_tokens=SP - 2 * PAGE, prompt_buckets=(SP,),
            gen_range=(GENP, GENP), arrival_rate=2.0,
            n_systems=n_engines,
        )

    outs, hits = {}, {}
    for pol in ("round_robin", "prefix_aware"):
        router = FleetRouter.build(
            cfg, ctx, ecfg,
            FleetConfig(n_engines=n_engines, policy=pol), params=params,
        )
        reqs = stream()
        stats = router.run(reqs)
        outs[pol] = [r.output for r in reqs]
        hits[pol] = stats.prefix["hit_rate"]
    parity = outs["round_robin"] == outs["prefix_aware"]
    return parity, hits["round_robin"], hits["prefix_aware"]


def fleet_chaos(cfg, params, n_engines, plan_name):
    """The chaos-parity lane: one staggered trace, served fault-free and
    under a named deterministic `FaultPlan`, must emit bit-identical
    greedy tokens (fp pools) — recovery re-routes the dead engine's
    queued work and re-adopts its in-flight slots by teacher-forced
    refill — and every engine's pool must drain fully free with the
    substrate placement contract intact."""
    from repro.serving.faults import make_plan
    from repro.serving.fleet import FleetConfig, FleetRouter

    plan = make_plan(plan_name)
    ecfg = EngineConfig(
        n_slots=B, max_seq=MAXS, prefill_buckets=(S,),
        page_tokens=PAGE, hot_window=8, local_budget_frac=0.5,
        admission="greedy", paged=True, pool_dtype="fp",
    )
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(5), (3 * n_engines * B, S), 0, cfg.vocab_size
    ))

    def mk():
        return [Request(request_id=i, tokens=toks[i], max_new_tokens=GEN,
                        arrival=0.05 * i) for i in range(len(toks))]

    clean_router = FleetRouter.build(
        cfg, ctx, ecfg,
        FleetConfig(n_engines=n_engines, policy="round_robin"),
        params=params,
    )
    clean = mk()
    clean_router.run(clean)

    router = FleetRouter.build(
        cfg, ctx, ecfg,
        FleetConfig(n_engines=n_engines, policy="round_robin",
                    faults=plan),
        params=params,
    )
    got = mk()
    stats = router.run(got)
    mismatch = sum(int(a.output != b.output) for a, b in zip(got, clean))
    drained = all(
        h.engine.pager.counters()["free_pages"] == h.engine.pager.n_phys
        and (h.engine.pager.ref == 0).all() and h.engine.pager.pins == 0
        for h in router.handles
    )
    placement_ok = all(
        h.engine.substrate is None
        or h.engine.pager.pool_bytes_used()
        == h.engine.substrate.ledger.placement_bytes()
        for h in router.handles
    )
    # SSM archs have no tier substrate — no transfer sites to flake
    has_sub = any(h.engine.substrate is not None for h in router.handles)
    return mismatch, drained, placement_ok, has_sub, plan, stats


def speculative_parity(cfg, params, mode):
    """The speculative-parity lane: paged engine with speculation on vs
    the plain greedy paged engine, token-for-token on fp pools. The
    proposer (ngram or self-draft) and the k-candidate verify cell must
    be invisible to the sampled tokens — only the per-sweep commit count
    may differ. Returns (mismatch, accept_len_mean, verify_steps)."""
    SGEN = 12
    maxs = S + SGEN
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size))

    def serve(ecfg):
        engine = ServingEngine.build(cfg, ctx, ecfg, params=params)
        reqs = [
            Request(request_id=i, tokens=prompts[i], max_new_tokens=SGEN,
                    arrival=0.0)
            for i in range(B)
        ]
        stats = engine.run(reqs)
        return np.stack([np.asarray(r.output) for r in reqs]), stats

    base = dict(
        n_slots=B, max_seq=maxs, prefill_buckets=(S,), page_tokens=PAGE,
        hot_window=8, local_budget_frac=0.5, admission="greedy",
        paged=True, pool_dtype="fp",
    )
    ref, _ = serve(EngineConfig(**base))
    got, stats = serve(EngineConfig(**base, speculative=mode,
                                    speculative_k=4))
    mismatch = int((ref != got).sum())
    return (mismatch, stats.spec["accept_len_mean"],
            stats.spec["verify_steps"])


def mesh_parity(cfg, params, dp, tp, pool_dtype):
    """The sharded-parity lane: the paged engine jitted over a forced
    dp x tp host mesh (KV heads over `model`, slots over `data`, block
    tables replicated — runtime.sharding.paged_cache_pspec) must emit
    the same greedy stream as the meshless single-device engine:
    bit-for-bit for fp pools, drift-bounded (INT8_TOKEN_AGREEMENT, the
    sharded contraction re-orders float sums) for int8. Also reports the
    substrate's measured placement contract under the mesh."""
    from repro.launch.mesh import ctx_for_mesh

    n_dev = dp * tp
    if len(jax.devices()) < n_dev:
        raise SystemExit(
            f"--mesh dp{dp}tp{tp} needs {n_dev} devices, have "
            f"{len(jax.devices())} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_dev}")
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size))
    ref, _ = engine_greedy(cfg, params, prompts, paged=True,
                           pool_dtype=pool_dtype)
    mesh = jax.make_mesh(
        (dp, tp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mctx = ctx_for_mesh(mesh, fsdp=False, remat="none")
    ecfg = EngineConfig(
        n_slots=B, max_seq=MAXS, prefill_buckets=(S,),
        page_tokens=PAGE, hot_window=8, local_budget_frac=0.5,
        admission="greedy", paged=True, pool_dtype=pool_dtype,
    )
    engine = ServingEngine.build(cfg, mctx, ecfg, params=params,
                                 mesh=mesh)
    reqs = [
        Request(request_id=i, tokens=np.asarray(prompts[i]),
                max_new_tokens=GEN, arrival=0.0)
        for i in range(B)
    ]
    stats = engine.run(reqs)
    got = np.stack([np.asarray(r.output) for r in reqs])
    agree = float((ref == got).mean())
    sub_ok, sub_mode = True, "off"
    if engine.substrate is not None:
        sub_mode = engine.substrate.mode
        placed = engine.substrate.ledger.placement_bytes()
        used = engine.pager.pool_bytes_used()
        sub_ok = abs(placed - used) <= 1e-6 * max(1.0, used)
    return agree, sub_ok, sub_mode, stats, engine


def check_teacher_forcing(cfg, params, toks, extras):
    full = {"tokens": toks[:, : S + 1], **extras}
    logits_full, _ = jax.jit(lambda p, b: M.forward(p, b, cfg, ctx))(
        params, full
    )
    caches, logits_pre = M.prefill(
        params, {"tokens": toks[:, :S], **extras}, cfg, ctx, max_seq=MAXS
    )
    err_pre = float(jnp.abs(logits_pre - logits_full[:, S - 1, :]).max())
    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    logits_dec, _ = M.decode_step(
        params, toks[:, S], caches, S + npfx, cfg, ctx
    )
    err_dec = float(jnp.abs(logits_dec - logits_full[:, S, :]).max())
    return err_pre, err_dec


def main():
    args = sys.argv[1:]
    paged_only = "--paged" in args
    prefix_cache = "--prefix-cache" in args
    if "--interpret" in args:
        kernels.force_backend("interpret")
    pool_dtype = "fp"
    if "--pool-dtype" in args:
        i = args.index("--pool-dtype")
        pool_dtype = args[i + 1]
        del args[i:i + 2]
    fleet_n = 0
    if "--fleet" in args:
        i = args.index("--fleet")
        fleet_n = int(args[i + 1])
        del args[i:i + 2]
    fault_plan = None
    if "--fault-plan" in args:
        i = args.index("--fault-plan")
        fault_plan = args[i + 1]
        del args[i:i + 2]
    spec_mode = None
    if "--speculative" in args:
        i = args.index("--speculative")
        spec_mode = args[i + 1]
        del args[i:i + 2]
    mesh_spec = None
    if "--mesh" in args:
        i = args.index("--mesh")
        mesh_spec = args[i + 1]
        del args[i:i + 2]
    archs = [a for a in args if not a.startswith("--")]
    archs = archs or configs.list_archs()

    if mesh_spec:
        import re

        m = re.fullmatch(r"dp(\d+)tp(\d+)", mesh_spec)
        if not m:
            raise SystemExit(f"--mesh wants dpDtpT (e.g. dp2tp2), got "
                             f"{mesh_spec!r}")
        dp, tp = int(m.group(1)), int(m.group(2))
        for arch in archs:
            cfg = dataclasses.replace(configs.reduced(arch),
                                      dtype="float32")
            params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
            agree, sub_ok, sub_mode, stats, _ = mesh_parity(
                cfg, params, dp, tp, pool_dtype)
            exact = pool_dtype != "int8"
            ok = (agree == 1.0 if exact
                  else agree >= INT8_TOKEN_AGREEMENT) and sub_ok
            status = "OK " if ok else "FAIL"
            print(f"{arch:28s} mesh=dp{dp}tp{tp} pool={pool_dtype} "
                  f"agree={agree:.2f} substrate={sub_mode} "
                  f"placement_ok={sub_ok} "
                  f"xfer_bytes="
                  f"{stats.summary().get('substrate_transfer_bytes', 0):.0f}"
                  f" {status}")
            assert status == "OK ", arch
        print("ALL OK")
        return

    if spec_mode:
        ran = 0
        for arch in archs:
            cfg = dataclasses.replace(configs.reduced(arch),
                                      dtype="float32")
            if not chunked_prefill_supported(cfg):
                # verify flattens slots -> slots*k token rows, which
                # per-slot SSM/conv state cannot follow — speculation is
                # attention-only by construction
                print(f"{arch:28s} speculative={spec_mode} SKIP "
                      f"(needs attention-only cache)")
                continue
            params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
            mismatch, accept, vsteps = speculative_parity(
                cfg, params, spec_mode)
            ok = mismatch == 0
            status = "OK " if ok else "FAIL"
            ran += 1
            print(f"{arch:28s} speculative={spec_mode} "
                  f"mismatch={mismatch} accept_len={accept:.2f} "
                  f"verify_steps={vsteps} {status}")
            assert status == "OK ", arch
        assert ran, "no attention-only arch ran the speculative lane"
        print("ALL OK")
        return

    if fleet_n and fault_plan:
        for arch in archs:
            cfg = dataclasses.replace(configs.reduced(arch),
                                      dtype="float32")
            params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
            (mismatch, drained, placement_ok, has_sub, plan,
             stats) = fleet_chaos(cfg, params, fleet_n, fault_plan)
            f = stats.faults
            ok = (mismatch == 0 and drained and placement_ok
                  and (not (plan.active and has_sub)
                       or f.get("retries", 0) >= 1)
                  and (plan.kill_engine is None
                       or f.get("engines_killed", 0) == 1))
            status = "OK " if ok else "FAIL"
            print(f"{arch:28s} chaos={fault_plan} fleet={fleet_n} "
                  f"mismatch={mismatch} "
                  f"killed={f.get('engines_killed', 0)} "
                  f"retries={f.get('retries', 0)} "
                  f"refill={f.get('reprefilled_tokens', 0)} "
                  f"drained={drained} placement_ok={placement_ok} "
                  f"{status}")
            assert status == "OK ", arch
        print("ALL OK")
        return

    if fleet_n:
        for arch in archs:
            cfg = dataclasses.replace(configs.reduced(arch),
                                      dtype="float32")
            params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
            mismatch, balanced, _ = fleet_parity(cfg, params, fleet_n)
            ok = mismatch == 0 and balanced
            note = ""
            if chunked_prefill_supported(cfg):
                parity, rr_hit, pa_hit = fleet_prefix(cfg, params, fleet_n)
                ok &= parity and pa_hit > rr_hit
                note = (f" prefix_hit rr={rr_hit:.3f} aware={pa_hit:.3f} "
                        f"parity={parity}")
            status = "OK " if ok else "FAIL"
            print(f"{arch:28s} fleet={fleet_n} rr_mismatch={mismatch} "
                  f"balanced={balanced}{note} {status}")
            assert status == "OK ", arch
        print("ALL OK")
        return
    for arch in archs:
        cfg = dataclasses.replace(configs.reduced(arch), dtype="float32")
        params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(
            jax.random.PRNGKey(1), (B, S + 2), 0, cfg.vocab_size
        )
        extras = {}
        if cfg.frontend == "vision_stub":
            extras["patches"] = synthetic_frontend_embeds(cfg, B, S)
        if cfg.frontend == "audio_stub":
            extras["frames"] = synthetic_frontend_embeds(cfg, B, S)

        if paged_only:
            tf_ok, err_pre, err_dec = True, float("nan"), float("nan")
        else:
            err_pre, err_dec = check_teacher_forcing(cfg, params, toks,
                                                     extras)
            tf_ok = err_pre < 2e-2 and err_dec < 2e-2

        prompts = np.asarray(toks[:, :S])
        lanes = [("paged", dict(paged=True, pool_dtype=pool_dtype))]
        if not paged_only:
            # the contiguous safety-net layout has no page pool to
            # quantize — pin the exact payload
            lanes.append(("dense", dict(paged=False, pool_dtype="fp")))
        if chunked_prefill_supported(cfg):
            lanes.append(("chunked", dict(paged=True, chunk=PAGE,
                                          pool_dtype=pool_dtype)))

        if extras:
            # engine equivalence needs per-request frontend embeds; the
            # engine derives them from request ids, the naive loop from the
            # same helper — compare only the non-frontend archs exactly and
            # run the engine for liveness on frontend archs
            naive = None
        else:
            naive = naive_greedy(cfg, params, jnp.asarray(prompts), {})

        eq_ok, eq_err, compiles, agree_min = True, 0, 0, 1.0
        for name, kw in lanes:
            eng_out, engine = engine_greedy(cfg, params, prompts, **kw)
            counts = engine.compile_counts()
            compiles += sum(v for v in counts.values() if v > 0)
            if naive is None:
                eq_ok &= eng_out.shape == (B, GEN)
                continue
            agree = float((naive == eng_out).mean())
            quantized = kw.get("pool_dtype", "fp") == "int8"
            if quantized:
                # lossy pool: drift-bounded agreement, not equality
                agree_min = min(agree_min, agree)
                eq_ok &= agree >= INT8_TOKEN_AGREEMENT
                eq_err += int((naive != eng_out).sum())
            else:
                bad = int((naive != eng_out).sum())
                eq_ok &= bad == 0
                eq_err += bad
        eq_err = "n/a" if naive is None else eq_err

        prefix_note = ""
        if prefix_cache and chunked_prefill_supported(cfg):
            waves, hits, engine = engine_prefix_greedy(
                cfg, params, prompts, pool_dtype=pool_dtype)
            counts = engine.compile_counts()
            compiles += sum(v for v in counts.values() if v > 0)
            # the cache must be invisible to the tokens: the hitting wave
            # replays the populating wave exactly (and both match naive —
            # drift-bounded when the pool is quantized)
            eq_ok &= bool((waves[0] == waves[1]).all())
            eq_ok &= hits >= B          # every wave-2 prompt hits the trie
            if naive is not None:
                agree = float((naive == waves[1]).mean())
                if pool_dtype == "int8":
                    agree_min = min(agree_min, agree)
                    eq_ok &= agree >= INT8_TOKEN_AGREEMENT
                else:
                    eq_ok &= agree == 1.0
            prefix_note = f" prefix_hits={hits}"

        status = "OK " if (tf_ok and eq_ok) else "FAIL"
        drift = (f" agree_min={agree_min:.2f}"
                 if pool_dtype == "int8" and naive is not None else "")
        print(
            f"{arch:28s} prefill_err={err_pre:9.2e} "
            f"decode_err={err_dec:9.2e} "
            f"lanes={'+'.join(n for n, _ in lanes)} "
            f"pool={pool_dtype} "
            f"engine_mismatch={eq_err}{drift}{prefix_note} "
            f"compiles={compiles} {status}"
        )
        assert status == "OK ", arch
    print("ALL OK")


if __name__ == "__main__":
    main()
