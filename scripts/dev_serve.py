"""Dev driver for the serving path, two gates per arch:

1. prefill+decode must agree with the teacher-forced forward (the original
   consistency check, kept);
2. the continuous-batching engine must emit token-for-token the same greedy
   stream as the naive one-shot loop (batched M.prefill + scalar-t
   M.decode_step) — slot batching, per-slot positions, cache splicing and
   tier paging must be invisible to the sampled tokens.

    PYTHONPATH=src python scripts/dev_serve.py [arch ...]
"""

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.frontends import synthetic_frontend_embeds
from repro.serving import EngineConfig, Request, ServingEngine

ctx = ParallelCtx(remat="none")

B, S, GEN = 2, 8, 6
MAXS = S + GEN


def naive_greedy(cfg, params, prompts, extras):
    """The pre-engine serve loop: batched prefill, scalar-t decode."""
    batch = {"tokens": prompts, **extras}
    caches, logits = M.prefill(params, batch, cfg, ctx, max_seq=MAXS)
    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(GEN - 1):
        logits, caches = M.decode_step(
            params, tok, caches, S + npfx + i, cfg, ctx
        )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.stack(out, axis=1))


def engine_greedy(cfg, params, prompts):
    ecfg = EngineConfig(
        n_slots=B, max_seq=MAXS, prefill_buckets=(S,),
        page_tokens=4, hot_window=8, local_budget_frac=0.5,
        admission="greedy",
    )
    engine = ServingEngine.build(cfg, ctx, ecfg, params=params)
    reqs = [
        Request(request_id=i, tokens=np.asarray(prompts[i]),
                max_new_tokens=GEN, arrival=0.0)
        for i in range(B)
    ]
    engine.run(reqs)
    return np.stack([np.asarray(r.output) for r in reqs]), engine


def check_teacher_forcing(cfg, params, toks, extras):
    full = {"tokens": toks[:, : S + 1], **extras}
    logits_full, _ = jax.jit(lambda p, b: M.forward(p, b, cfg, ctx))(
        params, full
    )
    caches, logits_pre = M.prefill(
        params, {"tokens": toks[:, :S], **extras}, cfg, ctx, max_seq=MAXS
    )
    err_pre = float(jnp.abs(logits_pre - logits_full[:, S - 1, :]).max())
    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    logits_dec, _ = M.decode_step(
        params, toks[:, S], caches, S + npfx, cfg, ctx
    )
    err_dec = float(jnp.abs(logits_dec - logits_full[:, S, :]).max())
    return err_pre, err_dec


def main():
    archs = sys.argv[1:] or configs.list_archs()
    for arch in archs:
        cfg = dataclasses.replace(configs.reduced(arch), dtype="float32")
        params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(
            jax.random.PRNGKey(1), (B, S + 2), 0, cfg.vocab_size
        )
        extras = {}
        if cfg.frontend == "vision_stub":
            extras["patches"] = synthetic_frontend_embeds(cfg, B, S)
        if cfg.frontend == "audio_stub":
            extras["frames"] = synthetic_frontend_embeds(cfg, B, S)

        err_pre, err_dec = check_teacher_forcing(cfg, params, toks, extras)
        tf_ok = err_pre < 2e-2 and err_dec < 2e-2

        if extras:
            # engine equivalence needs per-request frontend embeds; the
            # engine derives them from request ids, the naive loop from the
            # same helper — compare only the non-frontend archs exactly and
            # run the engine for liveness on frontend archs
            prompts = np.asarray(toks[:, :S])
            eng_out, engine = engine_greedy(cfg, params, prompts)
            eq_ok = eng_out.shape == (B, GEN)
            eq_err = "n/a"
        else:
            prompts = np.asarray(toks[:, :S])
            naive = naive_greedy(cfg, params, jnp.asarray(prompts), {})
            eng_out, engine = engine_greedy(cfg, params, prompts)
            eq_ok = bool((naive == eng_out).all())
            eq_err = int((naive != eng_out).sum())

        counts = engine.compile_counts()
        status = "OK " if (tf_ok and eq_ok) else "FAIL"
        print(
            f"{arch:28s} prefill_err={err_pre:9.2e} "
            f"decode_err={err_dec:9.2e} engine_mismatch={eq_err} "
            f"compiles={sum(v for v in counts.values() if v > 0)} {status}"
        )
        assert status == "OK ", arch
    print("ALL OK")


if __name__ == "__main__":
    main()
