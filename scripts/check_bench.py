"""Bench-regression gate: compare fresh BENCH_*.json smoke artifacts
against the committed baselines in `benchmarks/baselines/`.

    PYTHONPATH=src python scripts/check_bench.py \
        --fresh results/bench --baselines benchmarks/baselines

CI runs this inside the bench-smoke job AFTER `benchmarks/run.py
--smoke --out results/bench`, so a perf regression fails the job
instead of only uploading a quietly-worse artifact.

Only DETERMINISTIC metrics are gated — virtual-clock throughput/latency
and structural byte accounting, which are exact functions of the trace
and the code. Wall-clock numbers (us_per_call, tok_per_s_wall) are
never compared: CI machines are noisy by design.

Rules live in `RULES`: each entry names (file, row tag, metric) and a
tolerance type —

  rel_max  — fresh <= baseline * tol   (ratios/latencies that must not
             grow: pool_bytes_per_token, remote_share, p99 TTFT)
  rel_min  — fresh >= baseline * tol   (throughput/hit rates that must
             not collapse: tok_per_s_virtual, prefix_hit_rate)
  abs_max  — fresh <= tol              (absolute ceilings, baseline
             ignored: policy-comparison ratios like p99_ratio)
  abs_min  — fresh >= tol              (absolute floors, baseline
             ignored: acceptance-bar ratios like the speculative
             tokens/s gain)

A baseline file that doesn't exist is skipped with a warning (lets a PR
introduce a new bench before its first baseline lands); a MISSING row
tag or metric in a present pair of files is an error — silent metric
renames are exactly what a gate must catch. So is a file that fails to
parse or a metric that isn't a number: every mishap the gate can meet
turns into a one-line failure string, never a traceback. Exit 0 = all
rules pass.

After the rules run, a NON-FATAL pass prints one WARN line per baseline
file that carries numeric metrics no rule references — so a new bench
row can't quietly ship deterministic numbers the gate ignores. Warns
never change the exit code.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


# (file, tag, metric, rule, tolerance)
RULES = [
    # --- serving engine (BENCH_serve.json) ---
    ("BENCH_serve.json", "serve_chat", "pool_bytes_per_token",
     "rel_max", 1.10),
    ("BENCH_serve.json", "serve_chat", "tok_per_s_virtual",
     "rel_min", 0.90),
    ("BENCH_serve.json", "serve_long32k_hotness", "remote_share",
     "rel_max", 1.15),
    ("BENCH_serve.json", "serve_long32k_hotness", "pool_bytes_per_token",
     "rel_max", 1.10),
    ("BENCH_serve.json", "serve_int8_vs_fp16", "pool_bytes_ratio",
     "abs_max", 0.30),
    ("BENCH_serve.json", "serve_chunked_vs_serial", "tok_s_ratio",
     "rel_min", 0.95),
    # speculative decoding: the virtual tokens/s gain over the greedy
    # lane is the tentpole bar (absolute floor, not baseline-relative),
    # backed by the acceptance length and the per-token pager-bytes cut
    ("BENCH_serve.json", "serve_speculative_vs_greedy", "tok_s_ratio",
     "abs_min", 1.50),
    ("BENCH_serve.json", "serve_speculative_vs_greedy", "accept_len_mean",
     "rel_min", 0.90),
    ("BENCH_serve.json", "serve_speculative_vs_greedy",
     "bytes_per_token_ratio", "rel_max", 1.10),
    # physical-substrate traffic: measured transfer bytes must not grow,
    # and the pager-vs-ledger placement contract must hold exactly
    ("BENCH_serve.json", "serve_substrate", "transfer_bytes",
     "rel_max", 1.10),
    ("BENCH_serve.json", "serve_substrate", "placement_gap",
     "abs_max", 0.0),
    # --- pager/allocator churn (BENCH_pager.json) ---
    ("BENCH_pager.json", "pager_shared", "hit_rate",
     "rel_min", 0.95),
    ("BENCH_pager.json", "pager_prefix_chat", "pool_bytes_per_token_ratio",
     "rel_max", 1.10),
    ("BENCH_pager.json", "pager_prefix_chat", "tok_rate_ratio",
     "rel_min", 0.95),
    # --- fleet router (BENCH_fleet.json) ---
    ("BENCH_fleet.json", "fleet_bursty_kv_vs_rr", "p99_ratio",
     "abs_max", 1.00),
    ("BENCH_fleet.json", "fleet_bursty_kv_aware", "tok_per_s_virtual",
     "rel_min", 0.90),
    ("BENCH_fleet.json", "fleet_prefix_aware_vs_rr", "hit_rate_aware",
     "rel_min", 0.95),
    ("BENCH_fleet.json", "fleet_roles", "transfer_bytes",
     "rel_max", 1.10),
    # fault-recovery pricing: refill tokens and retry bytes are exact
    # functions of the chaos_smoke plan's Philox draws + the trace, so
    # rel_max catches any recovery-path change that re-prefills or
    # re-prices more than it used to; the p99 TTFT inflation is
    # watchdog-dominated (the killed engine's work waits out watchdog_s
    # on the virtual clock before re-routing), hence the wide absolute
    # ceiling rather than a vs-clean bar like fleet_bursty's
    ("BENCH_fleet.json", "fleet_faults", "recovery_overhead_tokens",
     "rel_max", 1.10),
    ("BENCH_fleet.json", "fleet_faults", "retry_bytes",
     "rel_max", 1.10),
    ("BENCH_fleet.json", "fleet_faults", "p99_ttft_ratio",
     "abs_max", 25.0),
]


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    rows = {}
    if not isinstance(payload, dict):
        raise ValueError(f"{path}: top level must be an object")
    for row in payload.get("rows", []):
        tag = row.get("tag")
        if tag is not None:
            rows[tag] = row
    return rows


def _metric_value(rows: dict, tag: str, metric: str):
    """(value, error) — error is a human-readable reason string when the
    metric is absent or not a number, value is a float otherwise."""
    if tag not in rows or metric not in rows[tag]:
        return None, "missing"
    raw = rows[tag][metric]
    if isinstance(raw, bool) or not isinstance(raw, (int, float)):
        return None, f"not numeric (got {raw!r})"
    return float(raw), None


def check(fresh_dir: str, base_dir: str, rules=RULES) -> list:
    """Returns a list of failure strings (empty = gate passes)."""
    failures = []
    cache = {}

    def rows_for(d, fname, which):
        """Parsed rows, None (file absent -> SKIP), or an error string
        (file present but unreadable -> hard failure, once per file)."""
        key = (d, fname)
        if key not in cache:
            path = os.path.join(d, fname)
            if not os.path.exists(path):
                cache[key] = None
            else:
                try:
                    cache[key] = load_rows(path)
                except (ValueError, OSError) as e:
                    msg = (f"{fname}: {which} file is unreadable "
                           f"({e}) — corrupt artifact?")
                    failures.append(msg)
                    cache[key] = msg
        return cache[key]

    for fname, tag, metric, rule, tol in rules:
        fresh = rows_for(fresh_dir, fname, "fresh")
        base = rows_for(base_dir, fname, "baseline")
        if fresh is None or base is None:
            which = "fresh" if fresh is None else "baseline"
            print(f"SKIP {fname}:{tag}:{metric} ({which} file missing)")
            continue
        if isinstance(fresh, str) or isinstance(base, str):
            continue                     # unreadable file already failed
        fval, err = _metric_value(fresh, tag, metric)
        if err == "missing":
            failures.append(
                f"{fname}: fresh run is missing {tag}.{metric} — "
                f"renamed or dropped metric?")
            continue
        if err is not None:
            failures.append(
                f"{fname}: fresh {tag}.{metric} is {err}")
            continue
        if rule == "abs_max":
            ok = fval <= tol
            detail = f"fresh={fval:.4g} ceiling={tol:.4g}"
        elif rule == "abs_min":
            ok = fval >= tol
            detail = f"fresh={fval:.4g} floor={tol:.4g}"
        else:
            bval, err = _metric_value(base, tag, metric)
            if err == "missing":
                failures.append(
                    f"{fname}: baseline is missing {tag}.{metric} — "
                    f"regenerate benchmarks/baselines/")
                continue
            if err is not None:
                failures.append(
                    f"{fname}: baseline {tag}.{metric} is {err}")
                continue
            if rule == "rel_max":
                bound = bval * tol
                ok = fval <= bound
            elif rule == "rel_min":
                bound = bval * tol
                ok = fval >= bound
            else:
                raise ValueError(f"unknown rule {rule!r}")
            detail = (f"fresh={fval:.4g} baseline={bval:.4g} "
                      f"bound={bound:.4g} ({rule} x{tol})")
        status = "OK  " if ok else "FAIL"
        print(f"{status} {fname}:{tag}:{metric} {detail}")
        if not ok:
            failures.append(f"{fname}:{tag}:{metric} {detail}")
    return failures


def warn_unreferenced(base_dir: str, rules=RULES) -> None:
    """Non-fatal visibility pass: one WARN line per baseline file whose
    rows carry numeric metrics NO rule references — deterministic
    numbers that can drift silently because nothing gates them. This
    never fails the run (percentile families and raw counters are
    recorded for humans, not all gated by design); it exists so a new
    bench row doesn't quietly ship metrics the gate ignores."""
    referenced = {(f, t, m) for f, t, m, _, _ in rules}
    if not os.path.isdir(base_dir):
        return
    for fname in sorted(os.listdir(base_dir)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        try:
            rows = load_rows(os.path.join(base_dir, fname))
        except (ValueError, OSError):
            continue                 # unreadable baselines fail the gate
        loose = [
            f"{tag}.{metric}"
            for tag, row in rows.items()
            for metric, val in row.items()
            if metric != "tag"
            and not isinstance(val, bool)
            and isinstance(val, (int, float))
            and (fname, tag, metric) not in referenced
        ]
        if loose:
            print(f"WARN {fname}: {len(loose)} baseline metric(s) no "
                  f"rule references (e.g. {', '.join(sorted(loose)[:3])})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory with the fresh BENCH_*.json artifacts")
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="directory with the committed baselines")
    args = ap.parse_args(argv)
    failures = check(args.fresh, args.baselines, RULES)
    warn_unreferenced(args.baselines, RULES)
    if failures:
        print(f"\nbench regression gate FAILED "
              f"({len(failures)} rule(s)):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
