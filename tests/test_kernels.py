"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs the pure-jnp oracle
(deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as dops
from repro.kernels.decode_attention import ref as dref
from repro.kernels.decode_attention.decode_attention import flash_decode
from repro.kernels.decode_attention.paged import paged_flash_decode
from repro.kernels.flash_attention import ops as fops
from repro.kernels.flash_attention import ref as fref
from repro.kernels.flash_attention.chunked import mha_chunked
from repro.kernels.flash_attention.flash_attention import flash_mha
from repro.kernels.flash_attention.paged_prefill import paged_prefill_flash
from repro.kernels.lbench import ref as lref
from repro.kernels.lbench.lbench import lbench_pallas
from repro.kernels.ssd_scan import ref as sref
from repro.kernels.ssd_scan.chunked import ssd_chunked_jnp
from repro.kernels.ssd_scan.ssd_scan import ssd_pallas

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------------- lbench
@pytest.mark.parametrize("nflop", [1, 2, 5, 16, 32])
@pytest.mark.parametrize("n", [512, 4096])
def test_lbench_sweep(nflop, n):
    a = jax.random.normal(jax.random.PRNGKey(n), (n,), jnp.float32)
    r = lref.lbench(a, nflop)
    p = lbench_pallas(a, nflop, interpret=True)
    np.testing.assert_allclose(np.asarray(p), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_lbench_flops_model():
    assert lref.flops(100, 1) == 100
    assert lref.flops(100, 2) == 200
    assert lref.flops(100, 5) == 500
    assert lref.bytes_moved(100) == 800


# ----------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "B,Sq,Skv,H,KV,D,causal,dtype",
    [
        (2, 256, 256, 4, 2, 64, True, jnp.float32),
        (1, 512, 512, 4, 1, 128, True, jnp.float32),
        (2, 128, 512, 4, 4, 64, True, jnp.float32),   # decode-window offset
        (2, 256, 256, 4, 2, 64, False, jnp.float32),
        (2, 256, 256, 8, 2, 64, True, jnp.bfloat16),
    ],
)
def test_flash_pallas_sweep(B, Sq, Skv, H, KV, D, causal, dtype):
    off = Skv - Sq if Skv != Sq else 0
    ks = jax.random.split(jax.random.PRNGKey(Sq + H), 3)
    q = _rand(ks[0], (B, Sq, H, D), dtype)
    k = _rand(ks[1], (B, Skv, KV, D), dtype)
    v = _rand(ks[2], (B, Skv, KV, D), dtype)
    r = fref.mha(q, k, v, causal=causal, kv_offset=off)
    p = flash_mha(q, k, v, causal, None, off, 128, 128, True)
    tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(p, np.float32), np.asarray(r, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_chunked_grads_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (2, 256, 4, 64), jnp.float32)
    k = _rand(ks[1], (2, 256, 2, 64), jnp.float32)
    v = _rand(ks[2], (2, 256, 2, 64), jnp.float32)
    g1 = jax.grad(lambda *a: (mha_chunked(*a, True, None, 0, 64, 64) ** 2)
                  .sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (fref.mha(*a) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_flash_pallas_bwd_pairing():
    """Pallas fwd (interpret) + chunked bwd == oracle grads."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (1, 256, 4, 64), jnp.float32)
    k = _rand(ks[1], (1, 256, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 256, 2, 64), jnp.float32)
    g1 = jax.grad(
        lambda *a: (flash_mha(*a, True, None, 0, 128, 128, True) ** 2).sum(),
        argnums=(0, 1, 2),
    )(q, k, v)
    g2 = jax.grad(lambda *a: (fref.mha(*a) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


# ----------------------------------------------------- decode attention
@pytest.mark.parametrize(
    "B,S,H,KV,D,dtype",
    [
        (2, 512, 8, 2, 64, jnp.float32),
        (1, 1024, 4, 4, 128, jnp.float32),
        (3, 256, 6, 2, 32, jnp.float32),
        (2, 512, 8, 2, 64, jnp.bfloat16),
    ],
)
def test_decode_pallas_sweep(B, S, H, KV, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + D), 3)
    q = _rand(ks[0], (B, H, D), dtype)
    k = _rand(ks[1], (B, S, KV, D), dtype)
    v = _rand(ks[2], (B, S, KV, D), dtype)
    length = jnp.array([(S // 2 + 7 * i) % S + 1 for i in range(B)],
                       jnp.int32)
    r = dref.decode_mha(q, k, v, length)
    p = flash_decode(q, k, v, length, interpret=True, block_k=128)
    tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(p, np.float32), np.asarray(r, np.float32),
        rtol=tol, atol=tol,
    )


def test_decode_ragged_lengths():
    B, S, H, KV, D = 4, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, KV, D), jnp.float32)
    v = _rand(ks[2], (B, S, KV, D), jnp.float32)
    length = jnp.array([1, 17, 100, 256], jnp.int32)
    r = dref.decode_mha(q, k, v, length)
    p = flash_decode(q, k, v, length, interpret=True, block_k=64)
    np.testing.assert_allclose(np.asarray(p), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


# ----------------------------------------------- paged decode attention
def _paged_layout(k, v, page, seed=0, extra_phys=3):
    """Scatter a dense (B,S,KV,D) cache into a permuted physical page
    pool + block tables (non-contiguous, interleaved physical order)."""
    B, S, KV, D = k.shape
    n_log = S // page
    n_phys = B * n_log + extra_phys          # a few never-mapped pages
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_phys)[: B * n_log]
    bt = perm.reshape(B, n_log).astype(np.int32)
    kp = np.asarray(
        jax.random.normal(jax.random.PRNGKey(99), (n_phys, page, KV, D))
    ).astype(np.asarray(k).dtype)            # garbage in unmapped pages
    vp = kp.copy()
    kr = np.asarray(k).reshape(B, n_log, page, KV, D)
    vr = np.asarray(v).reshape(B, n_log, page, KV, D)
    for b in range(B):
        for i in range(n_log):
            kp[bt[b, i]] = kr[b, i]
            vp[bt[b, i]] = vr[b, i]
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt)


@pytest.mark.parametrize("page", [16, 64, 128])
@pytest.mark.parametrize(
    "B,S,H,KV,D,dtype",
    [
        (2, 512, 8, 2, 64, jnp.float32),
        (1, 256, 4, 4, 128, jnp.float32),
        (2, 512, 8, 2, 64, jnp.bfloat16),
    ],
)
def test_paged_decode_matches_dense_ref(page, B, S, H, KV, D, dtype):
    """Acceptance: the paged kernel == the dense oracle token-for-token
    across page sizes {16, 64, 128} with scattered physical pages and
    ragged lengths."""
    ks = jax.random.split(jax.random.PRNGKey(S + D + page), 3)
    q = _rand(ks[0], (B, H, D), dtype)
    k = _rand(ks[1], (B, S, KV, D), dtype)
    v = _rand(ks[2], (B, S, KV, D), dtype)
    lengths = jnp.array([(S // 2 + 17 * i) % S + 1 for i in range(B)],
                        jnp.int32)
    kp, vp, bt = _paged_layout(k, v, page, seed=page)
    r = dref.decode_mha(q, k, v, lengths)
    p = paged_flash_decode(q, kp, vp, bt, lengths, interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(p, np.float32), np.asarray(r, np.float32),
        rtol=tol, atol=tol,
    )


def test_paged_ops_clamps_dead_table_entries():
    """ops.paged_decode_mha must tolerate garbage block-table entries
    past the valid length (the pager's freed/unallocated slots)."""
    B, S, H, KV, D, page = 2, 256, 4, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, KV, D), jnp.float32)
    v = _rand(ks[2], (B, S, KV, D), jnp.float32)
    lengths = jnp.array([40, 200], jnp.int32)
    kp, vp, bt = _paged_layout(k, v, page)
    bt = np.asarray(bt).copy()
    n_phys = kp.shape[0]
    live = np.arange(bt.shape[1])[None, :] * page < np.asarray(lengths)[:, None]
    bt[~live] = n_phys + 10_000              # out-of-bounds garbage
    r = dref.decode_mha(q, k, v, lengths)
    for impl in ("reference", "interpret"):
        out = dops.paged_decode_mha(q, kp, vp, jnp.asarray(bt), lengths,
                                    impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                                   rtol=2e-5, atol=2e-5)


def test_paged_kernel_reads_kv_pager_block_table():
    """The pager's page grain is real at the kernel level: admit
    interleaved requests into a KVPager, lay K/V out physically by its
    block_table(), and the paged kernel must reproduce the dense oracle
    token-for-token."""
    from repro.serving.kv_pager import KVPager, PagerConfig

    B, H, KV, D, page_tokens = 3, 4, 2, 64, 16
    max_seq = 128
    pager = KVPager(
        B, max_seq, bytes_per_token=2.0 * KV * D * 2, resident_bytes=0.0,
        pcfg=PagerConfig(page_tokens=page_tokens, policy="none"),
    )
    # interleaved admits/releases scatter physical pages across slots
    pager.admit(0, 64)
    pager.admit(1, 128)
    pager.release(0)
    pager.admit(0, 96)
    pager.admit(2, 48)
    lengths = jnp.asarray(pager.lengths, jnp.int32)
    bt = pager.block_table()
    assert bt.shape == (B, max_seq // page_tokens)
    mapped = bt[pager.valid]
    assert len(set(mapped)) == len(mapped)    # no phys page shared

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    k = _rand(ks[1], (B, max_seq, KV, D), jnp.float32)
    v = _rand(ks[2], (B, max_seq, KV, D), jnp.float32)
    n_phys = B * (max_seq // page_tokens)
    kp = np.zeros((n_phys, page_tokens, KV, D), np.float32)
    vp = np.zeros_like(kp)
    kr = np.asarray(k).reshape(B, -1, page_tokens, KV, D)
    vr = np.asarray(v).reshape(B, -1, page_tokens, KV, D)
    for s, p in zip(*np.nonzero(pager.valid)):
        kp[bt[s, p]] = kr[s, p]
        vp[bt[s, p]] = vr[s, p]
    r = dref.decode_mha(q, k, v, lengths)
    out = dops.paged_decode_mha(q, jnp.asarray(kp), jnp.asarray(vp),
                                jnp.asarray(bt), lengths, impl="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


# -------------------------------------------------------------- SSD
@pytest.mark.parametrize(
    "B,S,H,P,G,N,Q",
    [
        (2, 256, 4, 16, 1, 32, 64),
        (1, 128, 4, 32, 2, 16, 32),
        (2, 128, 8, 16, 1, 64, 128),
        (1, 192, 2, 8, 1, 8, 64),    # non-pow2 S
    ],
)
def test_ssd_pallas_sweep(B, S, H, P, G, N, Q):
    ks = jax.random.split(jax.random.PRNGKey(S + N + P), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    yr, hr = sref.ssd(x, dt, A, Bm, Cm, D)
    yp, hp = ssd_pallas(x, dt, A, Bm, Cm, D, None, Q, True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunked_initial_state_and_grads():
    B, S, H, P, G, N = 1, 128, 4, 16, 1, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 7)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    h0 = jax.random.normal(ks[6], (B, H, P, N)) * 0.1
    yr, hr = sref.ssd(x, dt, A, Bm, Cm, D, h0)
    yc, hc = ssd_chunked_jnp(x, dt, A, Bm, Cm, D, h0, 32)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    g1 = jax.grad(lambda x: (ssd_chunked_jnp(x, dt, A, Bm, Cm, D, h0, 32)[0]
                             ** 2).sum())(x)
    g2 = jax.grad(lambda x: (sref.ssd(x, dt, A, Bm, Cm, D, h0)[0]
                             ** 2).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_decode_matches_scan():
    B, H, P, G, N = 2, 4, 16, 1, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 6)
    state = jax.random.normal(ks[5], (B, H, P, N)) * 0.3
    x = jax.random.normal(ks[0], (B, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bt = jax.random.normal(ks[3], (B, G, N))
    Ct = jax.random.normal(ks[4], (B, G, N))
    D = jnp.ones((H,))
    y1, s1 = sref.ssd_decode(x, dt, A, Bt, Ct, D, state)
    # one-step full scan from the same initial state
    y2, s2 = sref.ssd(x[:, None], dt[:, None], A, Bt[:, None], Ct[:, None],
                      D, state)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2[:, 0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)


# ----------------------------------------------- paged chunked prefill
@pytest.mark.parametrize("page", [16, 64, 128])
@pytest.mark.parametrize(
    "B,S,C,H,KV,D,dtype",
    [
        (2, 512, 128, 8, 2, 64, jnp.float32),
        (1, 256, 128, 4, 4, 128, jnp.float32),
        (2, 512, 128, 8, 2, 64, jnp.bfloat16),
    ],
)
def test_paged_prefill_matches_dense_ref(page, B, S, C, H, KV, D, dtype):
    """The chunked paged-prefill kernel == dense causal attention with a
    kv offset, across page sizes {16, 64, 128}, chunk offsets and
    scattered physical pages."""
    ks = jax.random.split(jax.random.PRNGKey(S + D + page + 1), 3)
    q = _rand(ks[0], (B, C, H, D), dtype)
    k = _rand(ks[1], (B, S, KV, D), dtype)
    v = _rand(ks[2], (B, S, KV, D), dtype)
    kp, vp, bt = _paged_layout(k, v, page, seed=page + 1)
    tol = TOL[dtype]
    for c0 in (0, C, S - C):                  # first / middle / last chunk
        r = fref.mha(q, k[:, : c0 + C], v[:, : c0 + C], causal=True,
                     kv_offset=c0)
        c0v = jnp.full((B,), c0, jnp.int32)
        p = paged_prefill_flash(q, kp, vp, bt, c0v, interpret=True)
        np.testing.assert_allclose(
            np.asarray(p, np.float32), np.asarray(r, np.float32),
            rtol=tol, atol=tol,
        )


def test_paged_prefill_ops_clamps_frontier_entries():
    """ops.paged_prefill_mha must tolerate garbage block-table entries
    above the causal frontier (pages the prompt has not reached yet)."""
    B, S, C, H, KV, D, page = 2, 256, 64, 4, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = _rand(ks[0], (B, C, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, KV, D), jnp.float32)
    v = _rand(ks[2], (B, S, KV, D), jnp.float32)
    kp, vp, bt = _paged_layout(k, v, page)
    c0 = 64
    bt = np.asarray(bt).copy()
    live = np.arange(bt.shape[1])[None, :] * page < c0 + C
    bt[np.broadcast_to(~live, bt.shape)] = kp.shape[0] + 10_000
    r = fref.mha(q, k[:, : c0 + C], v[:, : c0 + C], causal=True,
                 kv_offset=c0)
    for impl in ("reference", "interpret"):
        out = fops.paged_prefill_mha(q, kp, vp, jnp.asarray(bt),
                                     jnp.full((B,), c0, jnp.int32),
                                     impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                                   rtol=2e-5, atol=2e-5)


def test_paged_prefill_chunk_walk_over_live_pager_table():
    """Chunked prefill against a LIVE KVPager block table: extend() the
    slot one chunk at a time, scatter each chunk's K/V through the
    table (`models.attention.paged_chunk_insert`), and every chunk's
    paged attention must equal the dense causal reference over the
    prefix — the end-to-end write-then-gather loop the serving engine
    runs."""
    from repro.models.attention import paged_chunk_insert
    from repro.serving.kv_pager import KVPager, PagerConfig

    B, H, KV, D = 1, 4, 2, 64
    page_tokens, C, S = 16, 32, 128
    pager = KVPager(
        2, S, bytes_per_token=2.0 * KV * D * 2, resident_bytes=0.0,
        pcfg=PagerConfig(page_tokens=page_tokens, policy="none"),
    )
    pager.admit(1, 40)                       # co-resident slot scatters
    slot = 0
    ks = jax.random.split(jax.random.PRNGKey(21), 3)
    k = _rand(ks[0], (B, S, KV, D), jnp.float32)
    v = _rand(ks[1], (B, S, KV, D), jnp.float32)
    n_phys = 2 * (S // page_tokens)
    kp = jnp.zeros((n_phys, page_tokens, KV, D), jnp.float32)
    vp = jnp.zeros_like(kp)
    for c0 in range(0, S, C):
        pager.extend(slot, c0 + C)
        row = jnp.asarray(pager.block_table()[slot][None, :])
        kp = paged_chunk_insert(kp, k[:, c0:c0 + C], c0, row, page_tokens)
        vp = paged_chunk_insert(vp, v[:, c0:c0 + C], c0, row, page_tokens)
        q = _rand(jax.random.fold_in(ks[2], c0), (B, C, H, D), jnp.float32)
        r = fref.mha(q, k[:, : c0 + C], v[:, : c0 + C], causal=True,
                     kv_offset=c0)
        for impl in ("reference", "interpret"):
            out = fops.paged_prefill_mha(q, kp, vp, row,
                                         jnp.full((B,), c0, jnp.int32),
                                         impl=impl)
            np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                                       rtol=2e-5, atol=2e-5)


# ------------------------------------- block-quantized (int8) page pools
from repro.kernels import quant
from repro.kernels.page_io import ops as pops


@pytest.mark.parametrize("page", [16, 64, 128])
def test_page_quant_roundtrip_error_bounded(page):
    """Satellite acceptance: per-page int8 round-trip error <= scale/2
    across page sizes {16, 64, 128} and adversarial ranges (all-zero
    page, single-outlier page)."""
    KV, D = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(page), (4, page, KV, D),
                          jnp.float32) * 3.0
    zero_page = jnp.zeros((1, page, KV, D), jnp.float32)
    outlier = jnp.zeros((1, page, KV, D), jnp.float32
                        ).at[0, page // 2, 1, 3].set(500.0)
    for pages in (x, zero_page, outlier):
        q8, sz = quant.quantize_pages(pages)
        back = quant.dequantize_pages(q8, sz)
        err = np.abs(np.asarray(back - pages))
        # bound per (page, head): half a quantization step
        bound = np.asarray(sz[..., 0])[:, None, :, None] / 2
        assert (err <= bound + 1e-6).all()
    # the all-zero page round-trips exactly
    q8, sz = quant.quantize_pages(zero_page)
    assert np.abs(np.asarray(quant.dequantize_pages(q8, sz))).max() == 0.0


@pytest.mark.parametrize("page", [16, 64, 128])
def test_paged_decode_quant_kernel_matches_quant_oracle(page):
    """The int8 decode kernel (scales on the scalar-prefetch channel,
    dequant epilogue) == the dequant-gather oracle exactly, and both
    track the fp dense oracle within the quantization drift."""
    B, S, H, KV, D = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(page), 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, KV, D), jnp.float32)
    v = _rand(ks[2], (B, S, KV, D), jnp.float32)
    lengths = jnp.array([(S // 2 + 17 * i) % S + 1 for i in range(B)],
                        jnp.int32)
    kp, vp, bt = _paged_layout(k, v, page, seed=page)
    k8, ksz = quant.quantize_pages(kp)
    v8, vsz = quant.quantize_pages(vp)
    r = dops.paged_decode_mha(q, k8, v8, bt, lengths, k_sz=ksz, v_sz=vsz,
                              impl="reference")
    p = dops.paged_decode_mha(q, k8, v8, bt, lengths, k_sz=ksz, v_sz=vsz,
                              impl="interpret")
    np.testing.assert_allclose(np.asarray(p), np.asarray(r), rtol=2e-5,
                               atol=2e-5)
    dense = dref.decode_mha(q, k, v, lengths)
    assert float(jnp.abs(p - dense).max()) < 0.05


def test_paged_prefill_quant_gather_matches_quant_oracle():
    """The int8 gather-only prefill kernel == the dequant-gather oracle."""
    B, S, C, H, KV, D, page = 1, 256, 64, 4, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (B, C, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, KV, D), jnp.float32)
    v = _rand(ks[2], (B, S, KV, D), jnp.float32)
    kp, vp, bt = _paged_layout(k, v, page, seed=3)
    k8, ksz = quant.quantize_pages(kp)
    v8, vsz = quant.quantize_pages(vp)
    for c0 in (0, 64, S - C):
        c0v = jnp.full((B,), c0, jnp.int32)
        r = fops.paged_prefill_mha(q, k8, v8, bt, c0v, k_sz=ksz, v_sz=vsz,
                                   impl="reference")
        p = fops.paged_prefill_mha(q, k8, v8, bt, c0v, k_sz=ksz, v_sz=vsz,
                                   impl="interpret")
        np.testing.assert_allclose(np.asarray(p), np.asarray(r),
                                   rtol=2e-5, atol=2e-5)


# --------------------------------------------- fused chunk insert+attend
def test_fused_prefill_insert_bit_for_bit_cache_parity_fp():
    """Acceptance: the fused insert+attend kernel (chunk write through
    input_output_aliases) produces BIT-FOR-BIT the same pool as the
    unfused scatter-then-attend reference in fp mode, with matching
    attention output, over a full chunk walk."""
    B, S, C, H, KV, D, page = 1, 256, 64, 4, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    k = _rand(ks[0], (B, S, KV, D), jnp.float32)
    v = _rand(ks[1], (B, S, KV, D), jnp.float32)
    n_log = S // page
    n_phys = 2 * n_log
    rng = np.random.default_rng(5)
    bt = jnp.asarray(rng.permutation(n_phys)[:n_log]
                     .reshape(B, n_log).astype(np.int32))
    kp = jnp.zeros((n_phys, page, KV, D), jnp.float32)
    vp = jnp.zeros_like(kp)
    kp_ref, vp_ref = kp, vp
    for c0 in range(0, S, C):
        qc = _rand(jax.random.fold_in(ks[2], c0), (B, C, H, D),
                   jnp.float32)
        kn, vn = k[:, c0:c0 + C], v[:, c0:c0 + C]
        c0v = jnp.full((B,), c0, jnp.int32)
        o, kp, vp = fops.paged_prefill_insert_mha(
            qc, kp, vp, kn, vn, bt, c0v, impl="interpret")
        o_ref, kp_ref, vp_ref = fops.paged_prefill_insert_mha(
            qc, kp_ref, vp_ref, kn, vn, bt, c0v, impl="reference")
        np.testing.assert_array_equal(np.asarray(kp), np.asarray(kp_ref))
        np.testing.assert_array_equal(np.asarray(vp), np.asarray(vp_ref))
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-5)
        dense = fref.mha(qc, k[:, :c0 + C], v[:, :c0 + C], causal=True,
                         kv_offset=c0)
        np.testing.assert_allclose(np.asarray(o), np.asarray(dense),
                                   rtol=2e-5, atol=2e-5)


def test_fused_prefill_insert_q8_parity():
    """The int8 fused kernel writes payload AND (scale, zero) arrays
    exactly like the unfused quantize-scatter-attend reference."""
    B, S, C, H, KV, D, page = 1, 128, 32, 4, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    k = _rand(ks[0], (B, S, KV, D), jnp.float32)
    v = _rand(ks[1], (B, S, KV, D), jnp.float32)
    n_log = S // page
    n_phys = 2 * n_log
    rng = np.random.default_rng(7)
    bt = jnp.asarray(rng.permutation(n_phys)[:n_log]
                     .reshape(B, n_log).astype(np.int32))
    pools = {
        impl: [jnp.zeros((n_phys, page, KV, D), jnp.int8),
               jnp.zeros((n_phys, page, KV, D), jnp.int8),
               jnp.zeros((n_phys, KV, 2), jnp.float32),
               jnp.zeros((n_phys, KV, 2), jnp.float32)]
        for impl in ("interpret", "reference")
    }
    n_wp = C // page
    for c0 in range(0, S, C):
        qc = _rand(jax.random.fold_in(ks[2], c0), (B, C, H, D),
                   jnp.float32)
        k8, ksz = quant.quantize_pages(
            k[:, c0:c0 + C].reshape(B, n_wp, page, KV, D))
        v8, vsz = quant.quantize_pages(
            v[:, c0:c0 + C].reshape(B, n_wp, page, KV, D))
        k8, v8 = k8.reshape(B, C, KV, D), v8.reshape(B, C, KV, D)
        c0v = jnp.full((B,), c0, jnp.int32)
        outs = {}
        for impl in ("interpret", "reference"):
            kp, vp, kszp, vszp = pools[impl]
            outs[impl], kp, vp, kszp, vszp = \
                fops.paged_prefill_insert_mha_q8(
                    qc, kp, vp, kszp, vszp, k8, v8, ksz, vsz, bt, c0v,
                    impl=impl)
            pools[impl] = [kp, vp, kszp, vszp]
        for a, b in zip(pools["interpret"], pools["reference"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(np.asarray(outs["interpret"]),
                                   np.asarray(outs["reference"]),
                                   rtol=2e-5, atol=2e-5)


def test_page_writer_matches_scatter_and_is_scatter_free():
    """kernels.page_io: the aliased writer == the jnp scatter oracle on
    fp/int8 payloads and (scale, zero) rows, and its jaxpr contains no
    scatter primitive."""
    nb, P, page, KV, hd, n_wp = 2, 12, 8, 2, 16, 3
    pool = _rand(jax.random.PRNGKey(0), (nb, P, page, KV, hd),
                 jnp.float32)
    tiles = _rand(jax.random.PRNGKey(1), (nb, n_wp, page, KV, hd),
                  jnp.float32)
    phys = jnp.array([9, 0, 4], jnp.int32)
    a = pops.write_pages(pool, tiles, phys, impl="reference")
    b = pops.write_pages(pool, tiles, phys, impl="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    sz = jnp.zeros((nb, P, KV, 2), jnp.float32)
    szt = _rand(jax.random.PRNGKey(2), (nb, n_wp, KV, 2), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(pops.write_pages(sz, szt, phys, impl="interpret")),
        np.asarray(pops.write_pages(sz, szt, phys, impl="reference")))
    jx = jax.make_jaxpr(
        lambda *a: pops.write_pages(*a, impl="interpret")
    )(pool, tiles, phys)
    assert "scatter" not in repr(jx)


def test_chunked_prefill_cell_issues_zero_page_scatters():
    """Acceptance: with the kernels active (interpret backend, the same
    dispatch TPU takes), the whole chunked-prefill CELL — embedding,
    layer stack, paged attention, cache write — lowers to a jaxpr with
    ZERO scatter ops in BOTH pool dtypes: the chunk's KV write rides the
    paged-prefill kernel's output aliasing instead of a standalone jnp
    page scatter. The fp fused path's bit-for-bit cache parity vs the
    unfused oracle is asserted in
    `test_fused_prefill_insert_bit_for_bit_cache_parity_fp`."""
    import dataclasses

    from repro import configs, kernels
    from repro.common.parallel import ParallelCtx
    from repro.models import model as M
    from repro.runtime.serve import build_prefill_chunk

    cfg = dataclasses.replace(configs.reduced("smollm_360m"),
                              dtype="float32")
    ctx = ParallelCtx(remat="none")
    page, chunk, n_slots, max_seq = 4, 8, 2, 16
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((1, chunk), jnp.int32)
    bt = jnp.zeros((n_slots, max_seq // page), jnp.int32)
    kernels.force_backend("interpret")
    try:
        for pool_dtype in ("fp", "int8"):
            caches = M.make_paged_decode_caches(
                cfg, n_slots, max_seq, page, pool_dtype=pool_dtype)
            cell = build_prefill_chunk(cfg, ctx, page)
            jx = jax.make_jaxpr(cell)(
                params, toks, caches, jnp.int32(0), jnp.int32(0), bt)
            assert "scatter" not in repr(jx), pool_dtype
    finally:
        kernels.force_backend(None)


def test_select_impl_dispatch():
    """The shared dispatch helper all ops.py modules route through."""
    from repro.kernels import select_impl

    assert select_impl("reference") == ("reference", False)
    assert select_impl("interpret") == ("pallas", True)
    assert select_impl("pallas") == ("pallas", False)
    with pytest.raises(ValueError):
        select_impl("cuda")

# ------------------------- per-token sub-scales (speculative int8 pools)
def test_token_sz_roundtrip_tighter_than_page():
    """Per-token (scale, zero) rows are a strict refinement of per-page
    blocks: the round-trip error is bounded by half the TOKEN row's step
    and never exceeds the per-page round-trip error materially."""
    page, KV, D = 16, 2, 32
    x = jax.random.normal(jax.random.PRNGKey(11), (4, page, KV, D),
                          jnp.float32)
    # one hot token per page stretches the page-level range
    x = x.at[:, 3].mul(50.0)
    q8t, szt = quant.quantize_tokens(x)
    back_t = quant.dequantize_tokens(q8t, szt)
    err_t = np.abs(np.asarray(back_t - x))
    bound_t = np.asarray(szt[..., 0])[..., None] / 2
    assert (err_t <= bound_t + 1e-6).all()
    q8p, szp = quant.quantize_pages(x)
    err_p = np.abs(np.asarray(quant.dequantize_pages(q8p, szp) - x))
    # the cold tokens next to the outlier are where per-page collapses
    assert err_t.mean() < err_p.mean()
    # all-zero rows round-trip exactly (MIN_SCALE floor, no 0/0)
    z8, zsz = quant.quantize_tokens(jnp.zeros_like(x))
    assert np.abs(np.asarray(quant.dequantize_tokens(z8, zsz))).max() == 0.0


@pytest.mark.parametrize("page", [16, 64])
def test_paged_decode_token_sz_matches_quant_oracle(page):
    """The decode kernel with PER-TOKEN sub-scales (k_sz/v_sz carrying a
    page_tokens axis) == the dequant-gather oracle, and tracks the fp
    dense oracle within a TIGHTER drift than the per-page path needs."""
    B, S, H, KV, D = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(page + 1), 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, KV, D), jnp.float32)
    v = _rand(ks[2], (B, S, KV, D), jnp.float32)
    lengths = jnp.array([(S // 2 + 17 * i) % S + 1 for i in range(B)],
                        jnp.int32)
    kp, vp, bt = _paged_layout(k, v, page, seed=page)
    k8, ksz = quant.quantize_tokens(kp)
    v8, vsz = quant.quantize_tokens(vp)
    assert ksz.shape == (kp.shape[0], page, KV, 2)
    r = dops.paged_decode_mha(q, k8, v8, bt, lengths, k_sz=ksz, v_sz=vsz,
                              impl="reference")
    p = dops.paged_decode_mha(q, k8, v8, bt, lengths, k_sz=ksz, v_sz=vsz,
                              impl="interpret")
    np.testing.assert_allclose(np.asarray(p), np.asarray(r), rtol=2e-5,
                               atol=2e-5)
    dense = dref.decode_mha(q, k, v, lengths)
    assert float(jnp.abs(p - dense).max()) < 0.05


def test_paged_prefill_token_sz_gather_matches_quant_oracle():
    """The gather-only prefill kernel with per-token sub-scales == the
    dequant-gather oracle across chunk offsets."""
    B, S, C, H, KV, D, page = 1, 256, 64, 4, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = _rand(ks[0], (B, C, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, KV, D), jnp.float32)
    v = _rand(ks[2], (B, S, KV, D), jnp.float32)
    kp, vp, bt = _paged_layout(k, v, page, seed=13)
    k8, ksz = quant.quantize_tokens(kp)
    v8, vsz = quant.quantize_tokens(vp)
    for c0 in (0, 64, S - C):
        c0v = jnp.full((B,), c0, jnp.int32)
        r = fops.paged_prefill_mha(q, k8, v8, bt, c0v, k_sz=ksz, v_sz=vsz,
                                   impl="reference")
        p = fops.paged_prefill_mha(q, k8, v8, bt, c0v, k_sz=ksz, v_sz=vsz,
                                   impl="interpret")
        np.testing.assert_allclose(np.asarray(p), np.asarray(r),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------ W8A8 int8 matmul cell
from repro.kernels.matmul_w8a8 import ops as w8ops


@pytest.mark.parametrize(
    "M_,K_,N_",
    [
        (128, 128, 128),    # exact single block
        (256, 384, 256),    # multi-block K walk (megacore grid)
        (130, 96, 200),     # ragged: every axis zero-padded to blocks
        (1, 128, 256),      # decode-like single row
    ],
)
def test_matmul_w8a8_pallas_matches_ref(M_, K_, N_):
    """The pallas W8A8 kernel (int32 VMEM accumulator, dequant epilogue
    on the last K step) == the pure-jnp int8 reference on exact and
    ragged shapes, and both track the fp matmul within the symmetric
    per-row/per-column quantization drift."""
    ka, kb = jax.random.split(jax.random.PRNGKey(M_ + K_ + N_))
    a = jax.random.normal(ka, (M_, K_), jnp.float32)
    b = jax.random.normal(kb, (K_, N_), jnp.float32) * 0.5
    a8, sa = w8ops.quantize_rows(a)             # per activation row
    b8, sb = w8ops.quantize_rows(b, axis=0)     # per weight column
    r = w8ops.matmul_w8a8(a8, b8, sa, sb, impl="reference")
    p = w8ops.matmul_w8a8(a8, b8, sa, sb, impl="interpret")
    np.testing.assert_allclose(np.asarray(p), np.asarray(r),
                               rtol=1e-6, atol=1e-6)
    fp = a @ b
    denom = max(float(jnp.abs(fp).max()), 1e-6)
    assert float(jnp.abs(p - fp).max()) / denom < 0.05


def test_matmul_w8a8_zero_operands_exact():
    """All-zero operands survive the MIN_SCALE floor exactly (no 0/0)."""
    a8, sa = w8ops.quantize_rows(jnp.zeros((64, 128), jnp.float32))
    b8, sb = w8ops.quantize_rows(jnp.zeros((128, 64), jnp.float32),
                                 axis=0)
    out = w8ops.matmul_w8a8(a8, b8, sa, sb, impl="interpret")
    assert np.abs(np.asarray(out)).max() == 0.0
