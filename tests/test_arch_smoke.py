"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.common.config import ShapeConfig, TrainConfig
from repro.common.parallel import ParallelCtx
from repro.data.synthetic import make_batch_for
from repro.launch.mesh import ctx_for_mesh
from repro.models import model as M
from repro.runtime import sharding as shd
from repro.runtime import train as train_rt

B, S = 2, 16


def _batch(cfg, steps=0):
    return make_batch_for(cfg, S, B, steps)


@pytest.mark.parametrize("arch", configs.list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = configs.reduced(arch)
    ctx = ParallelCtx(remat="none")
    params, axes = M.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    inputs = dict(batch, tokens=batch["tokens"][:, :S])
    logits, aux = M.forward(params, inputs, cfg, ctx)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.list_archs())
def test_train_step(arch, smoke_mesh):
    cfg = configs.reduced(arch)
    ctx = ctx_for_mesh(smoke_mesh, fsdp=False, remat="block")
    rules = shd.ShardingRules.for_training(None, ctx.tp_axis)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    batch = _batch(cfg)
    bundle = train_rt.make_bundle(cfg, ctx, tcfg, rules, smoke_mesh, batch,
                                  donate=False)
    state, _ = train_rt.init_train_state(cfg, jax.random.PRNGKey(1))
    new_state, metrics = bundle.step_fn(state, batch)
    assert int(new_state["step"]) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not bool(jnp.allclose(before, after))


@pytest.mark.parametrize("arch", configs.list_archs())
def test_microbatched_grad_accum_matches(arch, smoke_mesh):
    """Grad accumulation (k microbatches) must match the single-batch step."""
    cfg = configs.reduced(arch)
    if cfg.num_experts:
        pytest.skip("MoE routing is batch-composition dependent (capacity)")
    ctx = ctx_for_mesh(smoke_mesh, fsdp=False, remat="none")
    rules = shd.ShardingRules.for_training(None, ctx.tp_axis)
    batch = make_batch_for(cfg, S, 4, 0)
    state, _ = train_rt.init_train_state(cfg, jax.random.PRNGKey(1))

    outs = []
    for mb in (1, 2):
        tcfg = TrainConfig(total_steps=10, warmup_steps=2, microbatches=mb)
        bundle = train_rt.make_bundle(cfg, ctx, tcfg, rules, smoke_mesh,
                                      batch, donate=False)
        new_state, metrics = bundle.step_fn(state, batch)
        outs.append(jax.tree.leaves(new_state["params"])[0])
    assert bool(
        jnp.allclose(outs[0].astype(jnp.float32),
                     outs[1].astype(jnp.float32), atol=5e-3)
    )
