"""Architecture configs match the assignment table."""

import pytest

from repro import configs
from repro.common.config import SHAPES


def test_registry_complete():
    assert len(configs.list_archs()) == 10
    for a in configs.list_archs():
        cfg = configs.get(a)
        red = configs.reduced(a)
        assert cfg.family == red.family
        assert cfg.num_layers >= 2


SPEC = {
    # arch: (L, d_model, H, kv, vocab)
    "granite_moe_1b_a400m": (24, 1024, 16, 8, 49155),
    "kimi_k2_1t_a32b": (61, 7168, 64, 8, 163840),
    "mamba2_780m": (48, 1536, 0, 0, 50280),
    "jamba_1_5_large_398b": (72, 8192, 64, 8, 65536),
    "mistral_nemo_12b": (40, 5120, 32, 8, 131072),
    "qwen2_5_32b": (64, 5120, 40, 8, 152064),
    "smollm_360m": (32, 960, 15, 5, 49152),
    "granite_3_2b": (40, 2048, 32, 8, 49155),
    "seamless_m4t_large_v2": (24, 1024, 16, 16, 256206),
    "paligemma_3b": (18, 2048, 8, 1, 257216),
}


@pytest.mark.parametrize("arch", configs.list_archs())
def test_spec_dims(arch):
    L, d, h, kv, v = SPEC[arch]
    cfg = configs.get(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.vocab_size == v


# name-implied parameter counts (total, rtol) — sanity that the analytic
# counter and the config agree with the published sizes.
PARAMS = {
    "kimi_k2_1t_a32b": (1.04e12, 0.08),
    "mamba2_780m": (780e6, 0.15),
    "jamba_1_5_large_398b": (398e9, 0.10),
    "mistral_nemo_12b": (12.2e9, 0.10),
    "qwen2_5_32b": (32.5e9, 0.10),
    "smollm_360m": (360e6, 0.15),
    "granite_3_2b": (2.5e9, 0.25),
    "paligemma_3b": (2.5e9, 0.15),   # gemma-2b language tower of the 3B VLM
    "granite_moe_1b_a400m": (1.3e9, 0.25),
}


@pytest.mark.parametrize("arch", sorted(PARAMS))
def test_param_counts(arch):
    target, rtol = PARAMS[arch]
    n = configs.get(arch).param_count()
    assert abs(n - target) / target < rtol, (arch, n, target)


def test_active_params_kimi():
    cfg = configs.get("kimi_k2_1t_a32b")
    a = cfg.active_param_count()
    assert 25e9 < a < 40e9, a  # "a32b"
    assert a < cfg.param_count() / 10


def test_shape_cells():
    cells = configs.all_cells()
    # 10 archs x 4 shapes - 8 long_500k skips (pure-attention archs)
    assert len(cells) == 32
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"mamba2_780m", "jamba_1_5_large_398b"}


def test_shapes_table():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["decode_32k"].kind == "decode"
