"""Predictive prefetch subsystem: predictor zoo unit tests on synthetic
and adversarial streams, engine metric invariants, trace capture from all
three sources, and the BFS case-study acceptance number (slow-marked)."""

import numpy as np
import pytest

from repro.prefetch import (
    AccessTrace,
    PrefetchConfig,
    PrefetchEngine,
    TraceRecorder,
    bfs_trace,
    evaluate_zoo,
    kv_pager_trace,
    make_predictor,
    remote_reduction,
    sched_pool_trace,
)
from repro.prefetch.predictors import StaticSchedulePredictor


def _trace(steps, n_pages=1 << 20, hints=None, page_bytes=4096.0):
    return AccessTrace("t", "test", page_bytes, n_pages, steps,
                       hints=hints).validate()


def _run(steps, predictor, local=64, bw=16, degree=8, hints=None,
         n_pages=1 << 20):
    eng = PrefetchEngine(PrefetchConfig(local_pages=local,
                                        bw_pages_per_step=bw,
                                        degree=degree))
    return eng.run(_trace(steps, n_pages, hints), predictor)


# ----------------------------------------------------------- predictors
def test_stride_predictor_nails_constant_stride():
    steps = [[100 + 3 * i] for i in range(64)]
    r = _run(steps, make_predictor("stride"))
    # only the end-of-trace in-flight tail counts against accuracy
    assert r.accuracy > 0.85
    assert r.timeliness == pytest.approx(1.0)
    assert r.excess < 0.15
    # every touch after the confirmation window is covered
    assert r.demand_misses <= 4
    assert r.coverage > 0.9


def test_next_line_on_sequential_and_strided():
    seq = [[i] for i in range(64)]
    assert _run(seq, make_predictor("next_line")).coverage > 0.9
    strided = [[5 * i] for i in range(64)]
    # +1 prediction never hits a stride-5 stream with degree 4
    r = _run(strided, make_predictor("next_line"), degree=4)
    assert r.coverage == 0.0
    assert r.excess == pytest.approx(1.0)


def test_stream_predictor_untangles_interleaved_streams():
    # two interleaved sequential walks in distant regions
    steps = [[i, 1_000_000 + 2 * i] for i in range(64)]
    r = _run(steps, make_predictor("stream"))
    assert r.coverage > 0.85
    # a single-PC stride predictor sees alternating deltas and stalls
    assert _run(steps, make_predictor("stride")).coverage < 0.5


def test_markov_predictor_learns_repeating_cycle():
    # cycle longer than the local cache, so correlation (not residency)
    # must cover the touches; no positional pattern for stride to find
    cycle = [7, 3, 11, 5, 2, 19, 13, 31, 23, 41, 37, 29]
    steps = [[cycle[i % len(cycle)]] for i in range(120)]
    r = _run(steps, make_predictor("markov"), local=4, degree=2)
    assert r.accuracy > 0.9
    assert r.coverage > 0.8
    assert _run(steps, make_predictor("stride"), local=4).coverage == 0.0


def test_adversarial_random_stream_defeats_learned_predictors():
    rng = np.random.default_rng(0)
    steps = [[int(p)] for p in rng.integers(0, 1 << 16, 256)]
    for name in ("next_line", "stride", "stream", "markov"):
        r = _run(steps, make_predictor(name), local=16)
        assert r.coverage < 0.1, name
    # ... while the schedule-oracle static predictor still covers
    p = StaticSchedulePredictor([s for s in steps])
    r = _run(steps, p, local=16)
    assert r.accuracy == pytest.approx(1.0)
    assert r.coverage > 0.95


def test_static_predictor_accuracy_one_on_layer_stream():
    """The subsumed runtime/prefetch.py case: schedule fully known."""
    from repro.prefetch.static import layer_stream_trace

    t = layer_stream_trace(8, 4, epochs=3)
    eng = PrefetchEngine(PrefetchConfig(local_pages=8, bw_pages_per_step=8,
                                        degree=4))
    r = eng.run(t, make_predictor("static", schedule=t.steps))
    assert r.accuracy == pytest.approx(1.0)
    assert r.excess == pytest.approx(0.0)
    assert r.timeliness == pytest.approx(1.0)
    base = eng.run(t, make_predictor("demand"))
    assert r.remote_accesses < base.remote_accesses


def test_frontier_predictor_uses_hints_only():
    rng = np.random.default_rng(1)
    steps = [[int(p) for p in rng.integers(0, 4096, 4)] for _ in range(50)]
    hints = steps[1:] + [[]]
    with_h = _run(steps, make_predictor("frontier"), hints=hints,
                  n_pages=4096, local=32, bw=16, degree=8)
    assert with_h.accuracy == pytest.approx(1.0)
    assert with_h.coverage > 0.8
    no_h = _run(steps, make_predictor("frontier"), n_pages=4096, local=32)
    assert no_h.issued == 0


def test_make_predictor_unknown_name():
    with pytest.raises(ValueError):
        make_predictor("oracle9000")


# -------------------------------------------------------------- engine
def test_engine_metric_invariants():
    t = sched_pool_trace(3, steps=80, pages_per_job=64)
    cfg = PrefetchConfig(local_pages=24, bw_pages_per_step=8, degree=8)
    for r in evaluate_zoo(t, cfg):
        assert 0.0 <= r.accuracy <= 1.0
        assert 0.0 <= r.coverage <= 1.0
        assert 0.0 <= r.excess <= 1.0
        assert r.useful + r.late <= r.issued
        assert r.local_hits + r.demand_misses + r.late == t.touches
        assert r.remote_accesses <= t.touches
        assert r.total_time >= cfg.t_compute * t.n_steps


def test_engine_bandwidth_cap_limits_prefetch():
    # 4 new pages per step: a link that only fits the demand stream
    # leaves NO headroom to prefetch; a wider link covers everything
    steps = [[4 * i + j for j in range(4)] for i in range(32)]
    tight = _run(steps, make_predictor("next_line"), bw=4, degree=8)
    loose = _run(steps, make_predictor("next_line"), bw=12, degree=8)
    assert tight.issued == 0
    assert loose.issued > 0 and loose.coverage > 0.8
    # demand always gets link priority: the stream still completes
    assert tight.local_hits + tight.demand_misses + tight.late == 128


def test_pool_latency_makes_shallow_prefetch_late():
    """timeliness: at latency_steps=2 a depth-1 predictor is always
    correct but always late (touch stalls, transfer deduped), while a
    deep-degree predictor runs far enough ahead to stay in time."""
    steps = [[i] for i in range(64)]
    eng = PrefetchEngine(PrefetchConfig(local_pages=64,
                                        bw_pages_per_step=16, degree=1,
                                        latency_steps=2))
    shallow = eng.run(_trace(steps), make_predictor("next_line"))
    assert shallow.late > 0 and shallow.useful == 0
    assert shallow.accuracy > 0.95      # only the end-of-trace in-flight
    # page counts against it
    assert shallow.timeliness == 0.0
    assert shallow.coverage == 0.0
    # late prefetches still stall: remote accesses match demand paging
    base = eng.run(_trace(steps), make_predictor("demand"))
    assert shallow.remote_accesses == base.remote_accesses
    deep = PrefetchEngine(
        PrefetchConfig(local_pages=64, bw_pages_per_step=16, degree=8,
                       latency_steps=2)
    ).run(_trace(steps), make_predictor("next_line"))
    assert deep.timeliness > 0.9
    assert deep.coverage > 0.9
    assert deep.remote_accesses < shallow.remote_accesses


def test_demand_baseline_never_prefetches():
    t = kv_pager_trace(steps=32)
    r = PrefetchEngine(PrefetchConfig(16, 8)).run(
        t, make_predictor("demand")
    )
    assert r.issued == 0 and r.accuracy == 0.0


# ------------------------------------------------------ trace capture
def test_kv_pager_trace_shape_and_determinism():
    a = kv_pager_trace(steps=48)
    b = kv_pager_trace(steps=48)
    assert a.steps == b.steps
    assert a.n_steps == 48
    assert a.source == "serving"
    assert all(0 <= p < a.n_pages for s in a.steps for p in s)


def test_trace_recorder_roundtrip():
    rec = TraceRecorder()
    rec.record([1, 2])
    rec.record([])
    rec.record(iter([3]))
    t = rec.to_trace("x", "test", 128.0, 8)
    assert t.steps == [[1, 2], [], [3]]
    rec.record([99])                       # out of the 8-page space
    with pytest.raises(ValueError):
        rec.to_trace("x", "test", 128.0, 8)


def test_sched_pool_trace_streams_are_sequential_per_job():
    t = sched_pool_trace(2, steps=50, pages_per_job=64, seed=3)
    per_job = {0: [], 1: []}
    for s in t.steps:
        for p in s:
            per_job[p // 64].append(p % 64)
    for j, pages in per_job.items():
        assert pages, f"job {j} silent"
        deltas = np.diff(pages)
        # sequential scan with wraparound only
        assert set(np.unique(deltas)) <= {1, 1 - 64}


def test_bfs_trace_hints_are_next_step():
    b = bfs_trace(n_vertices=512, avg_degree=8, page_bytes=256, chunk=16)
    t = b.trace
    assert t.hints is not None
    assert t.hints[:-1] == t.steps[1:]
    assert t.hints[-1] == []
    assert sum(len(lv) for lv in b.levels) <= b.n_vertices


# ----------------------------------------------- BFS case study (§7.1)
@pytest.mark.slow
def test_bfs_frontier_prefetch_cuts_remote_access_40pct():
    """The paper's headline: application-directed (frontier) prefetch
    must cut remote accesses >= 40% vs demand paging at matched pool
    bandwidth (paper measures ~50%; the engine is idealized so we gate
    at the acceptance floor with slack)."""
    b = bfs_trace(n_vertices=8192, avg_degree=16, page_bytes=1024,
                  chunk=32)
    t = b.trace
    cfg = PrefetchConfig(local_pages=max(8, t.n_pages // 16),
                         bw_pages_per_step=40, degree=40)
    reports = evaluate_zoo(
        t, cfg, predictors=["demand", "next_line", "stream", "frontier"]
    )
    red = remote_reduction(reports, "frontier")
    assert red >= 0.40, f"frontier reduction {red:.2f} < 0.40"
    # and it is the APPLICATION knowledge doing it: HW-style predictors
    # stay far below the acceptance bar on the irregular frontier walk
    assert remote_reduction(reports, "next_line") < 0.20
    assert remote_reduction(reports, "stream") < 0.20
    # speedup comes with the reduction (paper: ~13%)
    base = next(r for r in reports if r.predictor == "demand")
    front = next(r for r in reports if r.predictor == "frontier")
    assert front.total_time < base.total_time


def test_excess_feedback_inflates_pool_traffic():
    from repro.core.access import TensorAccess, with_prefetch_excess

    prof = [TensorAccess("x", 1000, 1.0, "param")]
    out = with_prefetch_excess(prof, 500.0)
    assert sum(a.traffic for a in out) == 1500
    assert with_prefetch_excess(prof, 0.0) == prof


# ------------------------------------------------------------------ GHB
def test_ghb_learns_second_order_delta_pattern():
    """An alternating +1/+3 delta walk defeats the single-stride
    confirmer (it never sees the same stride twice in a row) but is a
    period-2 delta chain the GHB's two-delta index learns exactly."""
    steps, page = [], 100
    for i in range(96):
        page += 1 if i % 2 == 0 else 3
        steps.append([page])
    ghb = _run(steps, make_predictor("ghb"))
    stride = _run(steps, make_predictor("stride"))
    assert ghb.accuracy > 0.85
    assert ghb.coverage > 0.8
    assert stride.coverage < 0.2          # stride never confirms
    assert ghb.remote_accesses < stride.remote_accesses


def test_ghb_runs_delta_chain_deep():
    """predict(degree) replays the learned chain ahead, not just one
    step: on a constant stride the GHB covers like the stride
    prefetcher despite its second-order index."""
    steps = [[7 * i] for i in range(64)]
    r = _run(steps, make_predictor("ghb"), degree=4)
    assert r.accuracy > 0.85
    assert r.coverage > 0.8


def test_ghb_in_zoo_sweep_and_pager():
    """The GHB rides the shared protocol end-to-end: evaluate_zoo scores
    it by default and the serving pager accepts it as a page-in
    predictor."""
    from repro.serving import KVPager, PagerConfig

    t = _trace([[10 * i, 10 * i + 1] for i in range(48)], n_pages=1024)
    reports = evaluate_zoo(
        t, PrefetchConfig(local_pages=16, bw_pages_per_step=8, degree=4)
    )
    assert any(r.predictor == "ghb" for r in reports)
    pcfg = PagerConfig(page_tokens=8, local_budget_bytes=4 * 8 * 100.0,
                       policy="hotness", hot_window=16, cold_touch=0.1,
                       prefetch="ghb", prefetch_degree=8)
    p = KVPager(2, 400, bytes_per_token=100.0, resident_bytes=0.0,
                pcfg=pcfg)
    p.admit(0, 256)
    p.admit(1, 256)
    for _ in range(120):
        p.step(np.array([True, True]))
    c = p.counters()
    assert c["prefetch_useful"] > 0
    assert c["demand_share"] < 1.0


# ------------------------------------------------- adaptive switching
def _phased_trace():
    """Two phases with different winning predictors: a sequential walk
    (next_line/stride territory) followed by a repeating 12-page cycle
    whose deltas defeat the stride confirmer, overflow next_line's
    lookahead, and collide in the GHB's two-delta index — only the
    first-order markov table (absolute-page successors are unique)
    nails it. No fixed candidate aces both phases."""
    steps = [[1000 + i] for i in range(80)]
    cycle = [200, 210, 220, 500, 510, 520, 900, 910, 920, 40, 50, 60]
    for lap in range(8):
        steps.extend([[p] for p in cycle])
    return steps


def test_adaptive_switcher_beats_best_fixed_candidate():
    """The satellite acceptance: on a phase-changing stream the
    accuracy-tracked switcher must match or beat the best FIXED
    predictor from its own candidate set."""
    from repro.prefetch import AdaptiveSwitcher

    steps = _phased_trace()
    kw = dict(local=8, bw=4, degree=2)
    fixed = {name: _run(steps, make_predictor(name), **kw)
             for name in AdaptiveSwitcher.CANDIDATES}
    adaptive = _run(steps, make_predictor("adaptive"), **kw)
    best = min(r.remote_accesses for r in fixed.values())
    assert adaptive.remote_accesses <= best, (
        f"adaptive={adaptive.remote_accesses} vs best fixed={best} "
        f"({ {n: r.remote_accesses for n, r in fixed.items()} })")
    assert adaptive.coverage > 0.5


def test_adaptive_switcher_shadow_scores_and_switches():
    """All candidates observe and shadow-predict; only the active one's
    predictions surface. A phase flip moves the active role within one
    phase window, and the switch count records it."""
    from repro.prefetch import AdaptiveSwitcher

    sw = make_predictor("adaptive", phase_steps=8, window=32, ttl=4)
    assert isinstance(sw, AdaptiveSwitcher)
    assert sw.active == 0 and sw.switches == 0
    _run(_phased_trace(), sw, local=8, bw=4, degree=2)
    assert sw.switches >= 1
    names = [c.name for c in sw.candidates]
    assert names[sw.active] == "markov"        # phase-2 winner holds it
    accs = sw.accuracies()
    assert accs[sw.active] == max(accs)


def test_adaptive_switcher_tie_keeps_incumbent():
    """Equal windowed accuracy must not thrash the active role."""
    from repro.prefetch import AdaptiveSwitcher

    sw = AdaptiveSwitcher(phase_steps=4)
    # sequential walk: next_line (candidate 0, the incumbent) and
    # stride both reach accuracy 1 in shadow
    _run([[100 + i] for i in range(40)], sw, local=8, bw=4, degree=2)
    assert sw.candidates[sw.active].name == "next_line"
    assert sw.switches == 0


def test_adaptive_switcher_validation_and_pager_acceptance():
    from repro.prefetch import AdaptiveSwitcher
    from repro.serving import PagerConfig

    with pytest.raises(ValueError, match="candidate"):
        AdaptiveSwitcher(candidates=[])
    with pytest.raises(ValueError, match=">= 1"):
        AdaptiveSwitcher(window=0)
    # the pager accepts "adaptive" as a page-in predictor name
    PagerConfig(page_tokens=8, prefetch="adaptive")
    with pytest.raises(ValueError, match="static"):
        PagerConfig(page_tokens=8, prefetch="static")
