"""Fleet router layer: priority/cancellation queue semantics, placement
policies over hand-built EngineViews (pure, no engines), autoscaler
hysteresis, the bench-regression gate, and small end-to-end fleets that
pin down token parity with the single-engine path plus clean pager drain
after cancellations. All deterministic seeds."""

import dataclasses
import importlib.util
import json
import os

import numpy as np
import pytest

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.serving import EngineConfig, Request, RequestQueue, ServingEngine
from repro.serving.fleet import (
    AutoscaleConfig,
    Autoscaler,
    EngineView,
    FleetConfig,
    FleetRouter,
    KVLoadAwarePlacement,
    PrefixAwarePlacement,
    RoundRobinPlacement,
    kv_load_score,
    make_policy,
)
from repro.serving.queue import multi_tenant_stream, shared_prefix_stream
from repro.sched.workload import fleet_request_stream

CTX = ParallelCtx(remat="none")


def _cfg(arch="smollm_360m"):
    return dataclasses.replace(configs.reduced(arch), dtype="float32")


def _req(i, *, arrival=0.0, priority=0, prompt=4, gen=2, cancel_at=None,
         vocab=64, seed=None):
    rng = np.random.default_rng(i if seed is None else seed)
    return Request(
        request_id=i, tokens=rng.integers(0, vocab, prompt).astype(np.int32),
        max_new_tokens=gen, arrival=arrival, priority=priority,
        cancel_at=cancel_at,
    )


# ----------------------------------------------------------- queue: priority
def test_queue_priority_classes_order_within_arrived():
    """Among arrived requests the lowest priority class pops first, FIFO
    within a class; a later-arriving urgent request does NOT preempt the
    not-yet-arrived future."""
    reqs = [
        _req(0, arrival=0.0, priority=1),
        _req(1, arrival=0.1, priority=0),
        _req(2, arrival=0.2, priority=1),
        _req(3, arrival=5.0, priority=0),
    ]
    q = RequestQueue(reqs)
    order = [q.pop(1.0).request_id for _ in range(3)]
    assert order == [1, 0, 2]       # priority 0 first, then FIFO in class 1
    assert q.pop(1.0) is None       # request 3 hasn't arrived
    assert q.pop(5.0).request_id == 3


def test_queue_single_class_is_plain_fifo():
    """One priority class must replay the pre-priority FIFO exactly."""
    arrivals = [0.3, 0.1, 0.7, 0.2, 0.5]
    reqs = [_req(i, arrival=a) for i, a in enumerate(arrivals)]
    q = RequestQueue(reqs)
    got = []
    while len(q):
        got.append(q.pop(10.0).arrival)
    assert got == sorted(arrivals)


def test_queue_drops_cancelled_and_counts():
    """Cancelled requests are never handed out: eager cancellation drops
    at absorb, a `cancel_at` deadline drops once `now` passes it."""
    eager = _req(0, arrival=0.0)
    eager.cancel()
    deadline = _req(1, arrival=0.0, cancel_at=2.0)
    live = _req(2, arrival=0.0)
    q = RequestQueue([eager, deadline, live])
    assert q.pop(1.0).request_id == 1      # deadline not reached yet
    assert q.drop_cancelled == 1           # the eager one
    q2 = RequestQueue([_req(3, arrival=0.0, cancel_at=2.0), live])
    got = q2.pop(3.0)                      # now past the deadline
    assert got.request_id == 2
    assert q2.drop_cancelled == 1
    assert q.pop(1.0).request_id == 2


# ----------------------------------------------------- placement: round robin
def _view(eid, *, busy=0, queued=0, free=10, total=10, role="unified",
          accepting=True, queued_cost=None, busy_cost=None, slots=2):
    return EngineView(
        engine_id=eid, n_slots=slots, busy=busy, queued=queued,
        free_pages=free, total_pages=total, role=role, accepting=accepting,
        queued_cost=queued_cost, busy_cost=busy_cost,
    )


def test_round_robin_cycles_and_is_deterministic():
    views = [_view(0), _view(1), _view(2)]
    toks = [1, 2, 3, 4]
    p = RoundRobinPlacement()
    got = []
    for _ in range(6):
        e = p.place(views, toks)
        p.record(e, toks)
        got.append(e)
    assert got == [0, 1, 2, 0, 1, 2]
    # a second policy instance replays the identical sequence
    p2 = RoundRobinPlacement()
    got2 = []
    for _ in range(6):
        e = p2.place(views, toks)
        p2.record(e, toks)
        got2.append(e)
    assert got2 == got


def test_round_robin_empty_views_raises():
    with pytest.raises(ValueError):
        RoundRobinPlacement().place([], [1])


# ------------------------------------------------------ placement: kv-aware
def test_kv_aware_picks_lowest_outstanding_token_cost():
    """Token-cost scoring: an engine with one queued 96-token batch job
    is MORE loaded than one with two queued 10-token chats, even though
    its request count is lower."""
    heavy = _view(0, queued=1, queued_cost=96.0, busy_cost=0.0)
    light = _view(1, queued=2, queued_cost=20.0, busy_cost=0.0)
    p = KVLoadAwarePlacement()
    assert p.place([heavy, light], [1, 2]) == 1
    # count-based fallback (no costs supplied) would pick the other way
    heavy_n = _view(0, queued=1)
    light_n = _view(1, queued=2)
    assert p.place([heavy_n, light_n], [1, 2]) == 0


def test_kv_aware_pool_pressure_breaks_load_ties():
    """Equal outstanding load: the engine with more free pool pages wins
    (free_frac enters the score at half weight)."""
    tight = _view(0, queued_cost=0.0, busy_cost=0.0, free=2, total=10)
    roomy = _view(1, queued_cost=0.0, busy_cost=0.0, free=9, total=10)
    assert KVLoadAwarePlacement().place([tight, roomy], [1]) == 1
    assert kv_load_score(roomy) < kv_load_score(tight)


def test_kv_aware_deterministic_tie_break_on_engine_id():
    a, b = _view(0), _view(1)
    assert kv_load_score(a) == kv_load_score(b)
    assert KVLoadAwarePlacement().place([b, a], [1]) == 0


# --------------------------------------------------- placement: prefix-aware
def test_prefix_aware_steers_recorded_block_prefixes():
    p = PrefixAwarePlacement(page_tokens=4)
    sys_prompt = list(range(8))                 # two full pages
    p.record(1, sys_prompt + [20, 21, 22, 23])
    views = [_view(0), _view(1)]
    # same two-page system prefix, different tail -> steered to engine 1
    assert p.place(views, sys_prompt + [30, 31, 32, 33]) == 1
    assert p.steered == 1 and p.cold == 0
    # unrelated prompt -> cold fallback (kv-aware, ties to engine 0)
    assert p.place(views, [99] * 8) == 0
    assert p.cold == 1


def test_prefix_aware_longest_prefix_wins():
    p = PrefixAwarePlacement(page_tokens=2)
    # record order matters: the later record owns every path it inserts
    # (latest writer wins), so register the deep path first and let the
    # shallow one reclaim the one-block entry
    p.record(1, [1, 2, 3, 4])                   # blocks (1,2),(3,4) -> 1
    p.record(0, [1, 2])                         # one-block path -> engine 0
    views = [_view(0), _view(1)]
    assert p.place(views, [1, 2, 3, 4, 9, 9]) == 1    # deepest match
    assert p.place(views, [1, 2, 8, 8]) == 0          # only block 1 matches
    owner, matched = p.lookup([1, 2, 3, 4])
    assert (owner, matched) == (1, 2)


def test_prefix_aware_ineligible_owner_falls_back():
    """The indexed owner is draining (not in the eligible views): the
    request must fall back to kv-aware placement, not crash or steer to
    a non-eligible engine."""
    p = PrefixAwarePlacement(page_tokens=2)
    p.record(1, [1, 2, 3, 4])
    only0 = [_view(0)]
    assert p.place(only0, [1, 2, 3, 4]) == 0
    assert p.cold == 1


def test_prefix_aware_sub_page_prompt_is_cold():
    p = PrefixAwarePlacement(page_tokens=8)
    p.record(1, [1, 2, 3])                      # < one page: nothing indexed
    assert p.lookup([1, 2, 3]) == (None, 0)


def test_make_policy_names_and_validation():
    assert make_policy("round_robin").name == "round_robin"
    assert make_policy("kv_aware").name == "kv_aware"
    pa = make_policy("prefix_aware", page_tokens=4)
    assert pa.name == "prefix_aware" and pa.page_tokens == 4
    with pytest.raises(ValueError):
        make_policy("least_recently_invented")
    with pytest.raises(ValueError):
        PrefixAwarePlacement(page_tokens=0)


# ------------------------------------------------------------- autoscaler
def test_autoscaler_up_needs_patience_then_cooldown():
    cfg = AutoscaleConfig(min_engines=1, max_engines=3, up_patience=2,
                          down_patience=2, cooldown=2)
    a = Autoscaler(cfg)
    assert a.observe(2.0, 1) == 0           # first high observation
    assert a.observe(2.0, 1) == +1          # patience met
    assert a.observe(2.0, 2) == 0           # cooldown
    assert a.observe(2.0, 2) == 0           # cooldown
    # streak kept building through cooldown: next observation can fire
    assert a.observe(2.0, 2) == +1
    assert a.ups == 2


def test_autoscaler_down_patience_and_min_clamp():
    cfg = AutoscaleConfig(min_engines=1, max_engines=3, up_patience=1,
                          down_patience=3, cooldown=0)
    a = Autoscaler(cfg)
    assert [a.observe(0.0, 2) for _ in range(2)] == [0, 0]
    assert a.observe(0.0, 2) == -1          # third consecutive low
    # at the floor: keeps recommending 0 no matter how idle
    for _ in range(6):
        assert a.observe(0.0, 1) == 0
    assert a.downs == 1


def test_autoscaler_midband_resets_streaks():
    cfg = AutoscaleConfig(min_engines=1, max_engines=2, up_patience=2,
                          down_patience=2, cooldown=0)
    a = Autoscaler(cfg)
    assert a.observe(2.0, 1) == 0
    assert a.observe(0.8, 1) == 0           # mid-band: streak resets
    assert a.observe(2.0, 1) == 0           # must re-earn the patience
    assert a.observe(2.0, 1) == +1


def test_autoscaler_max_clamp():
    cfg = AutoscaleConfig(min_engines=1, max_engines=2, up_patience=1,
                          down_patience=1, cooldown=0)
    a = Autoscaler(cfg)
    assert a.observe(5.0, 2) == 0           # already at the ceiling


def test_autoscale_config_validation():
    with pytest.raises(ValueError):
        AutoscaleConfig(min_engines=3, max_engines=2)
    with pytest.raises(ValueError):
        AutoscaleConfig(high_watermark=0.2, low_watermark=0.5)


# ----------------------------------------------------- fleet config contract
def test_fleet_config_validation():
    with pytest.raises(ValueError):
        FleetConfig(n_engines=0)
    with pytest.raises(ValueError):
        FleetConfig(n_engines=1, roles=True)
    with pytest.raises(ValueError):
        FleetConfig(n_engines=2, roles=True,
                    autoscale=AutoscaleConfig(max_engines=2))
    with pytest.raises(ValueError):
        FleetConfig(n_engines=2, autoscale=AutoscaleConfig(max_engines=4))


# ------------------------------------------------------ bench gate (script)
def _load_check_bench():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "scripts", "check_bench.py")
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write_bench(d, fname, rows):
    with open(os.path.join(d, fname), "w") as f:
        json.dump({"tag": "serve", "module": "x", "rows": rows}, f)


def test_check_bench_catches_pool_bytes_regression(tmp_path):
    """The gate's reason to exist: a 2x pool_bytes_per_token regression
    must fail, an identical re-run must pass."""
    cb = _load_check_bench()
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    rules = [("BENCH_serve.json", "serve_chat", "pool_bytes_per_token",
              "rel_max", 1.10)]
    _write_bench(base, "BENCH_serve.json",
                 [{"tag": "serve_chat", "pool_bytes_per_token": 320.0}])
    _write_bench(fresh, "BENCH_serve.json",
                 [{"tag": "serve_chat", "pool_bytes_per_token": 640.0}])
    fails = cb.check(str(fresh), str(base), rules=rules)
    assert len(fails) == 1 and "pool_bytes_per_token" in fails[0]
    _write_bench(fresh, "BENCH_serve.json",
                 [{"tag": "serve_chat", "pool_bytes_per_token": 320.0}])
    assert cb.check(str(fresh), str(base), rules=rules) == []


def test_check_bench_rule_types(tmp_path):
    cb = _load_check_bench()
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    _write_bench(base, "BENCH_x.json",
                 [{"tag": "t", "tput": 100.0, "ratio": 0.5}])
    _write_bench(fresh, "BENCH_x.json",
                 [{"tag": "t", "tput": 80.0, "ratio": 1.2}])
    # rel_min: 80 < 100*0.9 fails; abs_max: 1.2 > 1.0 fails
    fails = cb.check(str(fresh), str(base), rules=[
        ("BENCH_x.json", "t", "tput", "rel_min", 0.90),
        ("BENCH_x.json", "t", "ratio", "abs_max", 1.00),
    ])
    assert len(fails) == 2


def test_check_bench_missing_metric_is_an_error(tmp_path):
    """A silently renamed/dropped metric must fail the gate, while a
    wholly absent file (new bench, no baseline yet) is only skipped."""
    cb = _load_check_bench()
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    _write_bench(base, "BENCH_x.json", [{"tag": "t", "old_name": 1.0}])
    _write_bench(fresh, "BENCH_x.json", [{"tag": "t", "new_name": 1.0}])
    fails = cb.check(str(fresh), str(base), rules=[
        ("BENCH_x.json", "t", "old_name", "rel_max", 1.1),
        ("BENCH_nope.json", "t", "m", "rel_max", 1.1),   # missing file
    ])
    assert len(fails) == 1 and "missing" in fails[0]


def test_check_bench_default_rules_reference_real_artifacts():
    """Every default rule must point at a committed baseline file, and
    the (tag, metric) pair must exist in it — a rule that can never
    fire is a hole in the gate."""
    cb = _load_check_bench()
    base_dir = os.path.join(os.path.dirname(__file__), os.pardir,
                            "benchmarks", "baselines")
    for fname, tag, metric, rule, tol in cb.RULES:
        path = os.path.join(base_dir, fname)
        assert os.path.exists(path), f"no committed baseline {fname}"
        rows = cb.load_rows(path)
        assert tag in rows, f"{fname} has no row tagged {tag!r}"
        if rule != "abs_max":
            assert metric in rows[tag], f"{fname}:{tag} lacks {metric!r}"


def test_check_bench_warns_on_unreferenced_metrics(tmp_path, capsys):
    """The visibility pass: a baseline metric no rule references gets
    exactly one non-fatal WARN line per file — and a fully-referenced
    file stays silent."""
    cb = _load_check_bench()
    base = tmp_path / "base"
    base.mkdir()
    _write_bench(base, "BENCH_x.json",
                 [{"tag": "t", "gated": 1.0, "loose_a": 2.0,
                   "loose_b": 3.0, "flag": True, "note": "text"}])
    _write_bench(base, "BENCH_y.json", [{"tag": "u", "gated": 1.0}])
    rules = [("BENCH_x.json", "t", "gated", "rel_max", 1.1),
             ("BENCH_y.json", "u", "gated", "rel_max", 1.1)]
    cb.warn_unreferenced(str(base), rules=rules)
    out = capsys.readouterr().out
    warns = [ln for ln in out.splitlines() if ln.startswith("WARN")]
    assert len(warns) == 1 and "BENCH_x.json" in warns[0]
    # bools and strings are not driftable numbers — only the two loose
    # floats count, and both are named for grepping
    assert "2 baseline metric(s)" in warns[0]
    assert "t.loose_a" in warns[0] and "t.loose_b" in warns[0]
    assert "BENCH_y.json" not in out


# ----------------------------------------------------------- fleet e2e (fast)
def _small_ecfg(**kw):
    base = dict(n_slots=2, max_seq=14, prefill_buckets=(8,), page_tokens=4,
                hot_window=8, local_budget_frac=0.5, admission="greedy")
    base.update(kw)
    return EngineConfig(**base)


def _clone_engines(first, cfg, ecfg, n):
    """Fresh engines over the first engine's compiled cells + params —
    per-fleet pools without per-fleet compilation."""
    return [ServingEngine(cfg, CTX, ecfg, first.params, first.cells)
            for _ in range(n)]


def _stream(cfg, n, gen=4, seed=11):
    rng = np.random.default_rng(seed)
    return [
        Request(request_id=i,
                tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=gen, arrival=0.05 * i)
        for i in range(n)
    ]


def test_fleet_round_robin_matches_single_engine():
    """Placement must be invisible to greedy tokens: a 2-engine
    round-robin fleet replays the single engine's output streams
    bit-for-bit, with both engines actually routed work."""
    cfg = _cfg()
    ecfg = _small_ecfg()
    eng = ServingEngine.build(cfg, CTX, ecfg)
    solo = _stream(cfg, 6)
    eng.run(solo)

    router = FleetRouter(
        _clone_engines(eng, cfg, ecfg, 2),
        FleetConfig(n_engines=2, policy="round_robin"),
    )
    fleet = _stream(cfg, 6)
    stats = router.run(fleet)
    assert [r.output for r in fleet] == [r.output for r in solo]
    assert stats.n_requests == 6
    assert min(stats.routed) > 0            # actually spread over engines
    assert stats.tokens == sum(len(r.output) for r in solo)


def test_fleet_cancellation_releases_pages():
    """Cancelled requests — both queued-then-dropped and swept while
    decoding — must hand every KV page back: each engine's pool drains
    to fully free with zero refcounts."""
    cfg = _cfg()
    ecfg = _small_ecfg()
    eng = ServingEngine.build(cfg, CTX, ecfg)
    reqs = _stream(cfg, 6, gen=6)
    reqs[1].cancel()                        # dropped at the queue
    reqs[3].cancel_at = reqs[3].arrival + 1e-5   # swept mid-flight
    reqs[4].cancel_at = reqs[4].arrival + 1e-5
    router = FleetRouter(
        _clone_engines(eng, cfg, ecfg, 2),
        FleetConfig(n_engines=2, policy="kv_aware"),
    )
    stats = router.run(reqs)
    assert stats.cancelled == 3
    assert not reqs[1].output               # never served
    for h in router.handles:
        pager = h.engine.pager
        assert pager.counters()["free_pages"] == pager.n_phys
        assert (pager.ref == 0).all()
    # the untouched survivors finished normally (swept requests may keep
    # a partial output — that's fine, their pages are what we checked)
    survivors = [reqs[0], reqs[2], reqs[5]]
    assert all(len(r.output) == r.max_new_tokens for r in survivors)


def test_fleet_priority_orders_coarrived_classes():
    """Two requests arriving together on one engine: the priority-0
    request must be admitted no later than the priority-1 one."""
    cfg = _cfg()
    ecfg = _small_ecfg(n_slots=1)           # force serialization
    eng = ServingEngine.build(cfg, CTX, ecfg)
    rng = np.random.default_rng(0)
    lo = Request(request_id=0,
                 tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                 max_new_tokens=2, arrival=0.0, priority=1)
    hi = Request(request_id=1,
                 tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                 max_new_tokens=2, arrival=0.0, priority=0)
    router = FleetRouter([ServingEngine(cfg, CTX, ecfg, eng.params,
                                        eng.cells)],
                         FleetConfig(n_engines=1))
    router.run([lo, hi])
    assert hi.admitted <= lo.admitted
    assert hi.output and lo.output


def test_fleet_streams_are_deterministic():
    a = fleet_request_stream(12, 64, seed=9, cancel_fraction=0.25)
    b = fleet_request_stream(12, 64, seed=9, cancel_fraction=0.25)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert [r.priority for r in a] == [r.priority for r in b]
    assert [r.cancel_at for r in a] == [r.cancel_at for r in b]
    assert all((x.tokens == y.tokens).all() for x, y in zip(a, b))
    assert {r.tenant for r in a} == {"interactive", "batch"}
    assert sum(r.cancel_at is not None for r in a) > 0
    mt = multi_tenant_stream(10, 64, seed=2)
    assert len({r.request_id for r in mt}) == 10


# ------------------------------------------------------------ e2e (slow lane)
@pytest.mark.slow
def test_fleet_prefix_aware_beats_round_robin_hit_rate():
    """The router-side radix index must lift the aggregate prefix hit
    rate over round-robin on a shared-prefix stream, at token parity."""
    cfg = _cfg()
    ecfg = EngineConfig(
        n_slots=2, max_seq=36, prefill_buckets=(32,), page_tokens=4,
        hot_window=16, local_budget_frac=0.5, admission="greedy",
        prefix_cache=True,
    )
    eng = ServingEngine.build(cfg, CTX, ecfg)
    hits, outs = {}, {}
    for policy in ("round_robin", "prefix_aware"):
        router = FleetRouter(
            _clone_engines(eng, cfg, ecfg, 2),
            FleetConfig(n_engines=2, policy=policy),
        )
        reqs = shared_prefix_stream(
            12, cfg.vocab_size, seed=3, system_tokens=24,
            prompt_buckets=(32,), gen_range=(4, 4), arrival_rate=4e4,
            n_systems=2,
        )
        stats = router.run(reqs)
        hits[policy] = stats.prefix["hit_rate"]
        outs[policy] = [r.output for r in reqs]
    assert outs["round_robin"] == outs["prefix_aware"]
    assert hits["prefix_aware"] > hits["round_robin"]


@pytest.mark.slow
def test_fleet_roles_handoff_token_parity():
    """Disaggregated prefill/decode: every request prefills on engine 0,
    transfers its pages, decodes on engine 1 — and the tokens match the
    unified single engine exactly."""
    cfg = _cfg()
    ecfg = _small_ecfg(max_seq=16, prefill_chunk=4)
    eng = ServingEngine.build(cfg, CTX, ecfg)
    solo = _stream(cfg, 4, gen=4)
    eng.run(solo)

    router = FleetRouter(
        _clone_engines(eng, cfg, ecfg, 2),
        FleetConfig(n_engines=2, policy="round_robin", roles=True),
    )
    fleet = _stream(cfg, 4, gen=4)
    stats = router.run(fleet)
    assert [r.output for r in fleet] == [r.output for r in solo]
    assert stats.transfers["transfers"] == 4
    assert stats.transfers["pages"] > 0
    for h in router.handles:
        assert h.engine.pager.counters()["free_pages"] \
            == h.engine.pager.n_phys


@pytest.mark.slow
def test_fleet_autoscale_scales_up_under_burst():
    """A burst deeper than one engine's slots must activate a parked
    engine (scale event), and the drained fleet still serves everything."""
    cfg = _cfg()
    ecfg = _small_ecfg()
    eng = ServingEngine.build(cfg, CTX, ecfg)
    acfg = AutoscaleConfig(min_engines=1, max_engines=2, high_watermark=1.0,
                           low_watermark=0.1, up_patience=1, down_patience=50,
                           cooldown=0)
    router = FleetRouter(
        _clone_engines(eng, cfg, ecfg, 2),
        FleetConfig(n_engines=2, policy="kv_aware", autoscale=acfg),
    )
    reqs = _stream(cfg, 8, gen=3)
    for i, r in enumerate(reqs):
        # stagger at decode-step scale: the queue must build up over
        # several routing epochs (a single co-arrival burst would be
        # fully routed BEFORE the scale event can matter)
        r.arrival = 1e-5 * i
    stats = router.run(reqs)
    assert stats.n_requests == 8
    assert any(d == +1 for _, d, _n in stats.scale_events)
    assert stats.routed[1] > 0              # the activated engine served


# ------------------------------------------------- fault tolerance (PR 10)
from repro.serving import FaultPlan, make_plan     # noqa: E402


def test_queue_requeue_preserves_priority_original_arrival():
    """Fault recovery drains a dead engine's queue and re-routes it; the
    destination queue must re-admit in (priority, ORIGINAL arrival)
    order — requeued work neither jumps the line nor loses its place."""
    reqs = [
        _req(0, arrival=0.3, priority=1),
        _req(1, arrival=0.1, priority=0),
        _req(2, arrival=0.2, priority=1),
        _req(3, arrival=0.4, priority=0),
        _req(4, arrival=9.0, priority=0),    # not yet arrived
    ]
    q = RequestQueue(reqs)
    assert q.peek(1.0).request_id == 1       # absorb the arrived four
    moved = q.drain()
    # ready set in (priority, original arrival), then the future feed
    assert [r.request_id for r in moved] == [1, 3, 2, 0, 4]
    assert len(q) == 0
    # re-admission on the destination replays the same order even though
    # the requests are pushed post-arrival (absorb time is NOT the key)
    q2 = RequestQueue()
    for r in moved:
        q2.push(r)
    got = [q2.pop(10.0).request_id for _ in range(5)]
    assert got == [1, 3, 4, 2, 0]            # (priority, original arrival)
    # single class: requeue keeps plain arrival FIFO bit-identical
    fifo = [_req(i, arrival=a) for i, a in enumerate([0.5, 0.2, 0.9, 0.1])]
    q3 = RequestQueue(fifo)
    q3.peek(1.0)
    q4 = RequestQueue()
    for r in q3.drain():
        q4.push(r)
    assert [q4.pop(1.0).arrival for _ in range(4)] == [0.1, 0.2, 0.5, 0.9]


def test_fleet_config_rejects_roles_with_kill_faults():
    """Chunked prefill-role engines cannot replay a migrated request
    (adopt needs the bucketed prefill cell), so kill/stall plans are
    rejected up front; pure transfer flaking stays allowed."""
    kill = FaultPlan(seed=0, kill_engine=1, kill_at_step=2)
    with pytest.raises(ValueError, match="role split"):
        FleetConfig(n_engines=2, roles=True, faults=kill)
    with pytest.raises(ValueError, match="watchdog"):
        FleetConfig(n_engines=2, watchdog_s=0.0)
    FleetConfig(n_engines=2, roles=True,
                faults=FaultPlan(seed=0, transfer_fail_rate=0.25))


def test_fault_plan_registry_and_determinism():
    """Named plans resolve; per-site Philox streams are deterministic
    and independent across sites (one site's draws never shift
    another's)."""
    from repro.serving.faults import FaultInjector
    plan = make_plan("transfer_flake")
    a = FaultInjector(plan)
    b = FaultInjector(plan)
    seq_a = [a.transfer_fails("substrate/page_in") for _ in range(40)]
    # interleave draws on ANOTHER site: page_in's sequence must not move
    seq_b = []
    for _ in range(40):
        b.transfer_fails("substrate/page_out")
        seq_b.append(b.transfer_fails("substrate/page_in"))
    assert seq_a == seq_b
    assert any(seq_a)                        # 0.25 rate over 40 draws
    assert not make_plan("none").active
    with pytest.raises(ValueError):
        make_plan("earthquake")


def test_fleet_chaos_kill_bit_parity():
    """THE headline contract: a 2-engine fp-pool fleet with engine 1
    killed mid-decode and 10% substrate transfer flaking emits
    BIT-IDENTICAL token streams to the fault-free fleet — recovery
    re-routes the dead engine's queue and re-adopts its in-flight slots
    by teacher-forced refill — and both pools drain exactly free with
    placement ledgers empty. The whole chaos run replays exactly."""
    cfg = _cfg()
    ecfg = _small_ecfg(pool_dtype="fp")
    eng = ServingEngine.build(cfg, CTX, ecfg)

    clean = _stream(cfg, 6, gen=6)
    FleetRouter(_clone_engines(eng, cfg, ecfg, 2),
                FleetConfig(n_engines=2, policy="round_robin")).run(clean)

    plan = FaultPlan(seed=0, transfer_fail_rate=0.10,
                     kill_engine=1, kill_at_step=3)
    outs, counters = [], []
    for _ in range(2):                       # exact replayability
        chaos = _stream(cfg, 6, gen=6)
        router = FleetRouter(
            _clone_engines(eng, cfg, ecfg, 2),
            FleetConfig(n_engines=2, policy="round_robin", faults=plan),
        )
        stats = router.run(chaos)
        outs.append([r.output for r in chaos])
        counters.append(stats.faults)
        assert router.handles[1].dead
        for h in router.handles:
            p = h.engine.pager
            assert p.counters()["free_pages"] == p.n_phys
            assert (p.ref == 0).all() and p.pins == 0
            sub = h.engine.substrate
            if sub is not None:
                assert p.pool_bytes_used() == sub.ledger.placement_bytes()
    assert outs[0] == [r.output for r in clean]      # bit parity
    assert outs[1] == outs[0]
    assert counters[1] == counters[0]
    f = counters[0]
    assert f["engines_killed"] == 1 and f["recoveries"] == 1
    assert f["restores"] >= 1 and f["reprefilled_tokens"] > 0
    assert f["retries"] >= 1 and f["retry_bytes"] > 0
    s = stats.summary()
    assert s["engines_killed"] == 1
    assert s["recovery_overhead_tokens"] == f["reprefilled_tokens"]


def test_fleet_transfer_flake_retry_accounting():
    """Pure link flaking (no kill): tokens stay bit-identical, every
    failed attempt shows up as retry bytes in the substrate ledgers
    (moved, placement unchanged), and nothing dies."""
    cfg = _cfg()
    ecfg = _small_ecfg(pool_dtype="fp")
    eng = ServingEngine.build(cfg, CTX, ecfg)
    clean = _stream(cfg, 6, gen=6)
    FleetRouter(_clone_engines(eng, cfg, ecfg, 2),
                FleetConfig(n_engines=2, policy="round_robin")).run(clean)

    flaky = _stream(cfg, 6, gen=6)
    router = FleetRouter(
        _clone_engines(eng, cfg, ecfg, 2),
        FleetConfig(n_engines=2, policy="round_robin",
                    faults=make_plan("transfer_flake")),
    )
    stats = router.run(flaky)
    assert [r.output for r in flaky] == [r.output for r in clean]
    assert stats.faults["engines_killed"] == 0
    assert stats.faults["retries"] >= 1
    assert stats.faults["retry_bytes"] > 0
    assert stats.faults["backoff_s"] > 0
    for h in router.handles:
        p = h.engine.pager
        assert p.counters()["free_pages"] == p.n_phys
        sub = h.engine.substrate
        if sub is not None:
            c = sub.ledger.counters()
            assert c["retry_bytes"] == pytest.approx(
                sub.retry_bytes)
            assert p.pool_bytes_used() == sub.ledger.placement_bytes()


def test_fleet_watchdog_recovers_stalled_engine():
    """A stall longer than the watchdog is indistinguishable from death:
    the router evacuates the wedged engine and the fleet still serves
    every request with bit-identical tokens."""
    cfg = _cfg()
    ecfg = _small_ecfg(pool_dtype="fp")
    eng = ServingEngine.build(cfg, CTX, ecfg)
    clean = _stream(cfg, 6, gen=4)
    FleetRouter(_clone_engines(eng, cfg, ecfg, 2),
                FleetConfig(n_engines=2, policy="round_robin")).run(clean)

    stalled = _stream(cfg, 6, gen=4)
    router = FleetRouter(
        _clone_engines(eng, cfg, ecfg, 2),
        FleetConfig(n_engines=2, policy="round_robin", watchdog_s=1e-3,
                    faults=FaultPlan(seed=0, stall_engine=1,
                                     stall_at_step=2, stall_s=1.0)),
    )
    stats = router.run(stalled)
    assert [r.output for r in stalled] == [r.output for r in clean]
    assert stats.faults["engines_killed"] == 1
    assert all(len(r.output) == r.max_new_tokens for r in stalled)


def test_fleet_autoscale_drain_frees_pools_immediately():
    """A scale-down drains the victim through the fault layer's
    migration path right AT the event — queued work re-routes with its
    original arrivals instead of tapering off — and the parked engine's
    pool is verified fully free. Token streams still match the
    unconstrained fleet bit-for-bit (greedy tokens are placement- and
    evacuation-invariant)."""
    cfg = _cfg()
    ecfg = _small_ecfg(pool_dtype="fp")
    eng = ServingEngine.build(cfg, CTX, ecfg)

    def _trace():
        reqs = _stream(cfg, 8, gen=4)
        for i, r in enumerate(reqs):
            r.arrival = 1e-5 * i             # burst: drives the scale-up
        reqs += [r for r in _stream(cfg, 10, gen=4)[8:]]
        reqs[8].arrival, reqs[9].arrival = 0.02, 0.05   # quiet tail:
        return reqs                          # drives the scale-down

    clean = _trace()
    FleetRouter(_clone_engines(eng, cfg, ecfg, 2),
                FleetConfig(n_engines=2, policy="round_robin")).run(clean)

    acfg = AutoscaleConfig(min_engines=1, max_engines=2,
                           high_watermark=1.0, low_watermark=0.5,
                           up_patience=1, down_patience=1, cooldown=0)
    drained = _trace()
    router = FleetRouter(
        _clone_engines(eng, cfg, ecfg, 2),
        FleetConfig(n_engines=2, policy="round_robin", autoscale=acfg),
    )
    stats = router.run(drained)
    assert any(d == +1 for _, d, _n in stats.scale_events)
    assert any(d == -1 for _, d, _n in stats.scale_events)
    assert [r.output for r in drained] == [r.output for r in clean]
    assert all(len(r.output) == r.max_new_tokens for r in drained)
    victim = router.handles[1]               # highest-id accepting drains
    assert not victim.accepting and not victim.dead
    p = victim.engine.pager
    assert p.counters()["free_pages"] == p.n_phys
    assert (p.ref == 0).all() and p.pins == 0
