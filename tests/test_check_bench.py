"""Bench-regression gate hardening: every mishap the gate can meet —
missing file, corrupt json, missing metric, non-numeric metric — must
come back as a SKIP or a one-line failure string, never a traceback."""

import json
import sys

import pytest

sys.path.insert(0, "scripts")
import check_bench  # noqa: E402


RULE_MAX = ("BENCH_x.json", "row", "metric", "rel_max", 1.10)
RULE_MIN = ("BENCH_x.json", "row", "metric", "rel_min", 0.90)
RULE_ABS = ("BENCH_x.json", "row", "metric", "abs_max", 2.0)


def _write(d, payload, name="BENCH_x.json"):
    p = d / name
    p.write_text(payload if isinstance(payload, str)
                 else json.dumps(payload))
    return d


def _dirs(tmp_path, fresh_payload, base_payload):
    fresh = tmp_path / "fresh"
    base = tmp_path / "base"
    fresh.mkdir(parents=True)
    base.mkdir(parents=True)
    if fresh_payload is not None:
        _write(fresh, fresh_payload)
    if base_payload is not None:
        _write(base, base_payload)
    return str(fresh), str(base)


def _rows(val):
    return {"rows": [{"tag": "row", "metric": val}]}


def test_gate_passes_within_tolerance(tmp_path, capsys):
    fresh, base = _dirs(tmp_path, _rows(1.05), _rows(1.0))
    assert check_bench.check(fresh, base, [RULE_MAX]) == []
    assert "OK" in capsys.readouterr().out


def test_gate_fails_outside_tolerance(tmp_path):
    fresh, base = _dirs(tmp_path, _rows(0.5), _rows(1.0))
    fails = check_bench.check(fresh, base, [RULE_MIN])
    assert len(fails) == 1 and "rel_min" in fails[0]


def test_missing_file_skips_with_warning(tmp_path, capsys):
    for fresh_p, base_p, who in [(None, _rows(1.0), "fresh"),
                                 (_rows(1.0), None, "baseline")]:
        fresh, base = _dirs(tmp_path / who, fresh_p, base_p)
        assert check_bench.check(fresh, base, [RULE_MAX]) == []
        out = capsys.readouterr().out
        assert f"SKIP" in out and f"({who} file missing)" in out


def test_missing_fresh_metric_is_clear_failure(tmp_path):
    fresh, base = _dirs(tmp_path, {"rows": [{"tag": "row"}]}, _rows(1.0))
    fails = check_bench.check(fresh, base, [RULE_MAX])
    assert len(fails) == 1
    assert "missing row.metric" in fails[0]
    assert "renamed or dropped" in fails[0]


def test_missing_baseline_metric_is_clear_failure(tmp_path):
    fresh, base = _dirs(tmp_path, _rows(1.0), {"rows": []})
    fails = check_bench.check(fresh, base, [RULE_MAX])
    assert len(fails) == 1 and "regenerate" in fails[0]
    # abs_max rules never consult the baseline: same dirs must pass
    assert check_bench.check(fresh, base, [RULE_ABS]) == []


def test_corrupt_fresh_file_is_failure_not_traceback(tmp_path):
    fresh, base = _dirs(tmp_path, "{not json", _rows(1.0))
    fails = check_bench.check(fresh, base, [RULE_MAX, RULE_ABS])
    # one failure per unreadable FILE, not per rule
    assert len(fails) == 1 and "unreadable" in fails[0]


def test_wrong_toplevel_shape_is_failure(tmp_path):
    fresh, base = _dirs(tmp_path, _rows(1.0), "[1, 2]")
    fails = check_bench.check(fresh, base, [RULE_MAX])
    assert len(fails) == 1 and "unreadable" in fails[0]


@pytest.mark.parametrize("bad", ["fast", None, True, [1], {"x": 1}])
def test_non_numeric_metric_is_clear_failure(tmp_path, bad):
    fresh, base = _dirs(tmp_path, _rows(bad), _rows(1.0))
    fails = check_bench.check(fresh, base, [RULE_MAX])
    assert len(fails) == 1
    assert "not numeric" in fails[0] and repr(bad) in fails[0]


def test_non_numeric_baseline_metric_is_clear_failure(tmp_path):
    fresh, base = _dirs(tmp_path, _rows(1.0), _rows("n/a"))
    fails = check_bench.check(fresh, base, [RULE_MIN])
    assert len(fails) == 1 and "baseline" in fails[0]


def test_main_exit_codes(tmp_path, monkeypatch):
    fresh, base = _dirs(tmp_path, _rows(1.0), _rows(1.0))
    monkeypatch.setattr(check_bench, "RULES", [RULE_MAX])
    assert check_bench.main(["--fresh", fresh, "--baselines", base]) == 0
    monkeypatch.setattr(check_bench, "RULES", [RULE_MIN])
    _write(tmp_path / "fresh", _rows(0.1))
    assert check_bench.main(["--fresh", fresh, "--baselines", base]) == 1


def test_repo_rules_reference_known_files():
    """Every gated file must be a BENCH artifact the bench runner can
    produce, and tolerances must be sane for their rule type."""
    for fname, tag, metric, rule, tol in check_bench.RULES:
        assert fname.startswith("BENCH_") and fname.endswith(".json")
        assert rule in ("rel_max", "rel_min", "abs_max", "abs_min")
        if rule == "rel_max":
            assert tol >= 1.0
        if rule == "rel_min":
            assert tol <= 1.0
        if rule == "abs_min":
            assert tol > 0.0
