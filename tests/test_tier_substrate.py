"""Physical memory substrate: capability-mode resolution, transfer
ledger accounting, TierSubstrate drain reconciliation against a live
engine, and the tentpole placement contract (`KVPager.pool_bytes_used`
== ledger `placement_bytes` after every drain) — plus the emulated-vs-
physical shape contract that keeps the CPU fallback honest."""

import dataclasses

import numpy as np
import pytest

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.blocks import PAGED_LEAF_NAMES, init_pool_twin
from repro.runtime import capability
from repro.serving import (
    EngineConfig,
    Request,
    RequestQueue,
    ServingEngine,
)
from repro.serving.substrate import SubstrateLedger, TierSubstrate
from repro.serving.substrate.ledger import KINDS

CTX = ParallelCtx(remat="none")


def _cfg(arch="smollm_360m"):
    return dataclasses.replace(configs.reduced(arch), dtype="float32")


def _burst(n, vocab, prompt_len, gen, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(request_id=i,
                tokens=rng.integers(0, vocab, prompt_len).astype(np.int32),
                max_new_tokens=gen, arrival=0.0)
        for i in range(n)
    ]


def _spilling_engine(pool_dtype="fp", substrate="auto"):
    """A small engine whose local budget forces pool placement."""
    cfg = _cfg()
    ecfg = EngineConfig(
        n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=4,
        hot_window=4, local_budget_frac=0.35, admission="greedy",
        pool_dtype=pool_dtype, substrate=substrate,
    )
    return cfg, ServingEngine.build(cfg, CTX, ecfg)


# -------------------------------------------- capability mode resolver
@pytest.mark.parametrize("requested", ["off", "emulated"])
@pytest.mark.parametrize("host_input", [False, True])
@pytest.mark.parametrize("internal", [False, True])
def test_resolve_fixed_modes_ignore_probes(requested, host_input,
                                           internal):
    assert capability.resolve_substrate_mode(
        requested, host_input=host_input, host_output=False,
        internal=internal) == requested


@pytest.mark.parametrize("host_input,internal,expect", [
    (True, True, "physical"),
    (True, False, "emulated"),
    (False, True, "emulated"),
    (False, False, "emulated"),
])
def test_resolve_auto_follows_probes(host_input, internal, expect):
    assert capability.resolve_substrate_mode(
        "auto", host_input=host_input, host_output=False,
        internal=internal) == expect


@pytest.mark.parametrize("host_input,internal", [
    (True, False), (False, True), (False, False),
])
def test_resolve_physical_requires_both_probes(host_input, internal):
    with pytest.raises(RuntimeError, match="physical"):
        capability.resolve_substrate_mode(
            "physical", host_input=host_input, host_output=False,
            internal=internal)
    assert capability.resolve_substrate_mode(
        "physical", host_input=True, host_output=True,
        internal=True) == "physical"


def test_resolve_rejects_unknown_mode():
    with pytest.raises(ValueError, match="substrate"):
        capability.resolve_substrate_mode(
            "hbm", host_input=True, host_output=True, internal=True)


def test_substrate_mode_probes_this_backend():
    """On any backend the probed resolution is a valid mode and agrees
    with the pure resolver fed the same probes."""
    mode = capability.substrate_mode("auto")
    assert mode in ("physical", "emulated")
    assert mode == capability.resolve_substrate_mode(
        "auto",
        host_input=capability.supports_host_input(),
        host_output=capability.supports_host_output(),
        internal=capability.supports_internal_transfer(),
    )


# ------------------------------------------------------ ledger contract
def test_ledger_placement_and_byte_accounting():
    led = SubstrateLedger(page_bytes=100.0, mode="emulated")
    led.record("page_out", 4, step=0)
    assert led.placement_bytes() == 400.0
    led.record("page_in", 1, step=1)
    led.record("drop", 2, step=1)
    assert led.resident_pages == 1
    led.record("handoff", 3, step=2)       # moves bytes, placement flat
    c = led.counters()
    assert c["placement_bytes"] == 100.0
    assert c["page_out_bytes"] == 400.0
    assert c["page_in_bytes"] == 100.0
    assert c["drop_bytes"] == 0.0          # frees move nothing
    assert c["handoff_bytes"] == 300.0
    assert c["events"] == 4 and c["in_flight"] == 0


def test_ledger_rejects_unknown_kind():
    with pytest.raises(ValueError, match="stream kind"):
        SubstrateLedger(1.0, "emulated").record("promote", 1, step=0)


def test_ledger_shapes_identical_across_modes():
    """The emulated fallback must report byte accounting in EXACTLY the
    physical ledger's shape — same counter keys, same event fields — so
    CPU CI exercises the same contract the pinned_host path serves."""
    counters = {}
    for mode in ("physical", "emulated"):
        led = SubstrateLedger(page_bytes=64.0, mode=mode)
        led.record("page_out", 2, step=0)
        led.record("page_in", 1, step=1)
        led.record("drop", 1, step=2)
        led.record("handoff", 1, step=3)
        counters[mode] = led.counters()
        assert led.events[0].mode == mode
    phys, emu = counters["physical"], counters["emulated"]
    assert set(phys) == set(emu)
    for k in phys:
        if k != "mode":
            assert phys[k] == emu[k], k
    assert {f.name for f in dataclasses.fields(led.events[0])} >= {
        "step", "kind", "n_pages", "bytes", "mode", "completed"}


# ------------------------------------------------- TierSubstrate drains
def test_tier_substrate_mode_must_be_resolved():
    cfg = _cfg()
    caches = M.make_paged_decode_caches(cfg, 2, 32, 8)
    for bad in ("auto", "off", "hbm"):
        with pytest.raises(ValueError, match="resolve"):
            TierSubstrate(caches, None, bad)


def test_pool_twin_mirrors_paged_leaves_only():
    cfg = _cfg()
    caches = M.make_paged_decode_caches(cfg, 2, 32, 8,
                                        pool_dtype="int8")
    twin = init_pool_twin(caches)
    assert twin
    for pos, sub in twin.items():
        assert set(sub) <= set(PAGED_LEAF_NAMES)
        for name, leaf in sub.items():
            assert leaf.shape == caches[pos][name].shape
            assert leaf.dtype == caches[pos][name].dtype


def test_substrate_disabled_on_ssm_only_stack():
    cfg = _cfg("mamba2_780m")
    eng = ServingEngine.build(cfg, CTX, EngineConfig(
        n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=8,
        admission="greedy", pool_dtype="fp", substrate="auto",
    ))
    assert eng.substrate is None           # no paged KV leaves to place
    sub = TierSubstrate(eng.caches, None, "emulated")
    assert not sub.enabled
    assert sub.drain(eng.pager, eng.caches) == {}
    assert sub.counters()["events"] == 0


def test_substrate_off_disables_wiring():
    _, eng = _spilling_engine(substrate="off")
    assert eng.substrate is None
    eng.run(_burst(2, 100, 8, 4), max_steps=6)   # runs fine without it


def test_placement_contract_holds_mid_run():
    """The tentpole acceptance, checked at EVERY decode step (not just
    at the drained end): after each drain the ledger's measured
    placement bytes equal the pager's derived pool footprint, and the
    measured page bytes match the pager's closed-form page bytes."""
    cfg, eng = _spilling_engine()
    assert eng.substrate is not None
    assert eng.substrate.mode == capability.substrate_mode("auto")
    assert eng.substrate.page_bytes == pytest.approx(
        eng.pager.page_bytes)
    reqs = _burst(4, cfg.vocab_size, 8, 6, seed=3)
    q = RequestQueue(reqs)
    cap = eng.begin_capture()
    checks = 0
    while len(q) or eng.batcher.n_busy:
        act = eng.pump(q)
        if act == "decode":
            # slot retirements free pages AFTER the in-step drain, so
            # reconcile before reading — the contract is "after every
            # drain", and a drain with no tier changes is a no-op
            eng.substrate.drain(eng.pager, eng.caches, step=eng.steps)
            assert eng.pager.pool_bytes_used() == pytest.approx(
                eng.substrate.counters()["placement_bytes"])
            checks += 1
        elif act == "idle":
            break
    stats = eng.capture_stats(cap, reqs)
    assert checks > 0, "trace never decoded"
    assert eng.substrate.counters()["events"] > 0, (
        "trace never exercised the substrate")
    assert eng.substrate.counters()["in_flight"] == 0   # capture syncs
    s = stats.summary()
    assert s["substrate_transfer_bytes"] > 0
    assert s["substrate_placement_bytes"] == pytest.approx(
        eng.pager.pool_bytes_used())


def test_drain_reconciles_out_in_drop_streams():
    """Page-out on first spill, page-in on promotion, drop on free —
    observed end-to-end over a run that admits, spills and completes."""
    cfg, eng = _spilling_engine()
    eng.run(_burst(4, cfg.vocab_size, 8, 6, seed=3))
    eng.substrate.sync()
    c = eng.substrate.counters()
    assert c["page_out_pages"] > 0
    assert c["drop_pages"] > 0             # completed slots freed pages
    assert c["page_out_bytes"] == pytest.approx(
        c["page_out_pages"] * eng.substrate.page_bytes)
    assert c["in_flight"] == 0
    # final reconciliation: whatever the pager still holds in the pool
    # is exactly what the ledger says is host-resident
    assert eng.pager.pool_bytes_used() == pytest.approx(
        c["placement_bytes"])


def test_handoff_recording():
    _, eng = _spilling_engine()
    eng.run(_burst(2, 100, 8, 4))
    before = eng.substrate.counters()
    eng.substrate.record_handoff(3, step=eng.steps)
    c = eng.substrate.counters()
    assert c["handoff_pages"] == before["handoff_pages"] + 3
    assert c["handoff_bytes"] == pytest.approx(
        before["handoff_bytes"] + 3 * eng.substrate.page_bytes)
    # handoffs move bytes but never change placement
    assert c["resident_pages"] == before["resident_pages"]
    eng.substrate.record_handoff(0)        # no-op, not an event
    assert eng.substrate.counters()["events"] == c["events"]


def test_int8_pool_substrate_measures_quantized_bytes():
    """With the int8 default pool the twin carries the quantized payload
    plus scale planes, and measured page bytes track the pager's
    dtype-aware accounting (the ~4x cut is the point of the flip)."""
    cfg, eng8 = _spilling_engine(pool_dtype="int8")
    _, engf = _spilling_engine(pool_dtype="fp")
    assert eng8.substrate.page_bytes == pytest.approx(
        eng8.pager.page_bytes)
    assert eng8.substrate.page_bytes < 0.5 * engf.substrate.page_bytes
    eng8.run(_burst(4, cfg.vocab_size, 8, 6, seed=3))
    eng8.substrate.sync()
    assert eng8.pager.pool_bytes_used() == pytest.approx(
        eng8.substrate.counters()["placement_bytes"])
