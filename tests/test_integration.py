"""End-to-end fault-tolerance integration: checkpoint/restart must continue
bit-compatibly (deterministic data keyed by step), straggler watchdog flags
outliers, quantify pipeline runs for every runnable cell."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.common.config import TrainConfig
from repro.data.synthetic import make_batch_for
from repro.launch.mesh import ctx_for_mesh
from repro.runtime import sharding as shd
from repro.runtime import train as train_rt
from repro.runtime.fault import StragglerWatchdog


def _train(cfg, mesh, steps, start_state, start=0):
    ctx = ctx_for_mesh(mesh, fsdp=False, remat="none")
    rules = shd.ShardingRules.for_training(None, ctx.tp_axis)
    tcfg = TrainConfig(total_steps=20, warmup_steps=2)
    batch = make_batch_for(cfg, 16, 4, 0)
    bundle = train_rt.make_bundle(cfg, ctx, tcfg, rules, mesh, batch,
                                  donate=False)
    state = start_state
    for step in range(start, steps):
        b = make_batch_for(cfg, 16, 4, step)
        state, metrics = bundle.step_fn(state, b)
    return state, metrics


def test_restart_continues_exactly(tmp_path, smoke_mesh):
    cfg = configs.reduced("granite_3_2b")
    init, _ = train_rt.init_train_state(cfg, jax.random.PRNGKey(0))

    # straight 8-step run
    final_a, _ = _train(cfg, smoke_mesh, 8, init)

    # 4 steps -> checkpoint -> restore -> 4 more
    mid, _ = _train(cfg, smoke_mesh, 4, init)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, mid, blocking=True)
    restored = mgr.restore(4, jax.tree.map(jnp.zeros_like, mid))
    final_b, _ = _train(cfg, smoke_mesh, 8, restored, start=4)

    for a, b in zip(jax.tree.leaves(final_a["params"]),
                    jax.tree.leaves(final_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog_flags():
    dog = StragglerWatchdog(threshold=2.0, warmup_steps=0)
    for s in range(8):
        dog.observe(s, 0.1)
    rep = dog.observe(8, 0.5)
    assert rep is not None and rep.ratio > 2
    assert dog.observe(9, 0.1) is None
    # ewma uncontaminated by the outlier
    assert abs(dog.ewma - 0.1) < 0.02
    assert len(dog.flagged) == 1


def test_restart_policy_backoff():
    from repro.runtime.fault import RestartPolicy

    pol = RestartPolicy(max_restarts=2, backoff_s=0.0)
    assert pol.should_restart(RuntimeError("x"))
    assert pol.should_restart(RuntimeError("x"))
    assert not pol.should_restart(RuntimeError("x"))


@pytest.mark.parametrize("arch,shape", configs.all_cells())
def test_quantify_every_cell(arch, shape):
    """The paper's 3-level analysis must run for every runnable cell."""
    from repro.core.quantify import analyze

    a = analyze(arch, shape, policy="hotness", pool_fraction="auto",
                use_dryrun=False)
    assert a.level1["footprint_bytes_per_chip"] > 0
    assert 0 <= a.level2["r_access_pool"] <= 1
    s = a.level3["sensitivity"]
    assert s["loi_0"] == pytest.approx(1.0)
    assert s["loi_50"] <= 1.0 + 1e-9
    assert a.level3["interference_coefficient"] >= 1.0
