"""Continuous-batching serving subsystem: queue/batcher/pager invariants,
engine-vs-naive token equivalence, no-recompile steady state, and the
M/D/1-knee admission throttle — all deterministic seeds."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.core import tiers as tr
from repro.models import model as M
from repro.serving import (
    AdmissionController,
    ContinuousBatcher,
    EngineConfig,
    INT8_TOKEN_AGREEMENT,
    KVPager,
    PagerConfig,
    PrefixCache,
    Request,
    RequestQueue,
    ServingEngine,
    bursty_stream,
    chat_stream,
    long_context_stream,
    shared_prefix_stream,
)

CTX = ParallelCtx(remat="none")


def _cfg(arch="smollm_360m"):
    return dataclasses.replace(configs.reduced(arch), dtype="float32")


def _burst(n, vocab, prompt_len, gen, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(request_id=i,
                tokens=rng.integers(0, vocab, prompt_len).astype(np.int32),
                max_new_tokens=gen, arrival=0.0)
        for i in range(n)
    ]


# --------------------------------------------------------------- queue
def test_queue_fifo_by_arrival():
    reqs = chat_stream(10, 64, seed=4, arrival_rate=1.0)
    q = RequestQueue(reqs)
    assert len(q) == 10
    assert q.pop(now=-1.0) is None          # nothing has arrived yet
    order = []
    now = 0.0
    while len(q):
        now = max(now, q.next_arrival())
        order.append(q.pop(now).arrival)
    assert order == sorted(order)


def test_queue_push_after_pop_preserves_consumed():
    """Ad-hoc push must not shuffle already-popped items back into the
    live window (regression: whole-list re-sort vs _head cursor)."""
    first = Request(request_id=0, tokens=np.zeros(4, np.int32),
                    max_new_tokens=1, arrival=5.0)
    q = RequestQueue([first])
    assert q.pop(5.0) is first
    late = Request(request_id=1, tokens=np.zeros(4, np.int32),
                   max_new_tokens=1, arrival=1.0)
    q.push(late)
    assert len(q) == 1
    assert q.pop(5.0) is late              # not the consumed request again
    assert q.pop(5.0) is None


def test_scenario_streams_deterministic():
    a = bursty_stream(12, 64, seed=7)
    b = bursty_stream(12, 64, seed=7)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert all((x.tokens == y.tokens).all() for x, y in zip(a, b))
    lc = long_context_stream(4, 64, seed=1, prompt_bucket=128)
    assert all(r.prompt_len == 128 for r in lc)


# -------------------------------------------------------------- batcher
def test_batcher_slot_lifecycle():
    b = ContinuousBatcher(2, prefill_buckets=(8,), park_pos=32)
    r0, r1, r2 = _burst(3, 64, 8, 4)
    s0 = b.admit(r0, start_pos=8)
    s1 = b.admit(r1, start_pos=8)
    assert b.n_free == 0 and b.n_active == 2
    with pytest.raises(RuntimeError):
        b.admit(r2, start_pos=8)
    assert list(b.t_vector()) == [8, 8]
    b.advance()
    assert list(b.t_vector()) == [9, 9]
    assert b.release(s0) is r0
    # freed slot parks its cursor out of cache range (masked writes)
    assert list(b.t_vector()) == [32, 9]
    s2 = b.admit(r2, start_pos=8)
    assert s2.index == 0                   # slot reuse
    with pytest.raises(ValueError):
        b.bucket_for(7)                    # not a bucket


# ---------------------------------------------------------------- pager
def _pager(policy, budget_pages=4, n_slots=2, max_seq=64, page=8):
    pcfg = PagerConfig(
        page_tokens=page, local_budget_bytes=budget_pages * page * 100.0,
        policy=policy, hot_window=16, cold_touch=0.05,
    )
    return KVPager(n_slots, max_seq, bytes_per_token=100.0,
                   resident_bytes=0.0, pcfg=pcfg)


def test_pager_hotness_keeps_tail_local():
    p = _pager("hotness")
    p.admit(0, 48)                         # 6 pages, budget 4
    local = p.tier[0] == 0
    valid = p.valid[0]
    assert valid[:6].all() and not valid[6:].any()
    # local usage within budget; the hot tail pages stay local, the cold
    # prefix is evicted to the pool
    assert p.local_bytes_used() <= p.budget + 1e-9
    assert local[4] and local[5]           # tail (hot window = 2 pages)
    assert not local[0] and not local[1]   # cold prefix evicted


def test_pager_static_strands_tail_on_pool():
    p = _pager("static")
    p.admit(0, 48)
    local = p.tier[0] == 0
    assert local[:4].all()                 # first-come pages got the budget
    assert not local[4] and not local[5]   # hot tail stranded remote
    # and decode traffic is therefore pool-heavy vs hotness
    hot = _pager("hotness")
    hot.admit(0, 48)
    t_static = p.step(np.array([True, False]))
    t_hot = hot.step(np.array([True, False]))
    assert t_static.pool_bytes > t_hot.pool_bytes
    assert t_static.total == pytest.approx(t_hot.total, rel=1e-9)


def test_pager_budget_invariant_over_decode():
    p = _pager("hotness", budget_pages=3)
    p.admit(0, 24)
    p.admit(1, 24)
    for _ in range(30):
        p.step(np.array([True, True]))
        assert p.local_bytes_used() <= p.budget + 1e-9
    assert p.lengths.tolist() == [54, 54]
    c = p.counters()
    assert c["pool_bytes"] > 0 and c["evictions"] > 0
    p.release(0)
    assert not p.valid[0].any()


def test_pager_remote_share_ordering():
    """hotness < static on a long-context decode run; 'none' is zero."""
    shares = {}
    for policy in ("hotness", "static", "none"):
        p = _pager(policy, budget_pages=4, max_seq=96)
        p.admit(0, 64)
        for _ in range(24):
            p.step(np.array([True, False]))
        shares[policy] = p.remote_share()
    assert shares["none"] == 0.0
    assert shares["hotness"] < shares["static"]


# ------------------------------------------------------------ admission
def test_admission_monotone_and_throttles():
    topo = tr.v5e_topology()
    ac = AdmissionController(topo, prior_loi=0.1)
    lois = [ac.projected_loi(n) for n in range(1, 10)]
    assert all(a <= b + 1e-12 for a, b in zip(lois, lois[1:]))
    # budget ~0.59: with 0.1/slot the 6th concurrent slot crosses the knee
    assert ac.admit(0) and ac.admit(4)
    assert not ac.admit(5)
    assert ac.blocks == 1
    # greedy mode never throttles
    g = AdmissionController(topo, mode="greedy", prior_loi=1.0)
    assert g.admit(100)


def test_admission_observe_refines_prior():
    ac = AdmissionController(tr.v5e_topology(), prior_loi=0.0)
    for _ in range(8):
        ac.observe(n_active=2, t_pool=0.5, dt=1.0)   # 25% link per slot
    assert ac.per_slot_loi == pytest.approx(0.25, rel=1e-2)


def test_engine_admission_throttles_under_loi(smoke_mesh):
    """A saturating prior must cap concurrency below the slot count."""
    cfg = _cfg()
    ecfg = EngineConfig(
        n_slots=6, max_seq=48, prefill_buckets=(16,), page_tokens=8,
        hot_window=8, local_budget_frac=0.25, admission="loi",
    )
    eng = ServingEngine.build(cfg, CTX, ecfg)
    eng.admission.per_slot_loi = 0.2       # deterministic saturating prior
    eng.admission.EMA = 0.0                # freeze: test the projection
    reqs = _burst(8, cfg.vocab_size, 16, 8, seed=3)
    stats = eng.run(reqs)
    assert stats.max_concurrency <= 2      # 3 * 0.2 > 0.59 knee budget
    assert stats.admission_blocks > 0
    assert all(r.done for r in reqs)       # throttled, not starved


# --------------------------------------------------------------- engine
def test_engine_slot_invariants():
    cfg = _cfg()
    ecfg = EngineConfig(
        n_slots=3, max_seq=64, prefill_buckets=(16, 32), page_tokens=8,
        hot_window=16, local_budget_frac=0.5, admission="greedy",
    )
    eng = ServingEngine.build(cfg, CTX, ecfg)
    reqs = chat_stream(9, cfg.vocab_size, seed=5, prompt_buckets=(16, 32),
                       gen_range=(2, 8), arrival_rate=2e4)
    stats = eng.run(reqs)
    assert stats.n_requests == 9
    assert all(len(r.output) == r.max_new_tokens for r in reqs)
    assert all(np.isfinite(r.finished) for r in reqs)
    assert all(r.finished >= r.admitted >= r.arrival - 1e-12 for r in reqs)
    assert stats.max_concurrency <= ecfg.n_slots
    assert eng.batcher.n_active == 0       # drained
    assert not eng.pager.valid.any()       # all pages released
    assert stats.tokens == sum(r.max_new_tokens for r in reqs)
    # per-token virtual times are monotone within each request
    for r in reqs:
        assert np.all(np.diff(r.token_times) > 0)


def test_engine_requests_consumed_once():
    cfg = _cfg()
    ecfg = EngineConfig(n_slots=2, max_seq=32, prefill_buckets=(8,),
                        admission="greedy", local_budget_frac=None)
    eng = ServingEngine.build(cfg, CTX, ecfg)
    reqs = _burst(2, cfg.vocab_size, 8, 4)
    eng.run(reqs)
    with pytest.raises(ValueError):
        eng.run(reqs)


def test_engine_no_recompile_steady_state():
    """Compile counts after warmup must not grow over continued serving
    with admissions/completions/slot churn (the fixed-shape contract)."""
    cfg = _cfg()
    ecfg = EngineConfig(
        n_slots=2, max_seq=48, prefill_buckets=(8, 16), page_tokens=8,
        hot_window=8, local_budget_frac=0.5, admission="greedy",
    )
    eng = ServingEngine.build(cfg, CTX, ecfg)
    warm = bursty_stream(4, cfg.vocab_size, seed=1, prompt_buckets=(8, 16),
                         gen_range=(2, 6), burst_size=2, burst_gap=1e-4)
    eng.run(warm)
    counts0 = eng.compile_counts()
    if any(v < 0 for v in counts0.values()):
        pytest.skip("this jax build does not expose jit cache sizes")
    more = bursty_stream(8, cfg.vocab_size, seed=2, prompt_buckets=(8, 16),
                         gen_range=(2, 6), burst_size=3, burst_gap=1e-4)
    eng.run(more)
    assert eng.compile_counts() == counts0
    assert all(v <= 1 for v in counts0.values())


@pytest.mark.parametrize("arch", ["smollm_360m", "granite_moe_1b_a400m",
                                  "mamba2_780m"])
def test_engine_matches_naive_loop(arch):
    """Token-level equivalence with the pre-engine ServeBundle-style loop
    (batched prefill + scalar-t decode) on an all-at-once trace."""
    cfg = _cfg(arch)
    B, S, GEN = 2, 8, 6
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size
    ))

    batch = {"tokens": jnp.asarray(prompts)}
    caches, logits = M.prefill(params, batch, cfg, CTX, max_seq=S + GEN)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    naive = [tok]
    for i in range(GEN - 1):
        logits, caches = M.decode_step(params, tok, caches, S + i, cfg, CTX)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        naive.append(tok)
    naive = np.asarray(jnp.stack(naive, axis=1))

    ecfg = EngineConfig(
        n_slots=B, max_seq=S + GEN, prefill_buckets=(S,), page_tokens=4,
        hot_window=8, local_budget_frac=0.5, admission="greedy",
    )
    eng = ServingEngine.build(cfg, CTX, ecfg, params=params)
    reqs = [Request(request_id=i, tokens=prompts[i], max_new_tokens=GEN)
            for i in range(B)]
    eng.run(reqs)
    engine_out = np.stack([np.asarray(r.output) for r in reqs])
    np.testing.assert_array_equal(engine_out, naive)


# -------------------------------------------- prediction-driven page-in
def test_pager_prefetch_cuts_demand_share():
    """Discrete prediction-driven paging: a stream predictor must convert
    demand page-ins of the cold prefix into staged (overlappable)
    transfers vs the 'demand' null baseline, without changing placement
    or total traffic structure."""
    shares = {}
    for pf in ("demand", "stream", "next_line"):
        pcfg = PagerConfig(
            page_tokens=8, local_budget_bytes=4 * 8 * 100.0,
            policy="hotness", hot_window=16, cold_touch=0.1,
            prefetch=pf, prefetch_degree=8,
        )
        p = KVPager(2, 400, bytes_per_token=100.0, resident_bytes=0.0,
                    pcfg=pcfg)
        p.admit(0, 256)
        p.admit(1, 256)
        for _ in range(120):
            p.step(np.array([True, True]))
        c = p.counters()
        shares[pf] = c["demand_share"]
        if pf == "demand":
            assert c["prefetch_issued"] == 0
        else:
            assert c["prefetch_useful"] > 0
            assert c["prefetch_useful"] <= c["prefetch_issued"]
    assert shares["stream"] < shares["demand"]
    assert shares["next_line"] < shares["demand"]


def test_pager_prefetch_invalid_name():
    with pytest.raises(ValueError):
        PagerConfig(prefetch="frontier")     # needs hints the pager lacks


def test_pager_recorder_captures_touch_stream():
    from repro.prefetch import TraceRecorder

    pcfg = PagerConfig(page_tokens=8, policy="none", hot_window=16,
                       cold_touch=0.1)
    p = KVPager(2, 128, bytes_per_token=100.0, resident_bytes=0.0,
                pcfg=pcfg)
    p.recorder = TraceRecorder()
    p.admit(0, 100)
    for _ in range(10):
        p.step(np.array([True, False]))
    t = p.recorder.to_trace("pager", "serving", p.page_bytes,
                            2 * p.n_pages)
    assert t.n_steps == 10
    assert t.touches > 0
    # hot tail present every step: last valid page id is always touched
    tail = p._page_of(int(p.lengths[0]) - 1)
    assert all(any(g % p.n_pages >= tail - 2 for g in s) for s in t.steps)


def test_engine_no_recompile_with_prefetch_enabled():
    """Acceptance: prediction-driven page-in is host-side accounting —
    steady state must stay recompile-free with it on."""
    cfg = _cfg()
    ecfg = EngineConfig(
        n_slots=2, max_seq=48, prefill_buckets=(8, 16), page_tokens=8,
        hot_window=8, local_budget_frac=0.5, admission="greedy",
        prefetch="stream", cold_touch=0.1,
    )
    eng = ServingEngine.build(cfg, CTX, ecfg)
    warm = bursty_stream(4, cfg.vocab_size, seed=1, prompt_buckets=(8, 16),
                         gen_range=(2, 6), burst_size=2, burst_gap=1e-4)
    eng.run(warm)
    counts0 = eng.compile_counts()
    if any(v < 0 for v in counts0.values()):
        pytest.skip("this jax build does not expose jit cache sizes")
    more = bursty_stream(8, cfg.vocab_size, seed=2, prompt_buckets=(8, 16),
                         gen_range=(2, 6), burst_size=3, burst_gap=1e-4)
    eng.run(more)
    assert eng.compile_counts() == counts0
    # the mode really is wired through to the pager (discrete accounting
    # active: every pool byte is classified demand or staged)
    assert eng.pager.cfg.prefetch == "stream"
    assert eng.pager._predictor is not None
    c = eng.pager.counters()
    assert (c["demand_pool_bytes"] + c["prefetch_pool_bytes"]
            == pytest.approx(c["pool_bytes"]))


def test_engine_prefetch_tokens_and_virtual_time():
    """Same trace under demand paging vs prediction-driven page-in:
    tokens identical (accounting never touches the math), demand share
    lower and the virtual clock no slower with prediction."""
    cfg = _cfg()
    out = {}
    for pf in ("demand", "stream"):
        ecfg = EngineConfig(
            n_slots=2, max_seq=96, prefill_buckets=(64,), page_tokens=8,
            hot_window=16, local_budget_frac=0.4, admission="greedy",
            prefetch=pf, cold_touch=0.1,
        )
        eng = ServingEngine.build(cfg, CTX, ecfg)
        reqs = long_context_stream(3, cfg.vocab_size, seed=2,
                                   prompt_bucket=64, gen_range=(8, 16),
                                   arrival_rate=1e9)
        out[pf] = (eng.run(reqs), [list(r.output) for r in reqs])
    (dm, dm_toks), (st, st_toks) = out["demand"], out["stream"]
    assert dm_toks == st_toks
    assert st.pager["demand_share"] < dm.pager["demand_share"]
    # staging issued near the end of a short run has not paid off yet,
    # so allow a small excess-traffic margin on the virtual clock
    assert st.virtual_s <= dm.virtual_s * 1.05


# ------------------------------------------- admission <-> sched loop
def test_measured_profile_feeds_scheduler(smoke_mesh):
    """ROADMAP closed loop: the engine's measured per-slot LoI becomes a
    sched trace profile, and co-located serving jobs throttle each other
    in the rack simulator."""
    from repro.sched.cluster import build_cluster
    from repro.sched.policies import make_policy
    from repro.sched.simulator import simulate
    from repro.sched.workload import serving_stream

    cfg = _cfg()
    ecfg = EngineConfig(n_slots=2, max_seq=48, prefill_buckets=(16,),
                        page_tokens=8, hot_window=8, local_budget_frac=0.3,
                        admission="greedy")
    eng = ServingEngine.build(cfg, CTX, ecfg)
    with pytest.raises(RuntimeError):
        eng.measured_profile()               # no steps yet
    eng.run(_burst(4, cfg.vocab_size, 16, 8, seed=9))
    prof = eng.measured_profile()
    assert prof.pool_traffic >= 0 and prof.t_compute > 0
    assert 0.0 <= prof.injected_loi() <= 1.0

    jobs = serving_stream(12, prof, seed=0, arrival_rate=50.0,
                          steps=(200, 400))
    assert all(j.injected_loi == pytest.approx(prof.injected_loi())
               for j in jobs)
    cluster = build_cluster(n_racks=1, pools_per_rack=1, nodes_per_pool=4)
    res = simulate(jobs, cluster, make_policy("fcfs"))
    assert np.all(res.finish >= res.start)
    if prof.injected_loi() > 0.05:
        # loud co-residents stretch each other beyond isolated runtime
        assert float(res.slowdown.max()) > 1.0


def test_engine_long_context_pager_beats_static():
    """The acceptance comparison at test scale: identical trace, equal
    steps, lower remote share under the tier-aware pager."""
    cfg = _cfg()
    out = {}
    for policy in ("hotness", "static"):
        ecfg = EngineConfig(
            n_slots=2, max_seq=96, prefill_buckets=(64,), page_tokens=8,
            hot_window=16, local_budget_frac=0.4, pager_policy=policy,
            admission="greedy",
        )
        eng = ServingEngine.build(cfg, CTX, ecfg)
        reqs = long_context_stream(3, cfg.vocab_size, seed=2,
                                   prompt_bucket=64, gen_range=(8, 16),
                                   arrival_rate=1e9)
        out[policy] = (eng.run(reqs), [list(r.output) for r in reqs])
    (hot, hot_toks), (st, st_toks) = out["hotness"], out["static"]
    assert hot_toks == st_toks             # placement never changes tokens
    assert hot.steps == st.steps           # equal schedule -> equal tok/s
    assert hot.pager["remote_share"] < st.pager["remote_share"]


# ---------------------------------------------- paged physical runtime
def test_pager_phys_tiers_partitions_pool():
    p = _pager("hotness")
    p.admit(0, 48)
    p.admit(1, 24)
    tiers = p.phys_tiers()
    assert tiers.shape == (p.n_slots * p.n_pages,)
    owned = int(p.valid.sum())
    assert int((tiers >= 0).sum()) == owned
    assert int((tiers == -1).sum()) == len(p._free_phys)
    # the tier tags match the per-(slot,page) accounting view exactly
    for s, pg in zip(*np.nonzero(p.valid)):
        assert tiers[p.phys[s, pg]] == p.tier[s, pg]


def _pager_invariants(p):
    """Free-list / block-table / refcount consistency under churn (the
    sharing-aware superset of the PR-5 invariants: mappings may alias,
    so DISTINCT live pages replace unique owners)."""
    owned = p.phys[p.valid]                # one entry per table mapping
    assert (owned >= 0).all()
    assert (p.ref >= 0).all()              # no double-free can go negative
    live = np.nonzero(p.ref > 0)[0]
    free = set(p._free_phys)
    assert len(free) == len(p._free_phys)                 # no dup frees
    assert free.isdisjoint(live.tolist())                 # free XOR live
    assert len(free) + len(live) == p.n_phys              # no leak
    # every mapping is counted exactly once:
    #   sum(refcounts) == mapped table entries + pins
    assert int(p.ref.sum()) == int(p.valid.sum()) + p.pins
    ids, counts = np.unique(owned, return_counts=True)
    assert (p.ref[ids] >= counts).all()    # refs cover each page's mappings
    bt = p.block_table()
    assert (bt[~p.valid] == 0).all()
    assert (bt[p.valid] == owned).all()
    assert (p.phys[~p.valid] == -1).all()
    # byte accounting is DEDUPLICATED: distinct live pages, counted once
    used = p.local_bytes_used() + p.pool_bytes_used()
    assert used == pytest.approx(len(live) * p.page_bytes)


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    churn_ops = st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=8),    # op kind
            st.integers(min_value=0, max_value=2),    # slot
            st.integers(min_value=1, max_value=64),   # length
        ),
        min_size=1, max_size=60,
    )

    @given(churn_ops)
    @settings(max_examples=60, deadline=None)
    def test_pager_allocator_churn(ops):
        """Free-list reuse, block-table consistency, refcount cover,
        no-double-free/no-leak and the COW write-privacy invariant hold
        under any randomized admit/release/extend/step/rebalance/share/
        pin/speculate/freeze-thaw sequence (the PR-5 churn test extended
        with sharing ops, the speculative-decode cycle — lookahead
        allocation, multi-token commit, rejected-tail truncate — and the
        fault layer's preemption cycle: freeze holds interleaved with
        shares and pins, spill-freezes, thaws into different slots —
        debug-mode validation ON)."""
        pcfg = PagerConfig(page_tokens=8, local_budget_bytes=4 * 8 * 100.0,
                           policy="hotness", hot_window=16, cold_touch=0.1,
                           validate=True)
        p = KVPager(3, 64, bytes_per_token=100.0, resident_bytes=0.0,
                    pcfg=pcfg)
        pinned = []                       # outstanding test-held pins
        frozen = []                       # outstanding freeze snapshots
        for kind, slot, length in ops:
            try:
                if kind == 0:
                    p.admit(slot, min(length, p.max_seq))
                elif kind == 1 and p.valid[slot].any():
                    p.release(slot)
                elif kind == 2 and p.lengths[slot] > 0:
                    p.extend(slot,
                             min(p.lengths[slot] + length, p.max_seq))
                elif kind == 3:
                    active = p.lengths > 0
                    # step writes one token per active slot; stay in range
                    active &= p.lengths < p.max_seq
                    p.step(active)
                    # COW invariant: a write NEVER lands on a shared page
                    # — after the step, every written tail page is private
                    for s in np.nonzero(active)[0]:
                        g = p.phys[s, p._page_of(int(p.lengths[s]) - 1)]
                        assert p.ref[g] == 1
                elif kind == 4:
                    p.rebalance()
                elif kind == 5:
                    # share: map another slot's page-aligned prefix into a
                    # fresh slot (the prefix-cache hit path at pager level)
                    donor, tgt = slot, (slot + 1) % p.n_slots
                    n_donor = int(p.valid[donor].sum())
                    if n_donor and not p.valid[tgt].any():
                        k = min(n_donor, 1 + length % 4)
                        pages = p.phys[donor, :k].copy()
                        p.map_shared(tgt, pages,
                                     k * p.cfg.page_tokens)
                elif kind == 6:
                    # speculate: one engine verify cycle at pager level —
                    # lookahead-k tail pages made live+private up front,
                    # a 1..k-token commit through the multi-token step,
                    # then truncate rolls the rejected tail's pages back
                    k = 1 + length % 4
                    active = (p.lengths > 0) & (p.lengths + k
                                                <= p.max_seq)
                    if active.any():
                        p.ensure_tail_pages(active, lookahead=k)
                        counts = np.zeros(p.n_slots, dtype=np.int64)
                        counts[active] = 1 + (slot + length) % k
                        p.step(active, tokens=counts)
                        for s in np.nonzero(active)[0]:
                            p.truncate(int(s))
                            # the committed tail page stays live+private
                            g = p.phys[
                                s, p._page_of(int(p.lengths[s]) - 1)]
                            assert p.ref[g] == 1
                elif kind == 7:
                    # freeze/thaw churn (the fault layer's preemption):
                    # a live slot's table is snapshotted and handed back
                    # — held under a freeze pin (thawable) or spilled
                    # outright — and held snapshots thaw into whichever
                    # slot is free, interleaved with shares and pins
                    owned = np.nonzero(p.valid[slot])[0]
                    contig = (owned.size > 0
                              and (owned == np.arange(owned.size)).all())
                    if frozen and not p.valid[slot].any():
                        p.thaw(slot, frozen.pop(0))
                    elif contig and len(frozen) < 2:
                        snap = p.freeze(slot, spill=(length % 2 == 0))
                        if snap["pages"] is not None:
                            frozen.append(snap)
                else:
                    # pin/unpin churn (the trie's non-slot references)
                    if len(pinned) < 2 and p.valid[slot].any():
                        g = int(p.phys[slot, 0])
                        p.pin([g])
                        pinned.append(g)
                    elif pinned:
                        p.unpin([pinned.pop()])
            except RuntimeError as e:
                # pins can strand live pages outside any slot, so the
                # finite pool CAN legitimately exhaust — the allocator
                # must refuse loudly (atomically: no partial allocation),
                # never hand out an aliased page. Reset and churn on.
                assert "pool exhausted" in str(e)
                while pinned:
                    p.unpin([pinned.pop()])
                while frozen:
                    p.drop_frozen(frozen.pop())
                for s in range(p.n_slots):
                    p.release(s)
            _pager_invariants(p)
        # drain: every page returns exactly once, all refcounts zero
        while pinned:
            p.unpin([pinned.pop()])
        while frozen:
            p.drop_frozen(frozen.pop())
        for s in range(p.n_slots):
            p.release(s)
        _pager_invariants(p)
        assert sorted(p._free_phys) == list(range(p.n_phys))
        assert (p.ref == 0).all() and p.pins == 0
except ImportError:  # pragma: no cover - conftest registers a fallback
    pass


def _gather_slot(leaf, bt_row, length):
    """Dense (nb, length, KV, hd) view of one slot's paged K/V leaf."""
    nb, _, page, kv, hd = leaf.shape
    dense = np.asarray(leaf)[:, bt_row]            # (nb, n_pages, page, ..)
    return dense.reshape(nb, -1, kv, hd)[:, :length]


def test_paged_cache_write_parity_with_contiguous():
    """The refactor's safety net at the BYTES level, not just tokens:
    after identical admissions and decode steps, gathering the physical
    page pool through the live block table must reproduce the contiguous
    engine's cache contents bit-for-bit over every valid token."""
    cfg = _cfg()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    engines = {}
    for paged in (False, True):
        ecfg = EngineConfig(
            n_slots=2, max_seq=48, prefill_buckets=(16,), page_tokens=8,
            hot_window=8, local_budget_frac=0.5, admission="greedy",
            paged=paged, pool_dtype="fp",    # byte parity needs exact pool
        )
        eng = ServingEngine.build(cfg, CTX, ecfg, params=params)
        reqs = _burst(2, cfg.vocab_size, 16, 24, seed=7)
        eng.run(reqs, max_steps=9)             # stop mid-flight
        assert eng.batcher.n_active == 2       # slots still live
        engines[paged] = eng
    dense_eng, paged_eng = engines[False], engines[True]
    pager = paged_eng.pager
    bt = pager.block_table()
    assert pager.lengths.tolist() == [25, 25]  # 16 prefill + 9 decode
    for pos, c in dense_eng.caches.items():
        for key in ("k", "v"):
            if key not in c:
                continue
            dense = np.asarray(c[key])
            pool = paged_eng.caches[pos][key]
            for s in range(pager.n_slots):
                L = int(pager.lengths[s])
                np.testing.assert_array_equal(
                    _gather_slot(pool, bt[s], L), dense[:, s, :L],
                )


def test_paged_default_and_block_table_threading():
    """EngineConfig defaults to the paged layout and the cells carry it."""
    ecfg = EngineConfig()
    assert ecfg.paged
    cfg = _cfg()
    eng = ServingEngine.build(cfg, CTX, EngineConfig(
        n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=8,
        admission="greedy",
    ))
    assert eng.cells.paged and eng.cells.n_pages == 4
    for pos, c in eng.caches.items():
        for key in ("k", "v"):
            if key in c:
                assert c[key].shape[1] == 2 * 4          # n_slots*n_pages
                assert c[key].shape[2] == 8              # page_tokens


def test_chunked_prefill_config_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="paged"):
        ServingEngine.build(cfg, CTX, EngineConfig(
            n_slots=2, max_seq=32, prefill_buckets=(8,), paged=False,
            pool_dtype="fp", prefill_chunk=8,
        ))
    with pytest.raises(ValueError, match="multiple"):
        ServingEngine.build(cfg, CTX, EngineConfig(
            n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=8,
            prefill_chunk=4,
        ))
    with pytest.raises(ValueError, match="attention-only"):
        ServingEngine.build(_cfg("mamba2_780m"), CTX, EngineConfig(
            n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=8,
            prefill_chunk=8,
        ))


def test_engine_chunked_prefill_matches_serialized():
    """Chunked prefill must be invisible to the sampled tokens and must
    land its admissions in smaller inter-decode-step gaps (the stall the
    chunking exists to kill)."""
    cfg = _cfg()
    out = {}
    for chunk in (None, 8):
        ecfg = EngineConfig(
            n_slots=2, max_seq=48, prefill_buckets=(32,), page_tokens=8,
            hot_window=8, local_budget_frac=0.5, admission="greedy",
            prefill_chunk=chunk,
        )
        eng = ServingEngine.build(cfg, CTX, ecfg)
        reqs = chat_stream(6, cfg.vocab_size, seed=11,
                           prompt_buckets=(32,), gen_range=(4, 10),
                           arrival_rate=3e4)
        stats = eng.run(reqs)
        out[chunk] = (stats, [list(r.output) for r in reqs])
        counts = eng.compile_counts()
        assert all(v <= 1 for v in counts.values())
        if chunk:
            assert "prefill_chunk" in counts
    (serial, serial_toks), (chunked, chunked_toks) = out[None], out[8]
    assert serial_toks == chunked_toks
    # a serialized 32-token prefill is one big gap; chunks of 8 are
    # several small ones
    assert chunked.decode_stall.max() < serial.decode_stall.max()


# ------------------------------------------ prefetch-excess admission
def test_admission_tightens_when_excess_rises():
    """Satellite acceptance: the same projected load that admits under a
    clean link is rejected once measured prefetch-excess traffic eats
    into the corridor budget."""
    topo = tr.v5e_topology()
    ac = AdmissionController(topo, prior_loi=0.1)
    assert ac.admit(4)                       # 0.5 < ~0.59 budget
    ac.EMA = 1.0                             # deterministic: no smoothing
    ac.observe(n_active=4, t_pool=0.4, dt=1.0, t_excess=0.2)
    assert ac.per_slot_loi == pytest.approx(0.1)   # load unchanged
    assert ac.excess_loi == pytest.approx(0.2)
    assert not ac.admit(4)                   # 0.5 + 0.2 > budget
    assert ac.blocks == 1
    # excess decaying back to zero re-opens admission
    ac.observe(n_active=4, t_pool=0.4, dt=1.0, t_excess=0.0)
    assert ac.admit(4)


def test_engine_feeds_pager_excess_to_admission():
    """Wiring: a speculative pager predictor's excess bytes must show up
    in the admission controller's excess LoI."""
    cfg = _cfg()
    ecfg = EngineConfig(
        n_slots=2, max_seq=96, prefill_buckets=(64,), page_tokens=8,
        hot_window=16, local_budget_frac=0.3, admission="greedy",
        prefetch="next_line", cold_touch=0.2,
    )
    eng = ServingEngine.build(cfg, CTX, ecfg)
    reqs = long_context_stream(3, cfg.vocab_size, seed=2, prompt_bucket=64,
                               gen_range=(8, 16), arrival_rate=1e9)
    eng.run(reqs)
    c = eng.pager.counters()
    assert c["prefetch_excess_bytes"] > 0     # next_line mispredicts
    assert eng.admission.excess_loi > 0.0


def test_paged_park_position_clears_partial_last_page():
    """Regression: when page_tokens does not divide max_seq_total, the
    parked write cursor must land PAST the pool's page-aligned position
    space — a park inside the last partial logical page passes the
    page-range guard and corrupts physical page 0 through the freed
    slot's zeroed block-table row. Uneven generation lengths keep one
    slot parked while the other decodes, and the paged stream must still
    match the contiguous engine token-for-token."""
    cfg = _cfg()
    S, page = 14, 4                       # n_pages=4: park=14 is IN page 3
    outs = {}
    for paged in (False, True):
        ecfg = EngineConfig(
            n_slots=2, max_seq=S, prefill_buckets=(8,), page_tokens=page,
            hot_window=8, local_budget_frac=None, admission="greedy",
            paged=paged, pool_dtype="fp",    # exact dense/paged token match
        )
        eng = ServingEngine.build(cfg, CTX, ecfg)
        rng = np.random.default_rng(13)
        reqs = [
            Request(request_id=i,
                    tokens=rng.integers(0, cfg.vocab_size, 8).astype(
                        np.int32),
                    max_new_tokens=gen, arrival=0.0)
            for i, gen in enumerate((2, 6))   # slot 0 parks early
        ]
        eng.run(reqs)
        outs[paged] = [list(r.output) for r in reqs]
        if paged:
            assert eng.batcher.park_pos == eng.cells.n_pages * page
            assert eng.batcher.park_pos > S
    assert outs[True] == outs[False]


# ------------------------------------------- block-quantized page pools
def test_pool_dtype_fp_is_exact_pr4_layout():
    """The pool_dtype="fp" safety net: byte-identical tree to the PR-4
    paged caches — no (scale, zero) leaves, payload in cfg.dtype."""
    cfg = _cfg()
    caches = M.make_paged_decode_caches(cfg, 2, 32, 8)     # default "fp"
    for pos, c in caches.items():
        assert "k_sz" not in c and "v_sz" not in c
        assert c["k"].dtype == jnp.dtype(cfg.dtype)
        assert c["v"].dtype == jnp.dtype(cfg.dtype)


def test_int8_cache_layout_and_bytes_accounting():
    """Tree walk == closed-form `core.access.kv_pool_token_bytes`, for
    both pool dtypes, and the int8 cut vs fp32 is < 0.3x."""
    from repro.core.access import kv_pool_token_bytes
    from repro.serving.engine import _kv_bytes_per_token

    cfg = _cfg()
    page, n_slots, max_seq = 8, 2, 32
    n_phys = n_slots * (max_seq // page)
    caches = M.make_paged_decode_caches(cfg, n_slots, max_seq, page,
                                        pool_dtype="int8")
    for pos, c in caches.items():
        assert c["k"].dtype == jnp.int8 and c["v"].dtype == jnp.int8
        assert c["k_sz"].shape == (cfg.num_layers, n_phys,
                                   cfg.num_kv_heads, 2)
        assert c["k_sz"].dtype == jnp.float32
    walk = _kv_bytes_per_token(caches)
    formula = kv_pool_token_bytes(cfg.num_layers, cfg.num_kv_heads,
                                  cfg.head_dim, page, "int8")
    assert walk == pytest.approx(formula)
    fp_caches = M.make_paged_decode_caches(cfg, n_slots, max_seq, page)
    fp_walk = _kv_bytes_per_token(fp_caches)
    assert fp_walk == pytest.approx(kv_pool_token_bytes(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, page, "fp"))
    assert walk < 0.3 * fp_walk


def test_pool_dtype_validation():
    with pytest.raises(ValueError, match="pool_dtype"):
        ServingEngine.build(_cfg(), CTX, EngineConfig(
            n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=8,
            pool_dtype="fp8",
        ))
    with pytest.raises(ValueError, match="paged"):
        ServingEngine.build(_cfg(), CTX, EngineConfig(
            n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=8,
            paged=False, pool_dtype="int8",
        ))


def test_int8_engine_cuts_pool_bytes_at_equal_schedule():
    """The tentpole's accounting end-to-end: identical trace, equal
    steps, same ABSOLUTE local budget — the int8 engine must move far
    fewer pool bytes than the fp32 engine (smaller pooled footprint
    AND more pages fitting locally), recompile-free."""
    cfg = _cfg()
    outs = {}
    budget = None
    for pd in ("fp", "int8"):
        ecfg = EngineConfig(
            n_slots=2, max_seq=96, prefill_buckets=(64,), page_tokens=8,
            hot_window=16, admission="greedy", pool_dtype=pd,
            local_budget_frac=0.3 if budget is None else None,
            local_budget_bytes=budget,
        )
        eng = ServingEngine.build(cfg, CTX, ecfg)
        if budget is None:
            budget = eng.pager.budget
        reqs = long_context_stream(3, cfg.vocab_size, seed=2,
                                   prompt_bucket=64, gen_range=(8, 16),
                                   arrival_rate=1e9)
        stats = eng.run(reqs)
        assert all(v <= 1 for v in eng.compile_counts().values())
        outs[pd] = stats
    fp, i8 = outs["fp"], outs["int8"]
    assert fp.steps == i8.steps            # equal schedule (length-based)
    assert i8.pager["pool_bytes"] < 0.35 * fp.pager["pool_bytes"]
    assert i8.pager["local_bytes"] < fp.pager["local_bytes"]


def test_int8_logit_drift_bounded_lockstep():
    """Teacher-forced lockstep decode over fp vs int8 paged caches: the
    same token stream feeds both pool dtypes, so the max logit gap
    isolates pure quantization drift (no greedy cascade). Runs the
    serve_int8 bench lane's own probe so the CI gate and the bench lane
    measure drift with one methodology, against the one documented
    bound."""
    from benchmarks.bench_serving import INT8_LOGIT_DRIFT, \
        _logit_drift_probe

    drift = _logit_drift_probe(_cfg(), steps=12, page_tokens=4)
    assert 0.0 < drift <= INT8_LOGIT_DRIFT


try:
    import hypothesis.strategies as st_q
    from hypothesis import given as given_q, settings as settings_q

    quant_churn_ops = st_q.lists(
        st_q.tuples(
            st_q.integers(min_value=0, max_value=3),   # op kind
            st_q.integers(min_value=0, max_value=2),   # slot
            st_q.integers(min_value=1, max_value=64),  # length
        ),
        min_size=1, max_size=50,
    )

    @given_q(quant_churn_ops)
    @settings_q(max_examples=40, deadline=None)
    def test_pager_allocator_churn_quantized_pools(ops):
        """Satellite: under random admit/finish sequences with the int8
        pool's (smaller, scale-carrying) bytes-per-token, the free list
        never double-frees or leaks — the batched `release` hands every
        owned page back exactly once."""
        from repro.core.access import kv_pool_token_bytes

        bpt = kv_pool_token_bytes(4, 2, 16, 8, "int8")
        pcfg = PagerConfig(page_tokens=8,
                           local_budget_bytes=4 * 8 * bpt,
                           policy="hotness", hot_window=16,
                           cold_touch=0.1)
        p = KVPager(3, 64, bytes_per_token=bpt, resident_bytes=0.0,
                    pcfg=pcfg)
        for kind, slot, length in ops:
            if kind == 0:
                p.admit(slot, min(length, p.max_seq))
            elif kind == 1 and p.valid[slot].any():
                p.release(slot)               # request finish/eviction
            elif kind == 2 and p.lengths[slot] > 0:
                p.extend(slot, min(p.lengths[slot] + length, p.max_seq))
            else:
                active = (p.lengths > 0) & (p.lengths < p.max_seq)
                p.step(active)
            _pager_invariants(p)
        for slot in range(p.n_slots):         # drain: everything returns
            p.release(slot)
        assert sorted(p._free_phys) == list(range(p.n_slots * p.n_pages))
except ImportError:  # pragma: no cover - conftest registers a fallback
    pass


# ------------------------------------- shared-prefix radix cache (PR 6)
def _vpager(n_slots=2, max_seq=64, page=8, validate=True):
    pcfg = PagerConfig(page_tokens=page, policy="none", validate=validate)
    return KVPager(n_slots, max_seq, bytes_per_token=100.0,
                   resident_bytes=0.0, pcfg=pcfg)


def test_shared_prefix_stream_shared_and_deterministic():
    a = shared_prefix_stream(8, 64, seed=3, system_tokens=24,
                             prompt_buckets=(32,))
    b = shared_prefix_stream(8, 64, seed=3, system_tokens=24,
                             prompt_buckets=(32,))
    assert all((x.tokens == y.tokens).all() for x, y in zip(a, b))
    sys_prefix = a[0].tokens[:24]
    assert all((r.tokens[:24] == sys_prefix).all() for r in a)
    # user tails differ (vocab 64, 8 tokens: collision chance ~0)
    assert any((r.tokens[24:] != a[0].tokens[24:]).any() for r in a[1:])
    with pytest.raises(ValueError, match="exceed"):
        shared_prefix_stream(2, 64, system_tokens=32, prompt_buckets=(32,))
    with pytest.raises(ValueError, match="n_systems"):
        shared_prefix_stream(2, 64, n_systems=0)


def test_prefix_cache_trie_match_insert_partial():
    p = _vpager()
    cache = PrefixCache(page_tokens=8)
    toks = np.arange(20, dtype=np.int32)          # 2 full pages + 4 tail
    assert cache.match(toks) is None              # cold miss
    p.admit(0, 20)
    row = p.phys[0]
    assert cache.insert(toks, row, p, include_partial=True) == 3
    assert p.pins == 3 and cache.cached_pages == 3
    # exact re-match: both full pages AND the terminal partial tail
    hit = cache.match(toks)
    assert hit.pages == [int(row[0]), int(row[1])]
    assert hit.n_full_tokens == 16
    assert hit.tail_page == int(row[2]) and hit.n_tokens == 20
    assert hit.all_pages == [int(row[i]) for i in range(3)]
    # divergent tail: full-page prefix only, the partial does not match
    div = toks.copy()
    div[-1] += 1
    hit = cache.match(div)
    assert hit.pages == [int(row[0]), int(row[1])]
    assert hit.tail_page is None and hit.n_tokens == 16
    # divergence inside the first block: miss
    assert cache.match(np.arange(1, 21, dtype=np.int32)) is None
    # re-insert of the same prompt adds nothing (existing nodes keep pages)
    assert cache.insert(toks, row, p, include_partial=True) == 0
    assert cache.counters()["hits"] == 2


def test_prefix_cache_capacity_cap_evicts_lru():
    p = _vpager(n_slots=2, max_seq=32)            # 8 phys pages
    cache = PrefixCache(page_tokens=8, capacity_pages=2)
    a = np.arange(16, dtype=np.int32)
    b = np.arange(100, 116, dtype=np.int32)
    p.admit(0, 16)
    cache.insert(a, p.phys[0], p)                 # 2 cached pages (at cap)
    p.release(0)
    p.admit(0, 16)
    cache.insert(b, p.phys[0], p)                 # over cap -> evict a's
    assert cache.cached_pages <= 2
    assert cache.evicted_pages == 2
    assert cache.match(a) is None                 # a evicted (LRU)
    assert cache.match(b) is not None             # b (MRU) survives
    _pager_invariants(p)


def test_prefix_cache_reclaim_under_free_list_pressure():
    p = _vpager(n_slots=2, max_seq=32)            # 8 phys pages
    cache = PrefixCache(page_tokens=8)
    p.prefix_cache = cache
    a = np.arange(16, dtype=np.int32)
    b = np.arange(100, 116, dtype=np.int32)
    p.admit(0, 16)
    cache.insert(a, p.phys[0], p)
    p.admit(1, 16)
    cache.insert(b, p.phys[1], p)
    cache.match(b)                                # bump b's recency
    p.release(0)
    p.release(1)                                  # trie pins keep all 4
    assert p.pins == 4 and len(p._free_phys) == 4
    # a 6-page demand exceeds the 4 free pages: _take_free calls back into
    # reclaim, which must evict LRU leaves (a's chain first) until enough
    # pages actually reach the free list
    p.admit(0, 32)                                # 4 pages
    p.extend(1, 16)                               # 2 more
    assert cache.evicted_pages >= 2
    assert cache.match(b) is not None             # the MRU chain survives
    assert cache.match(a) is None                 # the LRU chain was evicted
    _pager_invariants(p)


def test_pager_release_liveness_crosscheck():
    """Satellite (bug fix): a stale/aliased block-table entry must be
    caught at free time — returning a page to the free list while another
    live table entry still maps it would hand the recycled page two
    owners. The PR-5 release had no such cross-check."""
    p = _vpager(n_slots=2, max_seq=32)
    p.admit(0, 16)
    # forge an alias the refcounts don't know about (the bug class this
    # guard exists for: table mutation without the matching incref)
    p.phys[1, 0] = p.phys[0, 0]
    p.valid[1, 0] = True
    with pytest.raises(RuntimeError, match="still mapped"):
        p.release(0)
    # with validation off (production mode) the same forgery goes through
    q = _vpager(n_slots=2, max_seq=32, validate=False)
    q.admit(0, 16)
    q.phys[1, 0] = q.phys[0, 0]
    q.valid[1, 0] = True
    q.release(0)                                  # silent (pre-fix behavior)


def test_pager_shared_map_cow_lifecycle():
    """Refcount arithmetic of the full share/COW cycle at pager level:
    map -> ref 3 (donor + trie pin + sharer), tail write -> COW split,
    drain -> every page back exactly once."""
    p = _vpager(n_slots=2, max_seq=32)            # 4 pages/slot, 8 phys
    p.admit(0, 20)                                # 3 pages (tail partial)
    pages = [int(g) for g in p.phys[0, :3]]
    p.pin(pages)                                  # the trie's hold
    p.map_shared(1, pages, 20)
    assert (p.ref[pages] == 3).all()
    assert p.lengths[1] == 20 and p.shared_mapped_pages == 3
    # dedup accounting: 3 distinct live pages, not 6
    assert p.local_bytes_used() + p.pool_bytes_used() == 3 * p.page_bytes
    # slot 1 writes token 20 -> page 2 is shared -> COW
    cow = p.ensure_tail_pages(np.array([False, True]))
    assert len(cow) == 1
    old, new = cow[0]
    assert old == pages[2] and p.ref[old] == 2 and p.ref[new] == 1
    assert int(p.phys[1, 2]) == new != pages[2]
    assert p.cow_splits == 1
    # slot 0 writes its own token -> its tail is still shared (trie pin)
    cow = p.ensure_tail_pages(np.array([True, False]))
    assert len(cow) == 1 and cow[0][0] == pages[2]
    assert p.ref[pages[2]] == 1                   # pin only, now
    _pager_invariants(p)
    p.release(0)
    p.release(1)
    p.unpin(pages)
    assert sorted(p._free_phys) == list(range(p.n_phys))
    assert (p.ref == 0).all() and p.pins == 0


def test_kv_dedup_token_bytes_matches_pager_footprint():
    """The closed-form dedup formula and the pager's deduplicated byte
    accounting must agree: n_sharers slots sharing a page-aligned prefix
    occupy exactly the formula's bytes per token."""
    from repro.core.access import kv_dedup_token_bytes

    with pytest.raises(ValueError):
        kv_dedup_token_bytes(32, 40, 2, 1.0)
    with pytest.raises(ValueError):
        kv_dedup_token_bytes(32, 16, 0, 1.0)
    assert kv_dedup_token_bytes(32, 0, 4, 2.0) == pytest.approx(2.0)
    assert kv_dedup_token_bytes(0, 0, 4, 2.0) == 0.0

    p = _vpager(n_slots=3, max_seq=32)            # page 8 -> 4 pages/slot
    p.admit(0, 32)
    shared = [int(g) for g in p.phys[0, :2]]      # 16-token shared prefix
    p.map_shared(1, shared, 16)
    p.extend(1, 32)
    p.map_shared(2, shared, 16)
    p.extend(2, 32)
    used = p.local_bytes_used() + p.pool_bytes_used()
    assert used == pytest.approx(8 * p.page_bytes)   # 8 distinct pages
    per_tok = used / (3 * 32)
    assert per_tok == pytest.approx(
        kv_dedup_token_bytes(32, 16, 3, p.bytes_per_token))


def _shared_run(prefix_cache, *, pool_dtype="fp", prefill_chunk=None,
                n=8, seed=3):
    cfg = _cfg()
    ecfg = EngineConfig(
        n_slots=4, max_seq=64, prefill_buckets=(32,), page_tokens=8,
        hot_window=16, local_budget_frac=0.5, admission="greedy",
        pool_dtype=pool_dtype, prefill_chunk=prefill_chunk,
        prefix_cache=prefix_cache,
    )
    eng = ServingEngine.build(cfg, CTX, ecfg)
    reqs = shared_prefix_stream(n, cfg.vocab_size, seed=seed,
                                system_tokens=24, prompt_buckets=(32,),
                                gen_range=(6, 12), arrival_rate=3e4)
    stats = eng.run(reqs)
    return eng, stats, [list(r.output) for r in reqs]


def test_engine_prefix_cache_parity_and_dedup():
    """Satellite (parity): prefix cache ON vs OFF on a shared-system-
    prompt stream is token-for-token identical — sharing is a layout
    change, not a model change — while the trie actually dedups."""
    eng_off, _, toks_off = _shared_run(False)
    eng_on, stats_on, toks_on = _shared_run(True)
    assert toks_on == toks_off
    assert stats_on.prefix["hits"] >= 6           # every re-arrival hits
    assert stats_on.prefix["hit_rate"] > 0.5
    assert stats_on.pager["shared_mapped_pages"] > 0
    assert eng_on.pager.shared_mapped_pages > 0
    counts = eng_on.compile_counts()
    assert all(v <= 1 for v in counts.values())   # no recompiles
    # invariants hold on the live pager after the run
    _pager_invariants(eng_on.pager)


def test_engine_prefix_cache_chunked_skips_prefill():
    """Chunked path: shared chunks are genuinely skipped (prefill starts
    at the first divergent page), so ON spends no more virtual time than
    OFF — with identical tokens."""
    _, stats_off, toks_off = _shared_run(False, prefill_chunk=16)
    eng_on, stats_on, toks_on = _shared_run(True, prefill_chunk=16)
    assert toks_on == toks_off
    assert stats_on.prefix["hits"] > 0
    assert stats_on.virtual_s <= stats_off.virtual_s + 1e-12
    counts = eng_on.compile_counts()
    assert all(v <= 1 for v in counts.values())


def test_engine_prefix_cache_int8_token_agreement():
    """int8 pools share the per-page (scale, zero) leaves alongside the
    payload, so ON vs OFF greedy streams stay within the documented int8
    agreement bar (in practice bit-equal: quantizing identical content is
    deterministic)."""
    _, _, toks_off = _shared_run(False, pool_dtype="int8")
    _, stats_on, toks_on = _shared_run(True, pool_dtype="int8")
    assert stats_on.prefix["hits"] > 0
    for on, off in zip(toks_on, toks_off):
        n = min(len(on), len(off))
        agree = sum(a == b for a, b in zip(on[:n], off[:n])) / max(n, 1)
        assert agree >= INT8_TOKEN_AGREEMENT


def test_engine_cow_splits_shared_tail_page():
    """Two identical prompts whose bucket leaves a partial tail page: the
    second admission maps the donor's pages INCLUDING the partial tail
    (terminal trie node), so the first decode token of each slot must COW
    off the shared page — and the tokens still match the no-cache run."""
    cfg = _cfg()
    out = {}
    for on in (False, True):
        ecfg = EngineConfig(
            n_slots=2, max_seq=24, prefill_buckets=(12,), page_tokens=8,
            hot_window=8, local_budget_frac=None, admission="greedy",
            prefix_cache=on,
        )
        eng = ServingEngine.build(cfg, CTX, ecfg)
        rng = np.random.default_rng(5)
        prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
        reqs = [Request(request_id=i, tokens=prompt.copy(),
                        max_new_tokens=6, arrival=0.0) for i in range(2)]
        eng.run(reqs)
        out[on] = (eng, [list(r.output) for r in reqs])
    eng_on, toks_on = out[True]
    _, toks_off = out[False]
    assert toks_on == toks_off
    # donor splits off the trie's partial tail page at its first decode
    # write; the sharer splits off its mapped copy: >= 2 genuine COWs
    assert eng_on.pager.cow_splits >= 2
    counts = eng_on.compile_counts()
    assert counts.get("page_copy", 0) == 1        # compiled once, reused
    assert all(v <= 1 for v in counts.values())
    _pager_invariants(eng_on.pager)


def test_engine_prefix_cache_config_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="paged"):
        ServingEngine.build(cfg, CTX, EngineConfig(
            n_slots=2, max_seq=32, prefill_buckets=(8,), paged=False,
            pool_dtype="fp", prefix_cache=True,
        ))
    with pytest.raises(ValueError, match="attention-only"):
        ServingEngine.build(_cfg("mamba2_780m"), CTX, EngineConfig(
            n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=8,
            prefix_cache=True,
        ))
    with pytest.raises(ValueError, match="token-only"):
        ServingEngine.build(_cfg("paligemma_3b"), CTX, EngineConfig(
            n_slots=2, max_seq=32, prefill_buckets=(8,), page_tokens=8,
            prefix_cache=True,
        ))


@pytest.mark.slow
def test_bench_pager_churn_acceptance():
    """Tentpole acceptance, via the bench lanes themselves (satellite 3):
    bounded fragmentation under bursty churn, and the chat-lane dedup cut
    — prefix cache ON moves >= 30% fewer pool bytes per token than OFF at
    >= 0.95x the virtual token rate, token-identically."""
    from benchmarks import bench_pager_churn as B

    rows = B.run(smoke=True)
    by = {r["tag"]: r for r in rows}
    churn = by["pager_churn"]
    assert churn["fragmentation"] <= B.FRAG_BOUND
    assert churn["frag_drained"] == 0.0
    shared = by["pager_shared"]
    assert shared["hit_rate"] > 0.5
    assert shared["measured_token_bytes"] == pytest.approx(
        shared["dedup_token_bytes"], rel=1e-6)
    chat = by["pager_prefix_chat"]
    assert chat["token_parity"]
    assert chat["pool_bytes_per_token_ratio"] <= B.DEDUP_CUT
    assert chat["tok_rate_ratio"] >= 0.95


# ------------------------------------------------- speculative decoding
def test_ngram_propose_deterministic_replay():
    """The self-speculative proposer is a pure function of the history:
    deterministic, replays the continuation of the most recent earlier
    suffix match, pads with the tail, and falls back to repeating the
    last token when nothing recurs."""
    from repro.serving import ngram_propose

    hist = np.array([5, 6, 7, 9, 5, 6, 7], dtype=np.int64)
    a = ngram_propose(hist, 3)
    b = ngram_propose(hist, 3)
    np.testing.assert_array_equal(a, b)
    # suffix [5,6,7] recurred at position 0 -> replay what followed
    np.testing.assert_array_equal(a, [9, 5, 6])
    # short continuation pads by repeating its tail
    np.testing.assert_array_equal(
        ngram_propose(np.array([4, 2, 4, 2], dtype=np.int64), 3),
        [4, 2, 2])
    # longest match wins and prefers the MOST RECENT earlier occurrence
    h2 = np.array([1, 2, 3, 4, 1, 2, 8, 1, 2], dtype=np.int64)
    np.testing.assert_array_equal(ngram_propose(h2, 2), [8, 1])
    # nothing recurs -> repeat the last token
    h3 = np.array([3, 1, 4, 1, 5], dtype=np.int64)
    np.testing.assert_array_equal(ngram_propose(h3, 2)[:1], [5])
    # empty history -> zeros, right length
    assert ngram_propose(np.array([], dtype=np.int64), 4).shape == (4,)


def test_accept_greedy_acceptance_ladder():
    """The greedy-verification ladder over every acceptance count 0..k-1:
    emit = greedy[:a+1] where a is the first draft mismatch; at least one
    token always lands; a fully accepted ladder emits k tokens."""
    from repro.serving import accept_greedy

    k = 4
    greedy = [10, 11, 12, 13]
    # cand[0] is the last emitted token; drafts follow
    for a_want in range(k):
        cand = [7] + greedy[:a_want] + [99] * (k - 1 - a_want)
        a, emit = accept_greedy(np.array(cand), np.array(greedy))
        assert a == a_want
        assert emit == greedy[:a_want + 1]
    # perfect drafts accept everything: k tokens per sweep
    a, emit = accept_greedy(np.array([7] + greedy[:3]), np.array(greedy))
    assert a == k - 1 and emit == greedy


@pytest.mark.parametrize("mode", ["ngram", "draft"])
def test_engine_speculative_matches_greedy(mode):
    """Tentpole acceptance: the speculative engine (either proposer)
    emits BIT-FOR-BIT the plain greedy engine's tokens on fp pools —
    acceptance counts 0..k-1 all occur naturally across the trace — and
    drains the pager clean (every page back on the free list)."""
    cfg = _cfg()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S, GEN = 2, 8, 12
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(6), (B, S), 0, cfg.vocab_size))

    def serve(**kw):
        ecfg = EngineConfig(
            n_slots=B, max_seq=S + GEN, prefill_buckets=(S,),
            page_tokens=4, hot_window=8, local_budget_frac=0.5,
            # fp pools: the gate is BIT-exact (int8 speculation uses a
            # different quantization grid — per-token sub-scales — than
            # per-page greedy; the int8 test below bounds that drift)
            admission="greedy", paged=True, pool_dtype="fp", **kw,
        )
        eng = ServingEngine.build(cfg, CTX, ecfg, params=params)
        reqs = [Request(request_id=i, tokens=prompts[i],
                        max_new_tokens=GEN) for i in range(B)]
        stats = eng.run(reqs)
        return np.stack([np.asarray(r.output) for r in reqs]), stats, eng

    ref, ref_stats, _ = serve()
    got, stats, eng = serve(speculative=mode, speculative_k=4)
    np.testing.assert_array_equal(got, ref)
    # speculation must BEAT one-sweep-per-token: fewer verify steps than
    # emitted tokens, acceptance within [1, k]
    assert stats.spec["verify_steps"] < ref_stats.steps
    assert 1.0 <= stats.spec["accept_len_mean"] <= 4.0
    # verify steps commit everything past each request's prefill token
    assert stats.spec["emitted"] == B * (GEN - 1)
    if mode == "draft":
        assert stats.spec["draft_calls"] > 0
    # rollback left the pager exact: all slots retired, no leaked pages
    p = eng.pager
    assert sorted(p._free_phys) == list(range(p.n_phys))
    assert (p.ref == 0).all() and not p.valid.any()


def test_engine_speculative_int8_token_scales():
    """Speculative decoding over int8 pools auto-selects the per-token
    sub-scale layout (collision-free k-row scatter) and stays within the
    documented drift bound of the int8 greedy stream."""
    cfg = _cfg()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S, GEN = 2, 8, 10
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(8), (B, S), 0, cfg.vocab_size))

    def serve(**kw):
        ecfg = EngineConfig(
            n_slots=B, max_seq=S + GEN, prefill_buckets=(S,),
            page_tokens=4, hot_window=8, local_budget_frac=0.5,
            admission="greedy", paged=True, pool_dtype="int8", **kw,
        )
        eng = ServingEngine.build(cfg, CTX, ecfg, params=params)
        reqs = [Request(request_id=i, tokens=prompts[i],
                        max_new_tokens=GEN) for i in range(B)]
        eng.run(reqs)
        return np.stack([np.asarray(r.output) for r in reqs]), eng

    ref, _ = serve()
    got, eng = serve(speculative="ngram", speculative_k=4)
    assert eng.cells.sz_granularity == "token"
    # per-token k_sz/v_sz leaves carry the page_tokens axis
    for pos in eng.caches:
        if "k_sz" in eng.caches[pos]:
            assert eng.caches[pos]["k_sz"].ndim == 5
    assert float((ref == got).mean()) >= INT8_TOKEN_AGREEMENT


def test_speculative_config_validation():
    """Unsupported speculative configs fail loudly at build time."""
    cfg = _cfg()
    base = dict(n_slots=2, max_seq=16, prefill_buckets=(8,),
                page_tokens=4, hot_window=8, local_budget_frac=0.5,
                admission="greedy")
    with pytest.raises(ValueError, match="speculative"):
        ServingEngine.build(cfg, CTX, EngineConfig(
            **base, paged=True, speculative="beam"))
    with pytest.raises(ValueError, match="paged"):
        ServingEngine.build(cfg, CTX, EngineConfig(
            **base, paged=False, speculative="ngram"))
    with pytest.raises(ValueError, match="spec_k|speculative_k"):
        ServingEngine.build(cfg, CTX, EngineConfig(
            **base, paged=True, speculative="ngram", speculative_k=1))
    # verify flattens slots -> slots*k rows; SSM state cannot follow
    with pytest.raises(ValueError, match="attention"):
        ServingEngine.build(_cfg("mamba2_780m"), CTX, EngineConfig(
            **base, paged=True, speculative="ngram"))


def test_pager_speculative_cycle_refcounts_exact():
    """Deterministic lookahead/commit/truncate cycle at pager level:
    ensure_tail_pages makes k positions live, the multi-token step
    charges ONE read sweep while lengths advance by the acceptance
    count, and truncate returns exactly the rejected tail's pages."""
    pcfg = PagerConfig(page_tokens=4, local_budget_bytes=1e9,
                       policy="hotness", hot_window=8, cold_touch=0.1,
                       validate=True)
    p = KVPager(2, 32, bytes_per_token=100.0, resident_bytes=0.0,
                pcfg=pcfg)
    p.admit(0, 7)                       # mid-page frontier
    p.admit(1, 8)                       # page-aligned frontier
    free0 = len(p._free_phys)
    active = np.array([True, True])
    k = 4
    p.ensure_tail_pages(active, lookahead=k)
    # slot 0 writes 7..10 (page 1 already live, page 2 new), slot 1
    # writes 8..11 (page 2 new)
    assert len(p._free_phys) == free0 - 2
    t = p.step(active, tokens=np.array([1, 3]))
    assert list(p.lengths) == [8, 11]
    # ONE read sweep charged for the whole verify call: the multi-token
    # step moves strictly fewer bytes than the equivalent single-token
    # step sequence (which re-reads the growing cache every token)
    q = KVPager(2, 32, bytes_per_token=100.0, resident_bytes=0.0,
                pcfg=pcfg)
    q.admit(0, 7)
    q.admit(1, 8)
    serial = q.step(np.array([True, True])).total
    serial += q.step(np.array([False, True])).total
    serial += q.step(np.array([False, True])).total
    assert list(q.lengths) == [8, 11]
    assert t.total < serial
    freed = p.truncate(0) + p.truncate(1)
    # slot 0 committed through position 7 (page 1 full): page 2 dies;
    # slot 1 committed through 10 (page 2 live): nothing to roll back
    assert freed == 1
    assert len(p._free_phys) == free0 - 1
    _pager_invariants(p)


# ------------------------------------------- fault-layer preemption (PR 10)
def test_engine_preempts_low_priority_under_pool_exhaustion():
    """Admission under pool-exhaustion preempts instead of deadlocking:
    with pages stranded under an external hold (a handoff guard pin in
    flight), a high-priority prompt that cannot get pages spill-freezes
    the lowest-priority active decode slot, runs, and the victim resumes
    by teacher-forced refill — BOTH token streams bit-identical to an
    uncontended engine (fp pools), the pool drained exactly free."""
    cfg = _cfg()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        n_slots=2, max_seq=16, prefill_buckets=(8, 12), page_tokens=4,
        hot_window=8, local_budget_frac=0.5, admission="greedy",
        paged=True, pool_dtype="fp",
    )
    rng = np.random.default_rng(21)
    tok_b = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    tok_c = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)

    # uncontended reference streams (greedy decode: a request's tokens
    # depend only on its prompt, so solo runs give the ground truth)
    ref = ServingEngine.build(cfg, CTX, ecfg, params=params)
    ref_b = Request(request_id=0, tokens=tok_b, max_new_tokens=6)
    ref_c = Request(request_id=1, tokens=tok_c, max_new_tokens=4)
    ref.run([ref_b, ref_c])

    eng = ServingEngine.build(cfg, CTX, ecfg, params=params)
    # phase 1: a sacrificial request decodes mid-flight, then its pages
    # go under a guard pin and the slot retires — the handoff-in-flight
    # shape: 3 of 8 physical pages stranded outside any slot
    sac = Request(request_id=7, tokens=tok_b.copy(), max_new_tokens=6,
                  priority=1)
    eng.run([sac], max_steps=2)
    slot = next(s for s in eng.batcher.slots if s.active)
    held = eng.pager.phys[slot.index, eng.pager.valid[slot.index]].copy()
    assert held.size == 3
    eng.pager.pin(held)
    eng._retire(slot)

    # phase 2: the low-priority victim decodes mid-flight (3 more pages)
    b = Request(request_id=0, tokens=tok_b, max_new_tokens=6, priority=1)
    eng.run([b], max_steps=4)
    assert eng.batcher.n_active == 1
    free0 = eng.pager.counters()["free_pages"]

    # phase 3: the high-priority prompt needs 3 pages but only 2 are
    # free — the OLD allocator raised "page pool exhausted" here
    c = Request(request_id=1, tokens=tok_c, max_new_tokens=4, priority=0)
    assert free0 < -(-c.prompt_len // ecfg.page_tokens)
    stats = eng.run([c])

    np.testing.assert_array_equal(np.asarray(c.output),
                                  np.asarray(ref_c.output))
    np.testing.assert_array_equal(np.asarray(b.output),
                                  np.asarray(ref_b.output))
    assert stats.faults["preempts"] >= 1
    assert stats.faults["spills"] >= 1
    assert stats.faults["restores"] >= 1
    assert stats.faults["reprefilled_tokens"] > 0
    assert stats.faults["migrations_in"] == 0     # same-engine restore
    # high-priority admission beat the victim's restore
    assert c.admitted < b.finished

    eng.pager.unpin(held)
    p = eng.pager
    assert sorted(p._free_phys) == list(range(p.n_phys))
    assert (p.ref == 0).all() and p.pins == 0 and not eng.frozen


def test_engine_fault_free_stats_empty():
    """`ServeStats.faults` is {} on fault-free runs — the bench and CI
    baselines never see the fault block unless something fired."""
    cfg = _cfg()
    ecfg = EngineConfig(n_slots=2, max_seq=32, prefill_buckets=(8,),
                        page_tokens=4, hot_window=8, local_budget_frac=0.5,
                        admission="greedy")
    eng = ServingEngine.build(cfg, CTX, ecfg)
    stats = eng.run(_burst(3, cfg.vocab_size, 8, 4, seed=3))
    assert stats.faults == {}
    assert "fault_preempts" not in stats.summary()
