import jax
import pytest

# NB: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (multi-device coverage runs in
# subprocesses; see test_multidevice.py).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def smoke_mesh():
    from repro.launch.mesh import make_smoke_mesh

    return make_smoke_mesh()
