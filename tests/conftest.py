import sys
import types

import jax
import pytest

# NB: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (multi-device coverage runs in
# subprocesses; see test_multidevice.py).

jax.config.update("jax_enable_x64", False)


# ----------------------------------------------------- hypothesis fallback
# Property tests use hypothesis when it is installed. On a bare environment
# we register a miniature stand-in under the same module names BEFORE the
# test modules import it, degrading each @given property test to a small
# deterministic parametrized case sweep (seeded per case) instead of
# erroring out at collection.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as _np

    _N_FALLBACK_CASES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _integers(min_value=0, max_value=100, **_kw):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1))
        )

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def _sampled_from(seq):
        pool = list(seq)
        return _Strategy(lambda rng: pool[int(rng.integers(len(pool)))])

    def _lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]

        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def _given(*strategies, **kw_strategies):
        def deco(fn):
            def _case(_hyp_case):
                rng = _np.random.default_rng(_hyp_case + 1)
                args = [s.draw(rng) for s in strategies]
                kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, **kwargs)

            _case.__name__ = fn.__name__
            _case.__doc__ = fn.__doc__
            _case.__module__ = fn.__module__
            return pytest.mark.parametrize(
                "_hyp_case", range(_N_FALLBACK_CASES)
            )(_case)

        return deco

    def _settings(*_a, **_kw):
        return lambda fn: fn

    def _assume(condition):
        if not condition:
            pytest.skip("hypothesis-fallback: assume() rejected the case")

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = _assume
    _st = types.ModuleType("hypothesis.strategies")
    _st.floats = _floats
    _st.integers = _integers
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _st.tuples = _tuples
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def smoke_mesh():
    from repro.launch.mesh import make_smoke_mesh

    return make_smoke_mesh()
