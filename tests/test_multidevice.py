"""Multi-device coverage via subprocesses (the main pytest process must keep
a single CPU device; see conftest). Each case forces 8 host devices, builds
a real (2,4) mesh, and checks sharded-vs-single-device semantics."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}"
    ).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


MOE_EP_CODE = r"""
import jax, jax.numpy as jnp
from repro import configs
from repro.common.parallel import ParallelCtx
from repro.models import moe as moe_mod
from repro.models.module import Initializer
import dataclasses

cfg = dataclasses.replace(
    configs.reduced("granite_moe_1b_a400m"),
    num_experts=8, experts_per_token=2, capacity_factor=8.0,  # no drops
    dtype="float32", param_dtype="float32",
)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
init = Initializer(jax.random.PRNGKey(0), jnp.float32)
moe_mod.moe_init(init, cfg)
params, _ = init.collect()
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

dense_y, dense_aux = moe_mod.moe_dense(params, x, cfg)
ctx = ParallelCtx(mesh=mesh, dp_axes=("data",), fsdp_axis=None,
                  tp_axis="model")
ep_y, ep_aux = jax.jit(
    lambda p, x: moe_mod.moe_ep(p, x, cfg, ctx)
)(params, x)
err = float(jnp.abs(dense_y - ep_y).max() / (jnp.abs(dense_y).max() + 1e-9))
aux_err = abs(float(dense_aux) - float(ep_aux))
print("ERR", err, aux_err)
assert err < 1e-4, err
assert aux_err < 1e-4, aux_err
print("MOE_EP_OK")
"""


def test_moe_ep_matches_dense():
    out = run_sub(MOE_EP_CODE)
    assert "MOE_EP_OK" in out


SHARDED_TRAIN_CODE = r"""
import jax, jax.numpy as jnp
from repro import configs
from repro.common.config import TrainConfig
from repro.data.synthetic import make_batch_for
from repro.launch.mesh import ctx_for_mesh
from repro.runtime import sharding as shd, train as train_rt

cfg = configs.reduced("granite_3_2b")
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx = ctx_for_mesh(mesh, fsdp=True)
rules = shd.ShardingRules.for_training(ctx.fsdp_axis, ctx.tp_axis)
tcfg = TrainConfig(total_steps=4, warmup_steps=1)
batch = make_batch_for(cfg, 16, 8, 0)
bundle = train_rt.make_bundle(cfg, ctx, tcfg, rules, mesh, batch,
                              donate=False)
state, _ = train_rt.init_train_state(cfg, jax.random.PRNGKey(0))
losses = []
for step in range(3):
    b = make_batch_for(cfg, 16, 8, step)
    state, metrics = bundle.step_fn(state, b)
    losses.append(float(metrics["loss"]))
assert all(jnp.isfinite(jnp.asarray(losses))), losses

# single-device reference for step-0 loss
from repro.launch.mesh import make_smoke_mesh
mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx1 = ctx_for_mesh(mesh1, fsdp=False)
rules1 = shd.ShardingRules.for_training(None, None)
bundle1 = train_rt.make_bundle(cfg, ctx1, tcfg, rules1, mesh1, batch,
                               donate=False)
state1, _ = train_rt.init_train_state(cfg, jax.random.PRNGKey(0))
_, m1 = bundle1.step_fn(state1, make_batch_for(cfg, 16, 8, 0))
d = abs(losses[0] - float(m1["loss"]))
print("LOSS_DELTA", d)
assert d < 5e-2, d
print("SHARDED_TRAIN_OK")
"""


def test_sharded_train_matches_single_device():
    out = run_sub(SHARDED_TRAIN_CODE)
    assert "SHARDED_TRAIN_OK" in out


COLLECTIVE_PARSER_CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.profiler.hlo import analyze_hlo
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
x = jax.ShapeDtypeStruct((256, 512), jnp.float32,
                         sharding=NamedSharding(mesh, P("data", None)))
w1 = jax.ShapeDtypeStruct((512, 1024), jnp.float32,
                          sharding=NamedSharding(mesh, P(None, "model")))
w2 = jax.ShapeDtypeStruct((1024, 512), jnp.float32,
                          sharding=NamedSharding(mesh, P("model", None)))
c = jax.jit(lambda x, w1, w2: jnp.tanh(x @ w1) @ w2).lower(x, w1, w2).compile()
m = analyze_hlo(c.as_text())
# Megatron row-parallel second matmul -> psum over model(4) of the
# (256/2, 512) f32 output: 2*(3/4)*256/2*512*4 bytes
exp = 2 * 0.75 * 128 * 512 * 4
ar = m.collective_by_kind.get("all-reduce", 0)
print("AR", ar, "EXP", exp)
assert abs(ar - exp) / exp < 0.05, (ar, exp)
print("COLLECTIVE_OK")
"""


def test_collective_parser_on_sharded_program():
    out = run_sub(COLLECTIVE_PARSER_CODE)
    assert "COLLECTIVE_OK" in out


DRYRUN_SMALL_CODE = r"""
import sys
sys.argv = ["dryrun"]
from repro.launch import dryrun
class A: pass
a = A(); a.mesh = "2x4"; a.multi_pod = False; a.no_fsdp = False
a.remat = "block"; a.microbatches = 1; a.tier_policy = "hotness"
a.pool_fraction = 0.5; a.outdir = "/tmp/dryrun_test"
mesh = dryrun.build_mesh(a)
rec = dryrun.run_cell("smollm_360m", "train_4k", mesh, a, a.outdir)
assert rec["status"] == "ok", rec.get("error")
assert rec["tier"]["n_pool_tensors"] > 0
assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
print("DRYRUN_OK")
"""


def test_dryrun_tiered_small_mesh():
    out = run_sub(DRYRUN_SMALL_CODE)
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_production_mesh_lowering_smollm():
    """One full production-mesh (16x16) cell end-to-end in a subprocess."""
    code = DRYRUN_SMALL_CODE.replace('"2x4"', "None").replace(
        'a.mesh = None', 'a.mesh = None'
    )
    out = run_sub(code, devices=256, timeout=1200)
    assert "DRYRUN_OK" in out
