"""Prefill + decode must agree with teacher-forced forward (f32 exactness;
bf16 is covered by finiteness in the smoke tests)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.frontends import synthetic_frontend_embeds

CTX = ParallelCtx(remat="none")


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


@pytest.mark.parametrize("arch", configs.list_archs())
def test_prefill_decode_match_forward(arch):
    cfg = _f32(configs.reduced(arch))
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S, MAXS = 2, 8, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0,
                              cfg.vocab_size)
    extra = {}
    if cfg.frontend == "vision_stub":
        extra["patches"] = synthetic_frontend_embeds(cfg, B, S)
    if cfg.frontend == "audio_stub":
        extra["frames"] = synthetic_frontend_embeds(cfg, B, 16)

    logits_full, _ = M.forward(
        params, {"tokens": toks[:, : S + 1], **extra}, cfg, CTX
    )
    caches, logits_pre = M.prefill(
        params, {"tokens": toks[:, :S], **extra}, cfg, CTX, max_seq=MAXS
    )
    assert float(jnp.abs(logits_pre - logits_full[:, S - 1]).max()) < 1e-3

    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    logits_dec, caches = M.decode_step(
        params, toks[:, S], caches, S + npfx, cfg, CTX
    )
    assert float(jnp.abs(logits_dec - logits_full[:, S]).max()) < 1e-3

    # a second decode step stays consistent
    logits_full2, _ = M.forward(
        params, {"tokens": toks[:, : S + 2], **extra}, cfg, CTX
    )
    logits_dec2, _ = M.decode_step(
        params, toks[:, S + 1], caches, S + 1 + npfx, cfg, CTX
    )
    assert float(jnp.abs(logits_dec2 - logits_full2[:, S + 1]).max()) < 2e-3


@pytest.mark.parametrize("arch", ["mamba2_780m", "jamba_1_5_large_398b"])
def test_ssm_state_carry(arch):
    """SSM decode state must carry exactly (no attention to fall back on)."""
    cfg = _f32(configs.reduced(arch))
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    caches, _ = M.prefill(params, {"tokens": toks[:, :4]}, cfg, CTX,
                          max_seq=S)
    # decode 4..S-1 token by token; compare to teacher-forced each step
    full, _ = M.forward(params, {"tokens": toks}, cfg, CTX)
    for t in range(4, S - 1):
        logits, caches = M.decode_step(params, toks[:, t], caches, t, cfg,
                                       CTX)
        err = float(jnp.abs(logits - full[:, t]).max())
        assert err < 2e-3, (t, err)
