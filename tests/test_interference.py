"""Level-3 interference model + interference-aware scheduler."""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import interference as itf
from repro.core import tiers as tr
from repro.sched import (
    InterferenceAwareScheduler,
    Job,
    RandomScheduler,
    simulate_colocation,
)
from repro.sched.scheduler import five_number_summary


def mk_profile(pool_frac_traffic=0.3, ai_seconds=0.01, traffic=1e9):
    topo = tr.emulated(0.5, traffic)
    return itf.InterferenceProfile(
        arch="x", shape="y",
        pool_traffic=traffic * pool_frac_traffic,
        local_traffic=traffic * (1 - pool_frac_traffic),
        t_compute=ai_seconds,
        topo=topo,
    )


def test_queueing_monotone():
    xs = [itf.queueing_slowdown(r) for r in (0.0, 0.3, 0.6, 0.9, 0.99)]
    assert xs[0] == 1.0
    assert all(a < b for a, b in zip(xs, xs[1:]))


@given(
    st.floats(0.0, 0.9),         # pool traffic share
    st.floats(1e-4, 1.0),        # compute seconds
    st.floats(0.0, 0.5),         # LoI
)
@settings(max_examples=200, deadline=None)
def test_sensitivity_bounded_and_monotone(pool_share, t_comp, loi):
    p = mk_profile(pool_share, t_comp)
    s = p.sensitivity(loi)
    assert 0.0 < s <= 1.0 + 1e-9
    # more interference never helps
    assert p.sensitivity(min(loi + 0.2, 0.9)) <= s + 1e-9


def test_compute_bound_insensitive():
    """Paper Fig 10 HPL quadrant: compute-bound -> ~no degradation."""
    p = mk_profile(pool_frac_traffic=0.3, ai_seconds=10.0, traffic=1e6)
    assert p.sensitivity(0.5) > 0.99


def test_pool_bound_sensitive():
    """Paper Hypre/NekRS quadrant: pool-bound + low AI -> sensitive."""
    p = mk_profile(pool_frac_traffic=0.9, ai_seconds=1e-4, traffic=1e12)
    assert p.sensitivity(0.5) < 0.7


def test_ic_reflects_injection():
    loud = mk_profile(0.9, 1e-4, 1e12)
    quiet = mk_profile(0.01, 1.0, 1e6)
    assert loud.interference_coefficient() > quiet.interference_coefficient()
    assert quiet.interference_coefficient() >= 1.0


def test_mdl_knee_math():
    """rho* solves queueing_slowdown(rho*) = 1 + max_excess exactly."""
    for e in (0.25, 0.75, 2.0):
        rho = itf.mdl_knee(e)
        assert itf.queueing_slowdown(rho) == pytest.approx(1.0 + e)
    assert itf.mdl_knee(0.75) == pytest.approx(0.6)
    with pytest.raises(ValueError):
        itf.mdl_knee(0.0)


def test_corridor_budget_derived_not_hardcoded():
    """Binpack's budget comes from the topology (knee x (1 - r_bw_pool)),
    not the old 0.6 constant — and scales with the pool's bandwidth
    share."""
    from repro.sched.policies import CorridorBinPackPolicy

    topo = tr.v5e_topology()
    b = itf.corridor_budget(topo)
    assert b == pytest.approx(
        itf.mdl_knee() * (1.0 - topo.r_bw_pool)
    )
    assert 0.0 < b < itf.mdl_knee()
    assert CorridorBinPackPolicy().loi_budget == pytest.approx(b)
    assert CorridorBinPackPolicy(loi_budget=0.42).loi_budget == 0.42
    # a fatter pool link (larger r_bw_pool) must tighten the corridor
    import dataclasses as dc

    fat = dc.replace(
        topo,
        tiers=(topo.tiers[0],
               dc.replace(topo.tiers[1],
                          bandwidth=topo.tiers[0].bandwidth)),
    )
    assert itf.corridor_budget(fat) < b


def test_catalog_decode_loi_spread():
    """Paper Fig 10 spread: under the refined hot-tail/cold-prefix decode
    traffic model, catalog decode cells populate the intermediate LoI band
    instead of collapsing onto the silent/link-saturating extremes."""
    from repro import configs
    from repro.core.quantify import profile_for

    lois = [
        profile_for(a, "decode_32k", pool_fraction=0.05,
                    use_dryrun=False).injected_loi()
        for a in configs.list_archs()
    ]
    mid = [l for l in lois if 0.1 < l < 0.95]
    assert len(mid) >= 2, lois             # intermediate points exist
    assert max(lois) > 0.95, lois          # saturating cells remain
    # the adoption (pool-by-necessity) scenario also has an intermediate
    auto = [
        profile_for(a, "decode_32k", pool_fraction="auto",
                    use_dryrun=False).injected_loi()
        for a in configs.list_archs()
    ]
    assert any(0.1 < l < 0.95 for l in auto), auto


def test_decode_cache_split_model():
    from repro.core import access as acc

    # short sequences: everything hot, no split
    assert acc.decode_cache_split(acc.DECODE_HOT_WINDOW) == [("", 1.0, 1.0)]
    parts = acc.decode_cache_split(8 * acc.DECODE_HOT_WINDOW)
    assert len(parts) == 2
    (_, hot_frac, hot_t), (_, cold_frac, cold_t) = parts
    assert hot_frac == pytest.approx(1 / 8)
    assert hot_frac + cold_frac == pytest.approx(1.0)
    assert hot_t == 1.0 and cold_t == acc.DECODE_COLD_TOUCH < 1.0


def test_lbench_loi_monotone_in_nflop():
    topo = tr.v5e_topology()
    lois = [itf.lbench_loi(nf, 1 << 20, topo) for nf in (1, 8, 64, 512)]
    assert all(a >= b - 1e-12 for a, b in zip(lois, lois[1:]))
    assert lois[0] == pytest.approx(1.0)  # 1 flop/elem saturates the link


def test_lbench_beyond_saturation():
    """Paper Fig 11-middle: PCM saturates at link bw; IC keeps rising."""
    topo = tr.v5e_topology()
    rows = itf.lbench_intensity_sweep(topo, nflops=(1, 2, 4, 8))
    bw = [r["pcm_bw"] for r in rows]
    ic = [r["ic"] for r in rows]
    assert bw[0] == bw[1] == pytest.approx(topo.pool.bandwidth)
    assert ic[0] >= ic[1] >= ic[2]


# --------------------------------------------------------- scheduler
def _jobs():
    """Realistic mix: a few link-heavy jobs, many compute-bound ones — the
    co-location decision only matters when pools are not all saturated."""
    rng = np.random.default_rng(0)
    jobs = []
    for i in range(16):
        pool_share = rng.uniform(0.05, 0.6)
        traffic = 10 ** rng.uniform(7.5, 9.5)
        t_comp = 10 ** rng.uniform(-2.5, -1.0)
        jobs.append(
            Job(f"job{i}", mk_profile(pool_share, t_comp, traffic), steps=50)
        )
    return jobs


def _total_slowdown(pools):
    total = 0.0
    for p in pools:
        for j in p.jobs:
            bg = p.background_loi_for(j)
            total += 1.0 / max(j.sensitivity(bg), 1e-6)
    return total


def test_aware_beats_random():
    jobs = _jobs()
    slow_rand = []
    for seed in range(5):
        rs = RandomScheduler(4, 4, seed=seed)
        for j in jobs:
            assert rs.place(j) is not None
        slow_rand.append(_total_slowdown(rs.pools))
    aw = InterferenceAwareScheduler(4, 4)
    assert aw.place_all(jobs)
    # batch-aware vs random baseline: must beat the random MEAN (greedy is
    # not an offline optimum, so single lucky seeds may tie it)
    assert _total_slowdown(aw.pools) <= np.mean(slow_rand) + 1e-9


def test_colocation_simulation_fig13():
    """Interference-aware (LoI capped 0-20%) cuts mean AND p75 vs random
    (0-50%) for a sensitive workload — the paper's Fig 13."""
    sensitive = Job("hypre-like", mk_profile(0.8, 1e-4, 1e12), steps=120)
    base = simulate_colocation(sensitive, 100, loi_range=(0.0, 0.5), seed=1)
    aware = simulate_colocation(sensitive, 100, loi_range=(0.0, 0.2), seed=1)
    sb, sa = five_number_summary(base), five_number_summary(aware)
    assert sa["mean"] < sb["mean"]
    assert sa["p75"] < sb["p75"]
    assert sa["max"] <= sb["max"]
    # insensitive workload sees ~no benefit (paper: XSBench/HPL)
    stoic = Job("hpl-like", mk_profile(0.3, 10.0, 1e6), steps=120)
    b2 = simulate_colocation(stoic, 50, loi_range=(0.0, 0.5), seed=2)
    a2 = simulate_colocation(stoic, 50, loi_range=(0.0, 0.2), seed=2)
    assert np.mean(a2) == pytest.approx(np.mean(b2), rel=0.01)


def test_pool_capacity_respected():
    aw = InterferenceAwareScheduler(2, 1)
    jobs = _jobs()[:3]
    assert aw.place(jobs[0]) is not None
    assert aw.place(jobs[1]) is not None
    assert aw.place(jobs[2]) is None  # full
