"""Level-1 access profiles and bandwidth-capacity curves."""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro import configs
from repro.common.config import SHAPES
from repro.core import access as acc
from repro.core.access import TensorAccess
from repro.runtime import serve as serve_rt
from repro.runtime import train as train_rt


def test_expected_expert_fraction():
    cfg = configs.get("kimi_k2_1t_a32b")
    assert acc.expected_expert_fraction(cfg, 1) == pytest.approx(
        8 / 384, rel=1e-6
    )
    big = acc.expected_expert_fraction(cfg, 10**6)
    assert big > 0.999
    dense = configs.get("smollm_360m")
    assert acc.expected_expert_fraction(dense, 5) == 1.0


def test_train_profile_moments_cold():
    cfg = configs.reduced("smollm_360m")
    state, _ = train_rt.abstract_train_state(cfg)
    prof = acc.train_profile(state, cfg, SHAPES["train_4k"])
    cats = {a.category for a in prof}
    assert "moment" in cats and "param" in cats
    m = [a for a in prof if a.category == "moment"]
    p = [a for a in prof if a.category == "param"]
    assert max(a.touches for a in m) < min(a.touches for a in p)


def test_serve_profile_moe_skew():
    """Kimi decode: expert tensors must be far colder than attention — the
    Fig 6 skew that makes the 1T MoE pool-friendly."""
    cfg = configs.get("kimi_k2_1t_a32b")
    params, _ = serve_rt.abstract_params(cfg)
    prof = acc.serve_profile(params, None, cfg, SHAPES["decode_32k"])
    exp = [a for a in prof if a.category == "expert"]
    att = [a for a in prof if a.category == "param"]
    assert exp and att
    # the Zipf cold tail must be colder than any always-touched param;
    # the hottest experts may saturate at 1.0 with 128 tokens/step
    assert min(a.touches for a in exp) < 0.5
    mean_exp = sum(a.touches for a in exp) / len(exp)
    assert mean_exp < min(a.touches for a in att)


curve_profiles = st.lists(
    st.tuples(st.integers(1, 10**8), st.floats(0.01, 50.0)),
    min_size=1, max_size=30,
)


@given(curve_profiles)
@settings(max_examples=100, deadline=None)
def test_bwcap_curve_properties(entries):
    prof = [TensorAccess(f"t{i}", b, t, "param")
            for i, (b, t) in enumerate(entries)]
    xs, ys = acc.bandwidth_capacity_curve(prof)
    assert xs[0] == 0 and ys[0] == 0
    assert xs[-1] == pytest.approx(1.0)
    assert ys[-1] == pytest.approx(1.0)
    assert np.all(np.diff(xs) >= -1e-12)
    assert np.all(np.diff(ys) >= -1e-12)
    # hot-first ordering makes the curve concave-ish: y >= x everywhere
    assert np.all(ys >= xs - 1e-9)


def test_curve_skew_detects_moe():
    """MoE serve curve must be more skewed than dense serve curve."""
    kimi = configs.get("kimi_k2_1t_a32b")
    dense = configs.get("qwen2_5_32b")
    pk, _ = serve_rt.abstract_params(kimi)
    pd, _ = serve_rt.abstract_params(dense)
    sk = acc.serve_profile(pk, None, kimi, SHAPES["long_500k"])
    sd = acc.serve_profile(pd, None, dense, SHAPES["long_500k"])

    def hot20(prof):
        xs, ys = acc.bandwidth_capacity_curve(prof)
        i = np.searchsorted(xs, 0.2)
        return ys[min(i, len(ys) - 1)]

    assert hot20(sk) > hot20(sd)
