"""Rack-scale co-location simulator: cluster construction, policy
semantics, conservation invariants, and the aware-beats-random variance
regression — all with deterministic seeds."""

import time

import numpy as np
import pytest

from repro.core import interference as itf
from repro.sched import (
    Cluster,
    ClusterSpec,
    CorridorBinPackPolicy,
    InterferenceAwarePolicy,
    TraceJob,
    build_cluster,
    make_policy,
    profile_with_injected_loi,
    rescale_load,
    run_policies,
    simulate,
    synthetic_stream,
)


def _job(i, r, arrival=0.0, work=10.0):
    return TraceJob(
        job_id=i, name=f"j{i}", profile=profile_with_injected_loi(r),
        arrival=arrival, work=work,
    )


# ------------------------------------------------------------- cluster
def test_cluster_construction():
    spec = ClusterSpec(n_racks=3, pools_per_rack=2, nodes_per_pool=4)
    c = Cluster.build(spec)
    assert len(c.racks) == 3
    assert len(c.pools) == 6 == spec.n_pools
    assert [p.pool_id for p in c.pools] == list(range(6))
    assert [p.rack_id for p in c.pools] == [0, 0, 1, 1, 2, 2]
    assert c.total_capacity == 24 == spec.total_slots
    assert c.occupancy == 0
    assert all(p.is_open and p.free_slots == 4 for p in c.pools)


def test_cluster_spec_validation():
    with pytest.raises(ValueError):
        ClusterSpec(n_racks=0)
    with pytest.raises(ValueError):
        ClusterSpec(nodes_per_pool=-1)


def test_pool_capacity_enforced():
    c = build_cluster(1, 1, 2)
    p = c.pools[0]
    p.add(_job(0, 0.1))
    p.add(_job(1, 0.1))
    assert not p.is_open
    with pytest.raises(RuntimeError):
        p.add(_job(2, 0.1))


def test_pool_background_loi():
    c = build_cluster(1, 1, 4)
    p = c.pools[0]
    a, b = _job(0, 0.3), _job(1, 0.2)
    p.add(a)
    p.add(b)
    assert p.background_loi_for(a) == pytest.approx(b.injected_loi)
    assert p.total_injected_loi() == pytest.approx(
        a.injected_loi + b.injected_loi
    )
    np.testing.assert_allclose(
        p.background_lois(),
        [b.injected_loi, a.injected_loi], rtol=1e-12,
    )


# ------------------------------------------------------------- policies
def test_aware_separates_loud_from_sensitive():
    """Top-IC and top-sensitivity jobs must land on different pools while
    capacity allows (paper §7.2's whole point)."""
    cluster = build_cluster(1, 2, 2)
    pol = InterferenceAwarePolicy()
    loud = _job(0, 0.85)       # highest IC in the mix
    fragile = _job(1, 0.7)     # most sensitive in the mix
    p_loud = pol.select(loud, cluster, 0.0)
    p_loud.add(loud)
    p_fragile = pol.select(fragile, cluster, 0.0)
    assert p_fragile is not p_loud
    # ...but when only one pool exists, co-location is forced, not refused
    tight = build_cluster(1, 1, 2)
    tight.pools[0].add(loud)
    assert pol.select(fragile, tight, 0.0) is tight.pools[0]


def test_binpack_respects_corridor_budget():
    cluster = build_cluster(1, 4, 4)
    pol = CorridorBinPackPolicy(loi_budget=0.6)
    for i in range(6):
        j = _job(i, 0.25)
        p = pol.select(j, cluster, 0.0)
        p.add(j)
    aggs = [p.total_injected_loi() for p in cluster.pools]
    assert all(a <= 0.6 + 1e-9 for a in aggs)
    # best-fit consolidates: 6 jobs at 0.25 fit 2-per-pool in 3 pools
    assert sum(1 for p in cluster.pools if p.jobs) == 3


def test_policy_factory():
    for name in ("fcfs", "random", "aware", "binpack"):
        assert make_policy(name, seed=1).name == name
    with pytest.raises(ValueError):
        make_policy("clairvoyant")


# ------------------------------------------------------------ simulator
def test_conservation_invariants():
    """Every job placed exactly once, runs on one pool, capacity never
    exceeded, cluster fully drained."""
    jobs = synthetic_stream(300, seed=5)
    cluster = build_cluster(2, 2, 2)       # 8 slots -> backlog exercised
    res = simulate(jobs, cluster, make_policy("aware"))
    assert np.isfinite(res.start).all() and np.isfinite(res.finish).all()
    assert (res.pool_of >= 0).all() and (res.pool_of < 4).all()
    assert (res.start >= res.arrival - 1e-9).all()
    assert (res.finish > res.start).all()
    assert (res.slowdown >= 1.0 - 1e-9).all()
    assert (res.peak_occupancy <= [p.capacity for p in cluster.pools]).all()
    assert cluster.occupancy == 0


def test_simulator_deterministic():
    jobs = synthetic_stream(150, seed=9)
    r1 = simulate(jobs, build_cluster(2, 2, 2), make_policy("random", seed=4))
    r2 = simulate(jobs, build_cluster(2, 2, 2), make_policy("random", seed=4))
    np.testing.assert_array_equal(r1.pool_of, r2.pool_of)
    np.testing.assert_allclose(r1.finish, r2.finish, rtol=0, atol=0)


def test_no_contention_means_no_slowdown():
    """Jobs that never overlap run at isolated speed."""
    jobs = [_job(i, 0.5, arrival=100.0 * i, work=10.0) for i in range(5)]
    res = simulate(jobs, build_cluster(1, 1, 4), make_policy("fcfs"))
    np.testing.assert_allclose(res.slowdown, 1.0, rtol=1e-9)
    np.testing.assert_allclose(res.wait, 0.0, atol=1e-9)


def test_two_loud_jobs_slow_each_other():
    jobs = [_job(0, 0.5, 0.0, 10.0), _job(1, 0.5, 0.0, 10.0)]
    res = simulate(jobs, build_cluster(1, 1, 2), make_policy("fcfs"))
    expected = 1.0 / jobs[0].sensitivity(jobs[1].injected_loi)
    np.testing.assert_allclose(res.slowdown, expected, rtol=1e-6)


def test_aware_variance_not_worse_than_random():
    """Regression: on a fixed trace the aware policy's slowdown variance
    must not exceed the random baseline's (paper Fig 13 at rack scale)."""
    jobs = synthetic_stream(400, seed=7)
    res = run_policies(jobs, ClusterSpec(2, 2, 4),
                       policy_names=("random", "aware"), seed=3)
    var_aware = res["aware"].summary()["var_slowdown"]
    var_random = res["random"].summary()["var_slowdown"]
    assert var_aware <= var_random


def test_thousand_job_trace_is_fast():
    """Acceptance: a 1,000-job trace over >= 4 pools simulates in <10s."""
    jobs = synthetic_stream(1000, seed=3)
    t0 = time.perf_counter()
    simulate(jobs, build_cluster(2, 2, 4), make_policy("aware"))
    assert time.perf_counter() - t0 < 10.0


def test_rescale_load_hits_target_utilization():
    jobs = synthetic_stream(200, seed=1)
    rescale_load(jobs, total_slots=16, utilization=0.5)
    span = max(j.arrival for j in jobs)
    offered = sum(j.work for j in jobs) / (16 * span)
    assert offered == pytest.approx(0.5, rel=0.02)


def test_simulate_rejects_bad_input():
    with pytest.raises(ValueError):
        simulate([], build_cluster(1, 1, 1), make_policy("fcfs"))
    bad = [_job(0, 0.5, work=0.0)]
    with pytest.raises(ValueError):
        simulate(bad, build_cluster(1, 1, 1), make_policy("fcfs"))


# ------------------------------------------- vectorized interference math
def test_vectorized_sensitivity_matches_scalar():
    prof = profile_with_injected_loi(0.4)
    lois = np.linspace(0.0, 0.9, 16)
    vec = prof.sensitivity_vec(lois)
    scalar = np.array([prof.sensitivity(float(l)) for l in lois])
    np.testing.assert_allclose(vec, scalar, rtol=1e-12)


def test_progress_rates_match_sensitivity():
    profs = [profile_with_injected_loi(r) for r in (0.1, 0.4, 0.8)]
    inj = np.array([p.injected_loi() for p in profs])
    bg = itf.background_lois(inj)
    rates = itf.progress_rates(
        np.array([p.t_pool for p in profs]),
        np.array([p.t_local for p in profs]),
        np.array([p.t_compute for p in profs]),
        bg,
    )
    expected = np.array([p.sensitivity(float(b))
                         for p, b in zip(profs, bg)])
    np.testing.assert_allclose(rates, expected, rtol=1e-12)
    assert ((rates > 0.0) & (rates <= 1.0 + 1e-12)).all()
