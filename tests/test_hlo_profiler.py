"""The while-loop-aware HLO cost model (the roofline's data source)."""

import jax
import jax.numpy as jnp
import pytest

from repro.profiler.hlo import analyze_hlo


def _cost(fn, *args):
    return analyze_hlo(jax.jit(fn).lower(*args).compile().as_text())


def test_single_dot_flops():
    m = _cost(lambda a, b: a @ b, jnp.ones((128, 256)), jnp.ones((256, 64)))
    assert m.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.05)


def test_scan_trip_multiplier():
    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    x = jnp.ones((128, 128))
    ws = jnp.ones((16, 128, 128))
    m = _cost(f, x, ws)
    assert m.flops == pytest.approx(2 * 128**3 * 16, rel=0.05)
    assert not m.warnings


def test_nested_scan():
    def f(x, ws):
        def outer(c, _):
            c2 = jax.lax.scan(
                lambda cc, w: (jnp.tanh(cc @ w), None), c, ws
            )[0]
            return c2, None

        return jax.lax.scan(outer, x, None, length=3)[0]

    x = jnp.ones((64, 64))
    ws = jnp.ones((4, 64, 64))
    m = _cost(f, x, ws)
    assert m.flops == pytest.approx(2 * 64**3 * 4 * 3, rel=0.05)


def test_scan_hbm_not_quadratic():
    """dynamic-slice inside the loop must count the slice, not the stack."""
    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    x = jnp.ones((128, 128))
    small = _cost(f, x, jnp.ones((4, 128, 128)))
    big = _cost(f, x, jnp.ones((64, 128, 128)))
    # HBM bytes must scale ~linearly with depth (16x), not quadratically
    ratio = big.hbm_bytes / small.hbm_bytes
    assert ratio < 30, ratio


def test_grad_flops_about_3x():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    w = jnp.ones((256, 256))
    x = jnp.ones((64, 256))
    fwd = _cost(loss, w, x)
    bwd = _cost(jax.grad(loss), w, x)
    assert 1.8 < bwd.flops / fwd.flops < 4.0


def test_vmem_scope_excluded():
    """flash_vmem-scoped fp32 score tiles must not hit the HBM model."""
    from repro.kernels.flash_attention.chunked import mha_chunked

    q = jnp.ones((1, 1024, 4, 64), jnp.bfloat16)
    k = jnp.ones((1, 1024, 2, 64), jnp.bfloat16)
    m = _cost(lambda q, k, v: mha_chunked(q, k, v, True, None, 0, 256, 256),
              q, k, k)
    # naive S^2 scores would be 4*1024^2*4heads*4B = 67 MB *read+write;
    # kernel traffic is ~q+k+v+o + K/V reruns = low single-digit MB
    assert m.hbm_bytes < 3e7, m.hbm_bytes
    assert m.flops > 2 * 1024 * 1024 * 4 * 64  # scores still counted
