"""Level-2 placement: policy semantics + hypothesis property tests on the
system's invariants."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import placement as plc
from repro.core import tiers as tr
from repro.core.access import TensorAccess


def mk_profile(entries):
    return [TensorAccess(f"t{i}", b, t, "param")
            for i, (b, t) in enumerate(entries)]


@pytest.fixture
def topo():
    return tr.emulated(0.5, 4000)


def test_all_local(topo):
    p = plc.place(mk_profile([(1000, 5), (3000, 1)]), topo, "all_local")
    assert p.pool_bytes == 0
    assert p.r_access_pool == 0
    assert p.slowdown == 1.0


def test_first_touch_spills_in_order(topo):
    # local cap = 0.5 * 4000 = 2000 -> first two fit, rest spill
    prof = mk_profile([(1000, 1), (1000, 1), (1000, 9), (1000, 9)])
    p = plc.place(prof, topo, "first_touch", 0.5)
    assert p.assignment["t0"] == "hbm" and p.assignment["t1"] == "hbm"
    assert p.assignment["t2"] == "host" and p.assignment["t3"] == "host"
    assert p.r_access_pool == 0.9


def test_hotness_keeps_hot_local(topo):
    prof = mk_profile([(1000, 1), (1000, 1), (1000, 9), (1000, 9)])
    p = plc.place(prof, topo, "hotness", 0.5)
    assert p.assignment["t2"] == "hbm" and p.assignment["t3"] == "hbm"
    assert p.r_access_pool == 0.1
    # the paper's BFS case study: hotness strictly beats first-touch
    ft = plc.place(prof, topo, "first_touch", 0.5)
    assert p.t_memory < ft.t_memory


def test_corridor_check(topo):
    prof = mk_profile([(1000, 9), (1000, 1)])
    p = plc.place(prof, topo, "hotness", 0.5)
    c = plc.corridor_check(p)
    assert c["r_cap_pool"] == 0.5
    assert 0 <= c["r_access_pool"] <= 1


profiles = st.lists(
    st.tuples(
        st.integers(min_value=1, max_value=10**9),   # bytes
        st.floats(min_value=0.01, max_value=100.0),  # touches
    ),
    min_size=1,
    max_size=40,
)
fractions = st.floats(min_value=0.05, max_value=0.95)


@given(profiles, fractions)
@settings(max_examples=150, deadline=None)
def test_capacity_invariant(entries, f):
    """No policy may overfill the emulated local tier."""
    prof = mk_profile(entries)
    total = sum(a.bytes for a in prof)
    topo = tr.emulated(f, total)
    for policy in ("first_touch", "hotness", "balanced_bw", "capacity"):
        p = plc.place(prof, topo, policy, f)
        assert p.local_bytes <= (1 - f) * total + 1e-6
        assert p.local_bytes + p.pool_bytes == total
        assert 0.0 <= p.r_access_pool <= 1.0


equal_byte_profiles = st.tuples(
    st.integers(min_value=1, max_value=10**7),
    st.lists(st.floats(min_value=0.01, max_value=100.0), min_size=1,
             max_size=40),
)


@given(equal_byte_profiles, fractions)
@settings(max_examples=150, deadline=None)
def test_hotness_optimal_equal_sizes(sizes_touches, f):
    """With equal tensor sizes the greedy hotness order IS the knapsack
    optimum, so it must beat (or tie) first-touch. (With unequal sizes the
    problem is the paper's NP-complete knapsack and greedy is a heuristic.)
    """
    b, touches = sizes_touches
    prof = mk_profile([(b, t) for t in touches])
    total = sum(a.bytes for a in prof)
    topo = tr.emulated(f, total)
    hot = plc.place(prof, topo, "hotness", f)
    ft = plc.place(prof, topo, "first_touch", f)
    assert hot.pool_traffic <= ft.pool_traffic + 1e-6


@given(profiles, fractions)
@settings(max_examples=100, deadline=None)
def test_placement_deterministic(entries, f):
    prof = mk_profile(entries)
    total = sum(a.bytes for a in prof)
    topo = tr.emulated(f, total)
    p1 = plc.place(prof, topo, "hotness", f)
    p2 = plc.place(prof, topo, "hotness", f)
    assert p1.assignment == p2.assignment


def test_balanced_bw_leaves_traffic_on_pool():
    """When hotness would park ~all traffic in HBM, balanced_bw keeps the
    pool link fed at >= R_bw (the paper's tiers-ADD-bandwidth point)."""
    prof = mk_profile([(100, 10)] * 10 + [(10**6, 0.01)] * 2)
    total = sum(a.bytes for a in prof)
    topo = tr.emulated(0.4, total)
    bal = plc.place(prof, topo, "balanced_bw", 0.4)
    assert bal.r_access_pool >= bal.r_bw_pool - 1e-9


def test_multi_tier_roofline_math():
    from repro.core import roofline as rl

    # balanced access attains the sum of bandwidths
    b = rl.multi_tier_bandwidth([0.98, 0.02], [98.0, 2.0])
    assert abs(b - 100.0) < 1e-9
    # all-local attains only the local tier
    assert abs(rl.multi_tier_bandwidth([1.0, 0.0], [98.0, 2.0]) - 98.0) < 1e-9
    # pool-heavy collapses towards the pool link
    assert rl.multi_tier_bandwidth([0.5, 0.5], [98.0, 2.0]) == pytest.approx(
        4.0
    )
