"""Optimizer, schedules, gradient compression, data pipeline, checkpoint."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import PrefetchPipeline
from repro.data.synthetic import SyntheticLM
from repro.optim import adamw as aw
from repro.optim import compress
from repro.optim.schedule import warmup_cosine


# ---------------------------------------------------------------- adamw
def test_adamw_step_math():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 0.5)}
    cfg = aw.AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    st = aw.adamw_init(params)
    new_p, st = aw.adamw_update(grads, st, params, 0.1, cfg)
    # first step: mhat = g, vhat = g^2 -> step = g/|g| = 1
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.ones(4) - 0.1, rtol=1e-5)
    assert int(st["count"]) == 1


def test_adamw_weight_decay():
    params = {"w": jnp.full((2,), 2.0)}
    grads = {"w": jnp.zeros((2,))}
    cfg = aw.AdamWConfig(weight_decay=0.1)
    st = aw.adamw_init(params)
    new_p, _ = aw.adamw_update(grads, st, params, 0.5, cfg)
    np.testing.assert_allclose(np.asarray(new_p["w"]), 2.0 - 0.5 * 0.1 * 2.0,
                               rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    clipped, norm = aw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(3 * 16 + 4 * 9))
    cn = aw.global_norm(clipped)
    assert float(cn) == pytest.approx(1.0, rel=1e-5)
    # under the limit: untouched
    clipped2, _ = aw.clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), np.asarray(g["a"]))


def test_warmup_cosine():
    lr0 = float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10,
                              total_steps=100))
    lr10 = float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10,
                               total_steps=100))
    lr100 = float(warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    assert lr0 == pytest.approx(0.1)  # warms from step 1: never a no-op
    assert lr10 == pytest.approx(1.0)
    assert lr100 == pytest.approx(0.1)  # floor
    assert float(warmup_cosine(55, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) < lr10


# ----------------------------------------------------------- compression
def test_quantize_roundtrip_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (1024,))
    q, s = compress.quantize(g)
    err = jnp.abs(compress.dequantize(q, s) - g).max()
    assert float(err) <= float(s) / 2 + 1e-7
    assert q.dtype == jnp.int8


def test_error_feedback_accumulates():
    """With error feedback, the long-run mean of compressed grads converges
    to the true mean (unbiased in the time-average)."""
    g = jnp.full((256,), 1e-3)  # small, heavily quantized
    e = jnp.zeros((256,))
    total = jnp.zeros((256,))
    for _ in range(50):
        gi = g + e
        q, s = compress.quantize(gi)
        deq = compress.dequantize(q, s)
        e = gi - deq
        total = total + deq
    mean = total / 50
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g), rtol=0.05)


# ------------------------------------------------------------------ data
def test_synthetic_deterministic():
    ds = SyntheticLM(vocab_size=128, seq_len=32, global_batch=4, seed=3)
    b1 = ds.batch_at(7)
    b2 = ds.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch_at(8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert b1["tokens"].shape == (4, 33)
    assert int(b1["tokens"].max()) < 128


def test_pipeline_order_and_skip():
    ds = SyntheticLM(vocab_size=64, seq_len=8, global_batch=2, seed=0)
    pipe = PrefetchPipeline(ds.batch_at, start_step=5, depth=2)
    try:
        s, b = pipe.get()
        assert s == 5
        s, _ = pipe.get()
        assert s == 6
        pipe.skip_to(100)
        # drain whatever was in flight, then see 100+
        seen = [pipe.get()[0] for _ in range(4)]
        assert max(seen) >= 100
        assert sorted(seen)[-2:] == list(range(sorted(seen)[-2],
                                               sorted(seen)[-2] + 2))
    finally:
        pipe.close()


# ------------------------------------------------------------ checkpoint
@pytest.fixture
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def _state(x=1.0):
    return {
        "step": jnp.asarray(3),
        "params": {"w": jnp.full((4, 4), x), "b": jnp.arange(4.0)},
        "opt": {"m": {"w": jnp.zeros((4, 4)), "b": jnp.zeros(4)}},
    }


def test_checkpoint_roundtrip(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    st = _state(2.5)
    mgr.save(10, st, blocking=True)
    assert mgr.latest_step() == 10
    restored = mgr.restore(10, jax.tree.map(jnp.zeros_like, st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_corruption_detected(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    st = _state()
    mgr.save(5, st, blocking=True)
    # corrupt the arrays file
    path = os.path.join(ckpt_dir, "step_00000005", "arrays.npz")
    data = dict(np.load(path))
    data["a0"] = data["a0"] + 1
    np.savez(path, **data)
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(5, st)


def test_checkpoint_async_then_wait(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(1, _state())          # non-blocking
    mgr.wait()
    assert mgr.latest_step() == 1


def test_elastic_restore_structure_check(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(2, _state(), blocking=True)
    # leaf names absent from the checkpoint must raise
    bad = {"params": {"not_a_param": jnp.zeros((4, 4))}}
    with pytest.raises(KeyError):
        mgr.restore(2, bad)
    # partial restore (a subtree) is allowed — elastic re-shard relies on it
    sub = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros(4)}}
    out = mgr.restore(2, sub)
    np.testing.assert_array_equal(np.asarray(out["params"]["b"]),
                                  np.arange(4.0))


# ------------------------------------------------------------ prefetch
def test_scan_with_prefetch_matches_plain_scan():
    from repro.prefetch.static import scan_with_prefetch

    L, d = 6, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (L, d, d))
    bs = jax.random.normal(jax.random.PRNGKey(1), (L, d))
    x0 = jnp.ones((d,))

    def body(x, layer):
        w, b = layer["w"], layer["b"]
        y = jnp.tanh(x @ w + b)
        return y, y.sum()

    stacked = {"w": ws, "b": bs}
    mask = {"w": True, "b": False}
    y1, outs1 = scan_with_prefetch(body, x0, stacked, mask, L)
    y2, outs2 = jax.lax.scan(body, x0, stacked)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs1), np.asarray(outs2),
                               rtol=1e-4, atol=1e-5)


def test_scan_with_prefetch_jits():
    from repro.prefetch.static import scan_with_prefetch

    L, d = 4, 8
    stacked = {"w": jnp.ones((L, d, d))}

    def body(x, layer):
        return x @ layer["w"], None

    f = jax.jit(lambda x: scan_with_prefetch(
        body, x, stacked, {"w": True}, L)[0])
    out = f(jnp.ones((d,)))
    assert bool(jnp.isfinite(out).all())
