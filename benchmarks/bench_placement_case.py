"""Paper Fig 12 / case study 1 (the BFS optimization, mapped): on the
kimi-k2 1T MoE serving cell with 75% pool capacity, compare placement
policies. first_touch (allocation order, the Linux-default analogue) parks
hot attention/router tensors on the pool; hotness (the paper's
allocate-hottest-first fix) moves them to HBM; the paper's two reported
effects — remote access ratio down, interference sensitivity down — must
both reproduce."""

from __future__ import annotations

from repro.core.quantify import analyze
from benchmarks.common import emit, timed


def run():
    rows = []
    for arch, shape, frac in (
        ("kimi_k2_1t_a32b", "decode_32k", 0.75),
        ("kimi_k2_1t_a32b", "decode_32k", 0.5),
        ("granite_moe_1b_a400m", "decode_32k", 0.75),
    ):
        def case():
            out = {}
            for pol in ("first_touch", "hotness", "balanced_bw"):
                a = analyze(arch, shape, policy=pol, pool_fraction=frac,
                            use_dryrun=True)
                out[pol] = {
                    "r_access": a.level2["r_access_pool"],
                    "t_mem": a.level2["t_memory_s"],
                    "sens50": a.level3["sensitivity"]["loi_50"],
                }
            return out

        out, us = timed(case, repeats=1)
        ft, hot = out["first_touch"], out["hotness"]
        remote_cut = (ft["r_access"] - hot["r_access"]) / max(
            ft["r_access"], 1e-9
        )
        speedup = ft["t_mem"] / max(hot["t_mem"], 1e-12)
        emit(
            f"fig12_case1_{arch}_{int(frac * 100)}", us,
            f"Racc {ft['r_access']:.2f}->{hot['r_access']:.2f} "
            f"(-{100 * remote_cut:.0f}%) mem_speedup={speedup:.2f}x "
            f"sens50 {ft['sens50']:.3f}->{hot['sens50']:.3f}",
        )
        rows.append({"arch": arch, "frac": frac, "policies": out,
                     "remote_cut": remote_cut, "speedup": speedup})
    return rows
