"""Small-mesh dry-run sweep — keeps `results/dryrun/` records fresh.

Runs `repro.launch.dryrun` for a small arch x shape subset on a 4x4
emulated mesh (16 host-platform devices) in a subprocess (the dry-run must
set XLA_FLAGS before jax initializes, so it cannot run in-process), then
summarizes the regenerated records. Wired into `benchmarks/run.py` (tag
`dryrun`) and the CI benchmark job, which uploads the JSON records as
artifacts — closing the ROADMAP item about records going stale.

`BENCH_SMOKE=1` narrows the sweep to one cell.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

CELLS = [
    ("smollm-360m", "decode_32k"),
    ("smollm-360m", "prefill_32k"),
    ("mamba2-780m", "decode_32k"),
]
SMOKE_CELLS = CELLS[:1]
MESH = "4x4"
OUTDIR = "results/dryrun"


def run():
    smoke = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
    cells = SMOKE_CELLS if smoke else CELLS
    rows = []
    for arch, shape in cells:
        env = dict(os.environ, REPRO_DEVICES="16")
        env.setdefault("PYTHONPATH", "src")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", arch, "--shape", shape, "--mesh", MESH,
             "--outdir", OUTDIR],
            env=env, capture_output=True, text=True,
        )
        name = arch.replace("-", "_")
        rec_path = os.path.join(OUTDIR, f"{name}_{shape}_{MESH}.json")
        rec = None
        if os.path.exists(rec_path):
            with open(rec_path) as f:
                rec = json.load(f)
        ok = (proc.returncode == 0 and rec is not None
              and rec.get("status") == "ok")
        derived = f"status={'ok' if ok else 'fail'}"
        if rec and rec.get("status") == "ok":
            ro = rec["roofline"]
            derived += (
                f" dominant={ro['dominant']}"
                f" t_compute={ro['t_compute_s']:.2e}"
                f" t_memory={ro['t_memory_s']:.2e}"
                f" compile_s={rec.get('compile_s')}"
            )
        elif not ok:
            tail = (proc.stderr or proc.stdout or "").strip().splitlines()
            derived += f" err={tail[-1][:120] if tail else 'no-output'}"
        emit(f"dryrun_{name}_{shape}", 1e6 * (rec or {}).get("wall_s", 0.0),
             derived)
        rows.append({"arch": name, "shape": shape, "mesh": MESH, "ok": ok,
                     "record": rec_path})
        if not ok:
            raise RuntimeError(
                f"dry-run cell {arch} x {shape} failed: see {rec_path}"
            )
    return rows
