"""Paper Fig 11: (left) LoI scales linearly with configured intensity;
(middle) raw-counter bandwidth saturates at the link while LBench's IC keeps
resolving contention; (right) per-app interference coefficient. Also times
the actual Pallas LBench kernel (interpret mode) per NFLOP setting."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import interference as itf
from repro.core import tiers as tr
from repro.core.quantify import analyze
from repro.kernels.lbench import ref as lref
from repro.kernels.lbench.lbench import lbench_pallas
from benchmarks.common import emit, timed


def run():
    rows = []
    topo = tr.v5e_topology()

    # left panel: LoI vs configured intensity + kernel timing
    a = jax.random.normal(jax.random.PRNGKey(0), (1 << 16,), jnp.float32)
    for nflop in (1, 2, 4, 8, 16, 32):
        out, us = timed(
            lambda: jax.block_until_ready(
                lbench_pallas(a, nflop, interpret=True)
            ),
            repeats=2,
        )
        loi = itf.lbench_loi(nflop, a.size, topo)
        flops = lref.flops(a.size, nflop)
        emit(
            f"fig11_lbench_nflop{nflop}", us,
            f"loi={loi:.3f} ai={nflop / 8:.3f}flop/B kernel_flops={flops}",
        )
        rows.append({"nflop": nflop, "loi": loi, "us": us})

    # middle panel: PCM saturation vs LBench IC
    sweep = itf.lbench_intensity_sweep(topo)
    for r in sweep:
        emit(
            f"fig11_saturation_nflop{r['nflop']}", 0.0,
            f"pcm_bw={r['pcm_bw'] / 1e9:.1f}GB/s ic={r['ic']:.2f}",
        )

    # right panel: per-app IC (decode workloads on 50% pooling)
    for arch in configs.list_archs():
        def one():
            an = analyze(arch, "decode_32k", policy="hotness",
                         pool_fraction="auto", use_dryrun=True)
            return an.level3["interference_coefficient"], \
                an.level3["injected_loi"]

        (ic, inj), us = timed(one, repeats=1)
        emit(f"fig11_ic_{arch}", us, f"ic={ic:.3f} injected_loi={inj:.3f}")
        rows.append({"arch": arch, "ic": ic})
    return rows
