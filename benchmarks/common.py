"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import time


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Returns (result, mean_us)."""
    fn(*args, **kwargs)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def emit(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line
