"""Scheduler case study (paper §7.2), two scenarios.

1. `fig13_sched_*` — the paper's Fig 13 per-workload Monte-Carlo: 100 runs
   against a background whose LoI resamples every 60 steps, random (0-50%)
   vs interference-aware (0-20%). Mean speedup / p75 cut must track each
   workload's sensitivity (Hypre-benefits-most / XSBench-flat).

2. `rack_trace_*` — the rack-scale event-driven simulator: a 1,000-job
   synthetic trace over a 2x2x4 cluster (4 pools, 16 slots), FCFS /
   random / aware / corridor-binpack. The aware policy must show strictly
   lower slowdown variance than the random baseline (`aware_var_lower=True`
   in the comparison row), and the whole trace must simulate in seconds.
"""

from __future__ import annotations

import numpy as np

from repro import configs
from repro.core.quantify import analyze
from repro.sched import (
    ClusterSpec,
    Job,
    make_policy,
    simulate,
    simulate_colocation,
    synthetic_stream,
)
from repro.sched.cluster import Cluster
from repro.sched.scheduler import five_number_summary
from benchmarks.common import emit, timed


def run_fig13():
    rows = []
    for arch in configs.list_archs():
        def case():
            a = analyze(arch, "decode_32k", policy="hotness",
                        pool_fraction="auto", use_dryrun=True)
            job = Job(arch, a.profile, steps=240)
            base = simulate_colocation(job, 100, loi_range=(0.0, 0.5),
                                       seed=7)
            aware = simulate_colocation(job, 100, loi_range=(0.0, 0.2),
                                        seed=7)
            return five_number_summary(base), five_number_summary(aware)

        (sb, sa), us = timed(case, repeats=1)
        mean_speedup = (sb["mean"] - sa["mean"]) / sb["mean"]
        p75_cut = (sb["p75"] - sa["p75"]) / sb["p75"]
        emit(
            f"fig13_sched_{arch}", us,
            f"mean_speedup={100 * mean_speedup:.1f}% "
            f"p75_cut={100 * p75_cut:.1f}% "
            f"iqr_base={sb['p75'] - sb['p25']:.2e} "
            f"iqr_aware={sa['p75'] - sa['p25']:.2e}",
        )
        rows.append({"arch": arch, "mean_speedup": mean_speedup,
                     "p75_cut": p75_cut})
    return rows


def run_rack_trace(n_jobs: int = 1000, seed: int = 3):
    jobs = synthetic_stream(n_jobs, seed=seed)
    spec = ClusterSpec(n_racks=2, pools_per_rack=2, nodes_per_pool=4)
    rows = []
    summaries = {}
    for name in ("fcfs", "random", "aware", "binpack"):
        def case():
            return simulate(jobs, Cluster.build(spec),
                            make_policy(name, seed=11))

        result, us = timed(case, repeats=1)
        s = result.summary()
        summaries[name] = s
        emit(
            f"rack_trace_{name}", us,
            f"n_jobs={n_jobs} pools={spec.n_pools} "
            f"mean_slowdown={s['mean_slowdown']:.3f} "
            f"var_slowdown={s['var_slowdown']:.4f} "
            f"p95_slowdown={s['p95_slowdown']:.3f} "
            f"mean_wait_s={s['mean_wait_s']:.1f} "
            f"makespan_s={s['makespan_s']:.0f}",
        )
        rows.append({"policy": name, **s})

    var_aware = summaries["aware"]["var_slowdown"]
    var_random = summaries["random"]["var_slowdown"]
    emit(
        "rack_trace_aware_vs_random", 0.0,
        f"var_aware={var_aware:.4f} var_random={var_random:.4f} "
        f"aware_var_lower={var_aware < var_random} "
        f"var_cut={100 * (var_random - var_aware) / var_random:.1f}%",
    )
    return rows


def run():
    return run_fig13() + run_rack_trace()
