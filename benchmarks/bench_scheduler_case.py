"""Paper Fig 13 / case study 2: 100 runs of each workload with co-located
background whose LoI resamples every 60 steps — random scheduler (LoI
0-50%) vs interference-aware (LoI 0-20%). Reports mean speedup and p75
variability reduction, which must track each workload's sensitivity (the
paper's Hypre-benefits-most / XSBench-flat result)."""

from __future__ import annotations

import numpy as np

from repro import configs
from repro.core.quantify import analyze
from repro.sched import Job, simulate_colocation
from repro.sched.scheduler import five_number_summary
from benchmarks.common import emit, timed


def run():
    rows = []
    for arch in configs.list_archs():
        def case():
            a = analyze(arch, "decode_32k", policy="hotness",
                        pool_fraction="auto", use_dryrun=True)
            job = Job(arch, a.profile, steps=240)
            base = simulate_colocation(job, 100, loi_range=(0.0, 0.5),
                                       seed=7)
            aware = simulate_colocation(job, 100, loi_range=(0.0, 0.2),
                                        seed=7)
            return five_number_summary(base), five_number_summary(aware)

        (sb, sa), us = timed(case, repeats=1)
        mean_speedup = (sb["mean"] - sa["mean"]) / sb["mean"]
        p75_cut = (sb["p75"] - sa["p75"]) / sb["p75"]
        emit(
            f"fig13_sched_{arch}", us,
            f"mean_speedup={100 * mean_speedup:.1f}% "
            f"p75_cut={100 * p75_cut:.1f}% "
            f"iqr_base={sb['p75'] - sb['p25']:.2e} "
            f"iqr_aware={sa['p75'] - sa['p25']:.2e}",
        )
        rows.append({"arch": arch, "mean_speedup": mean_speedup,
                     "p75_cut": p75_cut})
    return rows
