"""Paper Table 1 analogue: memory configuration + estimated cost per arch on
the production system (256 x v5e + host pool), HBM at 3-5x DDR unit price.
"""

from __future__ import annotations

from repro import configs
from repro.common import hw
from repro.core import tiers as tr
from benchmarks.common import emit, timed

DDR_PER_GB = 3.0          # $/GB (order-of-magnitude, as in the paper)
HBM_MULT = (3.0, 5.0)


def run():
    topo = tr.v5e_topology()
    n_chips = 256
    rows = []

    def table():
        out = []
        for arch in configs.list_archs():
            cfg = configs.get(arch)
            # training state: fp32 master + 2 moments (+bf16 compute copies
            # are transient)
            state_gb = cfg.param_count() * 12 / 2**30
            hbm_total = n_chips * hw.V5E.hbm_bytes / 2**30
            pool_total = (
                n_chips / topo.chips_per_pool * hw.V5E_HOST.dram_bytes / 2**30
            )
            fits_hbm = state_gb <= hbm_total
            hbm_cost = hbm_total * DDR_PER_GB * HBM_MULT[0], \
                hbm_total * DDR_PER_GB * HBM_MULT[1]
            pool_cost = pool_total * DDR_PER_GB
            out.append({
                "arch": arch,
                "train_state_gb": round(state_gb, 1),
                "hbm_gb": hbm_total,
                "pool_gb": pool_total,
                "fits_hbm_alone": fits_hbm,
                "hbm_cost_usd": f"{hbm_cost[0]:.0f}-{hbm_cost[1]:.0f}",
                "pool_cost_usd": round(pool_cost),
            })
        return out

    out, us = timed(table, repeats=1)
    for r in out:
        emit(
            f"table1_memcost_{r['arch']}", us / len(out),
            f"state={r['train_state_gb']}GB "
            f"fits_hbm={r['fits_hbm_alone']} "
            f"hbm$={r['hbm_cost_usd']} pool$={r['pool_cost_usd']}",
        )
        rows.append(r)
    return rows
