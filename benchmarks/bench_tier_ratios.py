"""Paper Fig 9: remote (pool) access ratio vs the R_cap / R_bw reference
lines at 25 / 50 / 75% pool capacity, per arch, train + decode phases."""

from __future__ import annotations

from repro import configs
from repro.core.quantify import analyze
from benchmarks.common import emit, timed


def run():
    rows = []
    for arch in configs.list_archs():
        for shape in ("train_4k", "decode_32k"):
            parts = []

            def sweep():
                out = []
                for f in (0.25, 0.5, 0.75):
                    a = analyze(arch, shape, policy="first_touch",
                                pool_fraction=f, use_dryrun=True)
                    out.append((f, a.level2["r_access_pool"],
                                a.level2["r_cap_pool"],
                                a.level2["r_bw_pool"],
                                a.level2["in_corridor"]))
                return out

            out, us = timed(sweep, repeats=1)
            for f, racc, rcap, rbw, ok in out:
                parts.append(f"{int(f * 100)}%:Racc={racc:.2f}")
            emit(
                f"fig9_ratios_{arch}_{shape}", us,
                " ".join(parts) + f" Rbw={out[0][3]:.3f}",
            )
            rows.append({"arch": arch, "shape": shape, "sweep": out})
    return rows
