"""Paper Fig 9: remote (pool) access ratio vs the R_cap / R_bw reference
lines at 25 / 50 / 75% pool capacity, per arch, train + decode phases.

Also home of :func:`substrate_transfer_row` — the physical-substrate
analogue of the Fig-9 byte accounting: where `analyze()` derives pool
traffic from the closed-form model, the substrate row reports bytes
MEASURED off the `TierSubstrate` transfer ledger of a live serving run
(`bench_serving` runs the engine and emits the row into
BENCH_serve.json, where the regression gate picks it up)."""

from __future__ import annotations

from repro import configs
from repro.core.quantify import analyze
from benchmarks.common import emit, timed


def substrate_transfer_row(engine, stats, tag="serve_substrate"):
    """BENCH row for one serving run's physical-substrate traffic.

    `transfer_bytes` sums the measured page_out/page_in/handoff stream
    bytes (drop streams move nothing); `placement_gap` is the absolute
    difference between the pager's derived pool footprint and the
    ledger's measured placement — the tentpole contract, so the gate
    pins it at 0.
    """
    sub = engine.substrate
    if sub is None:
        return {"tag": tag, "mode": "off", "transfer_bytes": 0.0,
                "placement_gap": 0.0}
    sub.sync()
    c = sub.counters()
    xfer = (c["page_out_bytes"] + c["page_in_bytes"]
            + c["handoff_bytes"])
    gap = abs(engine.pager.pool_bytes_used() - c["placement_bytes"])
    emit(
        tag, 0.0,
        f"mode={c['mode']} transfer_bytes={xfer:.0f} "
        f"page_out={c['page_out_pages']} page_in={c['page_in_pages']} "
        f"drop={c['drop_pages']} page_bytes={sub.page_bytes:.0f} "
        f"placement_gap={gap:.1f} in_flight={c['in_flight']} "
        f"tokens={stats.tokens}",
    )
    return {
        "tag": tag,
        "mode": c["mode"],
        "transfer_bytes": float(xfer),
        "page_out_bytes": float(c["page_out_bytes"]),
        "page_in_bytes": float(c["page_in_bytes"]),
        "page_out_pages": int(c["page_out_pages"]),
        "page_in_pages": int(c["page_in_pages"]),
        "drop_pages": int(c["drop_pages"]),
        "page_bytes": float(sub.page_bytes),
        "placement_gap": float(gap),
        "tokens": int(stats.tokens),
    }


def run():
    rows = []
    for arch in configs.list_archs():
        for shape in ("train_4k", "decode_32k"):
            parts = []

            def sweep():
                out = []
                for f in (0.25, 0.5, 0.75):
                    a = analyze(arch, shape, policy="first_touch",
                                pool_fraction=f, use_dryrun=True)
                    out.append((f, a.level2["r_access_pool"],
                                a.level2["r_cap_pool"],
                                a.level2["r_bw_pool"],
                                a.level2["in_corridor"]))
                return out

            out, us = timed(sweep, repeats=1)
            for f, racc, rcap, rbw, ok in out:
                parts.append(f"{int(f * 100)}%:Racc={racc:.2f}")
            emit(
                f"fig9_ratios_{arch}_{shape}", us,
                " ".join(parts) + f" Rbw={out[0][3]:.3f}",
            )
            rows.append({"arch": arch, "shape": shape, "sweep": out})
    return rows
