"""Pager churn + shared-prefix dedup benchmark (BENCH_pager.json).

Three lanes over the refcounting page allocator (`serving.kv_pager`) and
the shared-prefix radix cache (`serving.prefix_cache`):

  pager_churn       — pure-allocator stress: bursty admit/extend/release/
                      step cycles over a fixed pool. Reports alloc and
                      release latency (us per call over whole bursts) and
                      free-list FRAGMENTATION = 1 - largest contiguous
                      free-page-id run / free pages. The acceptance
                      asserts the peak mid-churn fragmentation stays
                      bounded AND that a full drain restores the single
                      zero-fragmentation run — a leaked or double-freed
                      page would break the run (the PR-5 order-preserving
                      batched release, now refcount-aware).
  pager_shared      — pager + radix trie over `shared_prefix_stream`
                      token streams (no model): shared-prefix hit rate,
                      trie match latency, and the deduplicated footprint
                      cross-checked EXACTLY against the closed form
                      `core.access.kv_dedup_token_bytes`.
  pager_prefix_chat — full engine, chat lane behind one shared system
                      prompt, prefix cache ON vs OFF on an identical
                      all-at-once trace (equal admission schedule). The
                      acceptance asserts token parity, >= 30% lower pool
                      bytes per token, and >= 0.95x virtual tokens/s.

`BENCH_SMOKE=1` (set by `benchmarks/run.py --smoke`, the CI lane) shrinks
op counts; shapes and code paths stay identical.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.core.access import kv_dedup_token_bytes
from repro.serving import (
    EngineConfig,
    KVPager,
    PagerConfig,
    PrefixCache,
    ServingEngine,
    shared_prefix_stream,
)
from benchmarks.common import emit

ARCH = "smollm_360m"

# peak mid-churn free-list fragmentation the allocator may reach under
# the deterministic bursty trace below (measured 0.757 smoke / 0.806
# full; drained fragmentation must be exactly 0 — page-granular
# allocation never needs contiguity, so the bound documents free-list
# scatter, while the drain check is the leak/double-free gate)
FRAG_BOUND = 0.85
# prefix cache ON must move <= this ratio of OFF's pool bytes per token
# on the shared-system-prompt chat lane (the >= 30% dedup cut)
DEDUP_CUT = 0.70


def _smoke(smoke):
    if smoke is None:
        return os.environ.get("BENCH_SMOKE", "") not in ("", "0")
    return smoke


def _fragmentation(p: KVPager) -> float:
    """1 - (largest contiguous free-page-id run) / free pages."""
    free = np.sort(np.asarray(list(p._free_phys), dtype=np.int64))
    if free.size == 0:
        return 0.0
    runs = np.split(free, np.nonzero(np.diff(free) != 1)[0] + 1)
    return 1.0 - max(len(r) for r in runs) / free.size


# ------------------------------------------------------------ lane 1
def run_churn(smoke=None):
    n_rounds = 40 if _smoke(smoke) else 200
    pcfg = PagerConfig(page_tokens=16, local_budget_bytes=64 * 16 * 100.0,
                       policy="hotness", hot_window=32, cold_touch=0.05)
    p = KVPager(8, 256, bytes_per_token=100.0, resident_bytes=0.0,
                pcfg=pcfg)
    rng = np.random.default_rng(17)
    alloc_s = release_s = 0.0
    n_alloc = n_release = 0
    frag_peak = 0.0
    for _ in range(n_rounds):
        # burst: fill every slot with a mixed-length prompt
        lens = rng.integers(16, 257, size=p.n_slots)
        t0 = time.perf_counter()
        for s in range(p.n_slots):
            p.admit(s, int(lens[s]))
        alloc_s += time.perf_counter() - t0
        n_alloc += p.n_slots
        # decode a few steps (tail growth + rebalance churn)
        for _ in range(4):
            p.step((p.lengths > 0) & (p.lengths < p.max_seq))
        frag_peak = max(frag_peak, _fragmentation(p))
        # drain a random subset out of admission order (free-list holes)
        victims = rng.permutation(p.n_slots)[: int(rng.integers(3, 7))]
        t0 = time.perf_counter()
        for s in victims:
            p.release(int(s))
        release_s += time.perf_counter() - t0
        n_release += len(victims)
        frag_peak = max(frag_peak, _fragmentation(p))
    for s in range(p.n_slots):
        p.release(s)
    frag_drained = _fragmentation(p)
    alloc_us = 1e6 * alloc_s / max(n_alloc, 1)
    release_us = 1e6 * release_s / max(n_release, 1)
    emit(
        "pager_churn", alloc_us,
        f"alloc_us={alloc_us:.1f} release_us={release_us:.1f} "
        f"frag_peak={frag_peak:.3f} frag_drained={frag_drained:.3f} "
        f"rounds={n_rounds}",
    )
    assert frag_drained == 0.0, (
        "drain must restore one contiguous free run (leak/double-free)"
    )
    assert frag_peak <= FRAG_BOUND, (
        f"mid-churn fragmentation {frag_peak:.3f} exceeds {FRAG_BOUND}"
    )
    return [{
        "tag": "pager_churn",
        "alloc_us": float(alloc_us),
        "release_us": float(release_us),
        "fragmentation": float(frag_peak),
        "frag_drained": float(frag_drained),
        "rounds": int(n_rounds),
    }]


# ------------------------------------------------------------ lane 2
def run_shared(smoke=None):
    n = 16 if _smoke(smoke) else 64
    P, system, bucket = 8, 24, 32
    pcfg = PagerConfig(page_tokens=P, policy="none", validate=True)
    p = KVPager(4, bucket * 2, bytes_per_token=100.0, resident_bytes=0.0,
                pcfg=pcfg)
    cache = PrefixCache(page_tokens=P)
    p.prefix_cache = cache
    reqs = shared_prefix_stream(n, 64, seed=9, system_tokens=system,
                                prompt_buckets=(bucket,))
    match_s = 0.0
    for i, r in enumerate(reqs):
        slot = i % p.n_slots
        if p.valid[slot].any():
            p.release(slot)
        t0 = time.perf_counter()
        hit = cache.match(r.tokens)
        match_s += time.perf_counter() - t0
        if hit is not None:
            # the chunked-admission shape: map the cached prefix, then
            # extend privately over the divergent remainder
            p.pin(hit.pages)
            p.map_shared(slot, hit.pages, hit.n_full_tokens)
            p.extend(slot, bucket)
            p.unpin(hit.pages)
        else:
            p.admit(slot, bucket)
        cache.insert(r.tokens, p.phys[slot], p)
    # steady state: n_slots live sharers of the page-aligned system
    # prefix, each at one full bucket -> the closed form applies exactly
    used = p.local_bytes_used() + p.pool_bytes_used()
    live_slot_pages = len(np.unique(p.phys[p.valid]))
    trie_only = int((p.ref > 0).sum()) - live_slot_pages
    measured = (used - trie_only * p.page_bytes) / (p.n_slots * bucket)
    closed = kv_dedup_token_bytes(bucket, system, p.n_slots,
                                  p.bytes_per_token)
    match_us = 1e6 * match_s / n
    emit(
        "pager_shared", match_us,
        f"hit_rate={cache.hit_rate:.3f} hit_tokens={cache.hit_tokens} "
        f"measured_token_bytes={measured:.2f} "
        f"dedup_token_bytes={closed:.2f} cached_pages={cache.cached_pages} "
        f"evicted={cache.evicted_pages}",
    )
    assert cache.hit_rate > 0.5
    return [{
        "tag": "pager_shared",
        "match_us": float(match_us),
        "hit_rate": float(cache.hit_rate),
        "hit_tokens": int(cache.hit_tokens),
        "measured_token_bytes": float(measured),
        "dedup_token_bytes": float(closed),
        "cached_pages": int(cache.cached_pages),
    }]


# ------------------------------------------------------------ lane 3
def run_prefix_chat(smoke=None):
    n = 8 if _smoke(smoke) else 16
    cfg = dataclasses.replace(configs.reduced(ARCH), dtype="float32")
    results, engines, toks = {}, {}, {}
    for on in (False, True):
        ecfg = EngineConfig(
            n_slots=4, max_seq=64, prefill_buckets=(32,), page_tokens=8,
            hot_window=16, local_budget_frac=0.3, admission="greedy",
            prefix_cache=on,
        )
        engine = ServingEngine.build(cfg, ParallelCtx(remat="none"), ecfg)
        # all-at-once arrivals: identical admission order and decode
        # schedule for both lanes -> the byte cut is at equal tokens/s
        reqs = shared_prefix_stream(n, cfg.vocab_size, seed=13,
                                    system_tokens=24, prompt_buckets=(32,),
                                    gen_range=(8, 16), arrival_rate=1e9)
        stats = engine.run(reqs)
        results[on], engines[on] = stats, engine
        toks[on] = [list(r.output) for r in reqs]
        s = stats.summary()
        emit(
            f"pager_prefix_chat_{'on' if on else 'off'}",
            1e6 * stats.wall_s / max(stats.steps, 1),
            f"tok_s_virtual={s['tok_per_s_virtual']:.1f} "
            f"remote_share={s['remote_share']:.3f} "
            f"pool_bytes={stats.pager['pool_bytes']:.3e} "
            + (f"hit_rate={s['prefix_hit_rate']:.3f} "
               f"cow_splits={s['cow_splits']}" if on else ""),
        )
    off, on = results[False], results[True]
    pool_pt_off = off.pager["pool_bytes"] / max(off.tokens, 1)
    pool_pt_on = on.pager["pool_bytes"] / max(on.tokens, 1)
    pool_ratio = pool_pt_on / max(pool_pt_off, 1e-12)
    remote_ratio = (on.pager["remote_share"]
                    / max(off.pager["remote_share"], 1e-12))
    tok_ratio = (on.summary()["tok_per_s_virtual"]
                 / max(off.summary()["tok_per_s_virtual"], 1e-12))
    parity = toks[True] == toks[False]
    emit(
        "pager_prefix_chat_on_vs_off", 0.0,
        f"pool_bytes_per_token_ratio={pool_ratio:.3f} "
        f"remote_share_ratio={remote_ratio:.3f} "
        f"tok_rate_ratio={tok_ratio:.3f} token_parity={parity} "
        f"hit_rate={on.prefix['hit_rate']:.3f} "
        f"shared_mapped_pages={on.pager['shared_mapped_pages']}",
    )
    assert parity, "prefix cache must not change a single sampled token"
    assert pool_ratio <= DEDUP_CUT, (
        f"prefix cache must cut pool bytes/token by >= 30% "
        f"(got ratio {pool_ratio:.3f})"
    )
    assert tok_ratio >= 0.95, (
        f"dedup must not trade away throughput (got {tok_ratio:.3f})"
    )
    return [{
        "tag": "pager_prefix_chat",
        "pool_bytes_per_token_ratio": float(pool_ratio),
        "remote_share_ratio": float(remote_ratio),
        "tok_rate_ratio": float(tok_ratio),
        "token_parity": bool(parity),
        "hit_rate": float(on.prefix["hit_rate"]),
        "cow_splits": int(on.pager["cow_splits"]),
        "shared_mapped_pages": int(on.pager["shared_mapped_pages"]),
        "pool_bytes_per_token_off": float(pool_pt_off),
        "pool_bytes_per_token_on": float(pool_pt_on),
        "tokens": int(on.tokens),
    }]


def run(smoke=None):
    return (run_churn(smoke) + run_shared(smoke)
            + run_prefix_chat(smoke))
