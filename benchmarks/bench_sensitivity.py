"""Paper Fig 10: per-arch sensitivity to pool interference, LoI 0..50%, at
pool capacity ratios 25/50/75%."""

from __future__ import annotations

from repro import configs
from repro.core.quantify import analyze
from benchmarks.common import emit, timed


def run():
    rows = []
    for arch in configs.list_archs():
        shape = "decode_32k"

        def sweep():
            out = {}
            for f in (0.25, 0.5, 0.75):
                a = analyze(arch, shape, policy="hotness", pool_fraction=f,
                            use_dryrun=True)
                out[f] = [a.profile.sensitivity(l / 100)
                          for l in (0, 10, 20, 30, 40, 50)]
            return out

        out, us = timed(sweep, repeats=1)
        s50 = {f: v[-1] for f, v in out.items()}
        emit(
            f"fig10_sensitivity_{arch}", us,
            f"rel_perf@LoI50 25%={s50[0.25]:.3f} 50%={s50[0.5]:.3f} "
            f"75%={s50[0.75]:.3f}",
        )
        rows.append({"arch": arch, "sens": out})
    return rows
