"""Paper Fig 6: bandwidth-capacity scaling curves at three input scales
(1x / 2x / 4x tokens), per architecture. The derived column reports the
traffic fraction captured by the hottest 25% of the footprint and whether
the curve is scale-invariant (the paper's key observation for HPL/Hypre vs
the shifting BFS curve; here: dense archs are invariant, MoE serve curves
shift with token count because expert activation saturates)."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import configs
from repro.common.config import SHAPES
from repro.core import access as acc
from repro.runtime import serve as serve_rt
from benchmarks.common import emit, timed


def hot_frac(profile, x=0.25):
    xs, ys = acc.bandwidth_capacity_curve(profile)
    return float(np.interp(x, xs, ys))


def run():
    rows = []
    for arch in configs.list_archs():
        cfg = configs.get(arch)
        params, _ = serve_rt.abstract_params(cfg)
        base = SHAPES["decode_32k"]

        def curves():
            out = []
            for scale in (1, 2, 4):
                shape = dataclasses.replace(
                    base, global_batch=base.global_batch * scale
                )
                prof = acc.serve_profile(params, None, cfg, shape)
                out.append(hot_frac(prof))
            return out

        (h1, h2, h4), us = timed(curves, repeats=1)
        invariant = abs(h1 - h4) < 0.02
        emit(
            f"fig6_bwcap_{arch}", us,
            f"hot25={h1:.3f}/{h2:.3f}/{h4:.3f} scale_invariant={invariant}",
        )
        rows.append({"arch": arch, "hot25": (h1, h2, h4),
                     "invariant": invariant})
    return rows
