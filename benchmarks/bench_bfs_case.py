"""Paper §7.1 BFS case study — the headline number.

BFS over a pool-resident CSR adjacency array: frontier expansion is
irregular (HW-style predictors are near-blind on it), but the application
knows the next frontier exactly, so frontier-directed prefetch converts
demand page-ins into overlapped transfers at the SAME pool bandwidth.
The paper measures a ~50% remote-access cut worth ~13% runtime; the repo
gates acceptance at >= 40% reduction vs demand paging (asserted with
slack in tests/test_prefetch.py's slow lane; this bench reports the
actual number and the predictor contrast into BENCH_bfs.json)."""

from __future__ import annotations

import os

from benchmarks.common import emit, timed
from repro.prefetch import (
    PrefetchConfig,
    bfs_trace,
    evaluate_zoo,
    remote_reduction,
)

PREDICTORS = ["demand", "next_line", "stride", "stream", "markov",
              "frontier"]


def run():
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    n_vertices = 4096 if smoke else 32768
    rows = []

    def case():
        b = bfs_trace(n_vertices=n_vertices, avg_degree=16,
                      page_bytes=1024, chunk=32)
        cfg = PrefetchConfig(
            local_pages=max(8, b.trace.n_pages // 16),
            bw_pages_per_step=40, degree=40,
        )
        return b, evaluate_zoo(b.trace, cfg, predictors=PREDICTORS)

    (b, reports), us = timed(case, repeats=1)
    base = next(r for r in reports if r.predictor == "demand")
    for r in reports:
        red = remote_reduction(reports, r.predictor)
        speedup = base.total_time / r.total_time
        emit(
            f"bfs_case_{r.predictor}", us,
            f"remote={r.remote_accesses} cut={red:.2f} "
            f"speedup={speedup:.2f}x acc={r.accuracy:.2f} "
            f"excess={r.excess:.2f}",
        )
        rows.append({
            "n_vertices": b.n_vertices,
            "n_edges": b.n_edges,
            "predictor": r.predictor,
            "remote_accesses": r.remote_accesses,
            "remote_reduction": red,
            "speedup": speedup,
            "accuracy": r.accuracy,
            "coverage": r.coverage,
            "excess": r.excess,
        })
    headline = remote_reduction(reports, "frontier")
    emit(
        "bfs_case_headline", us,
        f"frontier_remote_cut={headline:.2f} (acceptance >= 0.40; "
        f"paper ~0.50)",
    )
    return rows
