"""Serving engine benchmark — steady-state tokens/s, per-token latency and
local-vs-remote access ratio across the scenario lanes (all over the
paged physical-page-pool cache layout, the engine default):

  serve_chat      — short prompts, Poisson arrivals (interactive);
  serve_long32k   — long-context lane: per-slot KV spills the local-tier
                    budget (a reduced-scale stand-in for the 32k cell on
                    this CPU container; the shapes stress the same pager
                    paths the full cell would);
  serve_bursty    — mixed bursty arrivals (slot churn + admission);
  serve_chunked   — chunked-prefill lane: long prompts arriving into an
                    in-flight decode batch, serialized whole-prompt
                    prefill vs page-aligned chunks interleaved between
                    decode steps. The acceptance row asserts chunking
                    cuts the p95 inter-decode-step stall at (near-)equal
                    tokens/s — the prefill-serializes-against-decode fix.

The long-context lane additionally runs the acceptance comparison of the
brief: tier-aware pager (`hotness`) vs the no-paging first-touch baseline
(`static`) on an identical all-at-once trace, so both engines take the
same admission/decode schedule (equal steps -> equal tokens/s) and differ
only in page placement. The comparison row asserts the pager cuts the
remote (pool-tier) access share.

`BENCH_SMOKE=1` (set by `benchmarks/run.py --smoke`, the CI lane) shrinks
request counts; shapes stay identical so the same code paths compile.
"""

from __future__ import annotations

import dataclasses
import os

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.serving import (
    EngineConfig,
    ServingEngine,
    bursty_stream,
    chat_stream,
    long_context_stream,
)
from benchmarks.common import emit

ARCH = "smollm_360m"
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def _cfg():
    return dataclasses.replace(configs.reduced(ARCH), dtype="float32")


def _engine(ecfg, cfg):
    return ServingEngine.build(cfg, ParallelCtx(remat="none"), ecfg)


def _emit_scenario(tag, stats, extra=""):
    s = stats.summary()
    emit(
        tag, 1e6 * stats.wall_s / max(stats.steps, 1),
        f"tok_s_wall={s['tok_per_s_wall']:.1f} "
        f"tok_s_virtual={s['tok_per_s_virtual']:.1f} "
        f"ttft_p50={s['ttft_p50_s']:.2e} tpot_p50={s['tpot_p50_s']:.2e} "
        f"tpot_p99={s['tpot_p99_s']:.2e} "
        f"stall_p95={s['stall_p95_s']:.2e} "
        f"remote_share={s['remote_share']:.3f} "
        f"max_conc={s['max_concurrency']} "
        f"admission_blocks={s['admission_blocks']}{extra}",
    )
    return {"tag": tag, **{k: float(v) if isinstance(v, (int, float))
                           else v for k, v in s.items()}}


def run_chat(cfg):
    n = 8 if SMOKE else 24
    ecfg = EngineConfig(
        n_slots=4, max_seq=64, prefill_buckets=(16, 32), page_tokens=8,
        hot_window=16, local_budget_frac=0.5, admission="loi",
        catalog_arch=ARCH,
    )
    engine = _engine(ecfg, cfg)
    reqs = chat_stream(n, cfg.vocab_size, seed=1, prompt_buckets=(16, 32),
                       gen_range=(8, 24), arrival_rate=3e4)
    stats = engine.run(reqs)
    return [_emit_scenario("serve_chat", stats)]


def run_long_context(cfg):
    """Pager-vs-baseline acceptance comparison on an identical trace."""
    n = 4 if SMOKE else 8
    rows, results = [], {}
    for policy in ("hotness", "static"):
        ecfg = EngineConfig(
            n_slots=4, max_seq=192, prefill_buckets=(128,), page_tokens=16,
            hot_window=32, local_budget_frac=0.4, pager_policy=policy,
            admission="greedy",
        )
        engine = _engine(ecfg, cfg)
        # all-at-once arrivals: identical admission order and step count
        # for both policies -> the comparison is at equal tokens/s
        reqs = long_context_stream(
            n, cfg.vocab_size, seed=2, prompt_bucket=128,
            gen_range=(16, 48), arrival_rate=1e9,
        )
        stats = engine.run(reqs)
        results[policy] = stats
        rows.append(_emit_scenario(
            f"serve_long32k_{policy}", stats,
            extra=(f" evictions={engine.pager.evictions}"
                   f" promotions={engine.pager.promotions}"),
        ))

    hot, st = results["hotness"], results["static"]
    remote_hot = hot.pager["remote_share"]
    remote_static = st.pager["remote_share"]
    emit(
        "serve_long32k_pager_vs_static", 0.0,
        f"remote_hotness={remote_hot:.3f} remote_static={remote_static:.3f} "
        f"pager_remote_lower={remote_hot < remote_static} "
        f"equal_steps={hot.steps == st.steps} "
        f"tokens={hot.tokens}",
    )
    rows.append({
        "tag": "serve_long32k_pager_vs_static",
        "remote_hotness": float(remote_hot),
        "remote_static": float(remote_static),
        "pager_remote_lower": bool(remote_hot < remote_static),
        "equal_steps": bool(hot.steps == st.steps),
    })
    assert remote_hot < remote_static, (
        "tier-aware pager must cut the remote access share vs the "
        "no-paging baseline"
    )
    return rows


def run_bursty(cfg):
    n = 8 if SMOKE else 24
    ecfg = EngineConfig(
        n_slots=4, max_seq=96, prefill_buckets=(16, 32, 64), page_tokens=8,
        hot_window=16, local_budget_frac=0.5, admission="loi",
        catalog_arch=ARCH,
    )
    engine = _engine(ecfg, cfg)
    reqs = bursty_stream(n, cfg.vocab_size, seed=3,
                         prompt_buckets=(16, 32, 64), gen_range=(8, 24),
                         burst_size=6, burst_gap=1e-3)
    stats = engine.run(reqs)
    counts = engine.compile_counts()
    steady = all(v <= 1 for v in counts.values())  # 0 = unused bucket
    return [_emit_scenario("serve_bursty", stats,
                           extra=f" steady_state_compiles={steady}")]


def run_chunked_prefill(cfg):
    """Serialized whole-prompt prefill vs chunked prefill on an identical
    trace of long prompts landing in an in-flight decode batch."""
    n = 8 if SMOKE else 24
    base = dict(
        n_slots=4, max_seq=160, prefill_buckets=(128,), page_tokens=16,
        hot_window=32, local_budget_frac=0.5, admission="greedy",
    )
    rows, results = [], {}
    for mode, extra in (("serial", {}), ("chunked", {"prefill_chunk": 32})):
        engine = _engine(EngineConfig(**base, **extra), cfg)
        # steady arrivals with short generations: most decode gaps contain
        # a long-prompt admission, so serialized prefill shows up directly
        # in the p95 inter-decode-step stall (pure arrival waits are
        # excluded from the metric; the prefill work after them counts)
        reqs = long_context_stream(
            n, cfg.vocab_size, seed=5, prompt_bucket=128,
            gen_range=(8, 16), arrival_rate=2e4,
        )
        stats = engine.run(reqs)
        results[mode] = stats
        rows.append(_emit_scenario(f"serve_chunked_{mode}", stats))

    ser, chk = results["serial"], results["chunked"]
    stall_ser = ser.summary()["stall_p95_s"]
    stall_chk = chk.summary()["stall_p95_s"]
    max_ser = float(ser.decode_stall.max())
    max_chk = float(chk.decode_stall.max())
    tok_ratio = (chk.summary()["tok_per_s_virtual"]
                 / max(ser.summary()["tok_per_s_virtual"], 1e-12))
    emit(
        "serve_chunked_vs_serial", 0.0,
        f"stall_p95_serial={stall_ser:.2e} stall_p95_chunked={stall_chk:.2e} "
        f"stall_max_serial={max_ser:.2e} stall_max_chunked={max_chk:.2e} "
        f"stall_lower={stall_chk < stall_ser} tok_s_ratio={tok_ratio:.3f} "
        f"tokens={chk.tokens}",
    )
    rows.append({
        "tag": "serve_chunked_vs_serial",
        "stall_p95_serial": float(stall_ser),
        "stall_p95_chunked": float(stall_chk),
        "stall_max_serial": max_ser,
        "stall_max_chunked": max_chk,
        "stall_lower": bool(stall_chk < stall_ser),
        "tok_s_ratio": float(tok_ratio),
        "equal_tokens": bool(chk.tokens == ser.tokens),
    })
    assert chk.tokens == ser.tokens
    assert stall_chk < stall_ser, (
        "chunked prefill must cut the p95 decode-step stall vs "
        "serialized prefill"
    )
    # the worst gap is the headline: a serialized long prompt (or two
    # back-to-back) stalls in-flight decode for multiples of a chunk
    assert max_chk < 0.75 * max_ser
    assert tok_ratio > 0.85, "chunking must not trade away throughput"
    return rows


def run():
    cfg = _cfg()
    return (run_chat(cfg) + run_long_context(cfg) + run_bursty(cfg)
            + run_chunked_prefill(cfg))
