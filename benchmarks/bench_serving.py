"""Serving engine benchmark — steady-state tokens/s, per-token latency and
local-vs-remote access ratio across the scenario lanes (all over the
paged physical-page-pool cache layout, the engine default):

  serve_chat      — short prompts, Poisson arrivals (interactive);
  serve_long32k   — long-context lane: per-slot KV spills the local-tier
                    budget (a reduced-scale stand-in for the 32k cell on
                    this CPU container; the shapes stress the same pager
                    paths the full cell would);
  serve_bursty    — mixed bursty arrivals (slot churn + admission);
  serve_chunked   — chunked-prefill lane: long prompts arriving into an
                    in-flight decode batch, serialized whole-prompt
                    prefill vs page-aligned chunks interleaved between
                    decode steps. The acceptance row asserts chunking
                    cuts the p95 inter-decode-step stall at (near-)equal
                    tokens/s — the prefill-serializes-against-decode fix.
  serve_int8      — block-quantized pool lane: the fp16-class paged
                    engine (`pool_dtype="bf16"`) vs the int8 per-page
                    quantized engine on an identical trace at the SAME
                    ABSOLUTE local-tier budget (same HBM — the physically
                    meaningful comparison: int8 shrinks the pooled
                    footprint, so the same budget keeps far more pages
                    local AND each remaining pool touch moves ~4x fewer
                    bytes). The acceptance row asserts remote pool bytes
                    <= 0.30x of the fp16 lane at >= 0.95x virtual
                    tokens/s and equal tokens, plus a lockstep
                    teacher-forced logit-drift probe against the fp
                    paged caches staying under `INT8_LOGIT_DRIFT`.
  serve_speculative — speculative-decoding lane: the plain greedy paged
                    engine vs the same engine with the self-speculative
                    n-gram proposer on an identical DECODE-BOUND chat
                    trace (all-at-once arrivals, so the virtual clock
                    measures decode sweeps, not Poisson idle time). The
                    k-candidate verify cell scores every draft in one
                    paged sweep, so each accepted token amortizes the
                    pool read traffic. The acceptance row asserts
                    BIT-IDENTICAL tokens (fp pools), >= `SPEC_TOK_GAIN`x
                    virtual tokens/s at equal output tokens, and a lower
                    pager-bytes-per-token figure.

Every serving row records `pool_bytes_per_token` (the pager's dtype-aware
per-cached-token pool footprint, scale arrays included), so the BENCH
json artifacts track the pool-byte trajectory across PRs.

The long-context lane additionally runs the acceptance comparison of the
brief: tier-aware pager (`hotness`) vs the no-paging first-touch baseline
(`static`) on an identical all-at-once trace, so both engines take the
same admission/decode schedule (equal steps -> equal tokens/s) and differ
only in page placement. The comparison row asserts the pager cuts the
remote (pool-tier) access share.

`BENCH_SMOKE=1` (set by `benchmarks/run.py --smoke`, the CI lane) shrinks
request counts; shapes stay identical so the same code paths compile.
"""

from __future__ import annotations

import dataclasses
import os

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.serving import (
    EngineConfig,
    ServingEngine,
    bursty_stream,
    chat_stream,
    long_context_stream,
)
from benchmarks.common import emit

ARCH = "smollm_360m"
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def _cfg():
    return dataclasses.replace(configs.reduced(ARCH), dtype="float32")


def _engine(ecfg, cfg):
    return ServingEngine.build(cfg, ParallelCtx(remat="none"), ecfg)


def _emit_scenario(tag, stats, engine=None, extra=""):
    s = stats.summary()
    if engine is not None:
        s["pool_bytes_per_token"] = engine.pager.bytes_per_token
        extra = (f" pool_bytes_per_token="
                 f"{engine.pager.bytes_per_token:.1f}{extra}")
    emit(
        tag, 1e6 * stats.wall_s / max(stats.steps, 1),
        f"tok_s_wall={s['tok_per_s_wall']:.1f} "
        f"tok_s_virtual={s['tok_per_s_virtual']:.1f} "
        f"ttft_p50={s['ttft_p50_s']:.2e} tpot_p50={s['tpot_p50_s']:.2e} "
        f"tpot_p99={s['tpot_p99_s']:.2e} "
        f"stall_p95={s['stall_p95_s']:.2e} "
        f"remote_share={s['remote_share']:.3f} "
        f"max_conc={s['max_concurrency']} "
        f"admission_blocks={s['admission_blocks']}{extra}",
    )
    return {"tag": tag, **{k: float(v) if isinstance(v, (int, float))
                           else v for k, v in s.items()}}


def run_chat(cfg):
    n = 8 if SMOKE else 24
    ecfg = EngineConfig(
        n_slots=4, max_seq=64, prefill_buckets=(16, 32), page_tokens=8,
        hot_window=16, local_budget_frac=0.5, admission="loi",
        catalog_arch=ARCH,
    )
    engine = _engine(ecfg, cfg)
    reqs = chat_stream(n, cfg.vocab_size, seed=1, prompt_buckets=(16, 32),
                       gen_range=(8, 24), arrival_rate=3e4)
    stats = engine.run(reqs)
    return [_emit_scenario("serve_chat", stats, engine)]


def run_long_context(cfg):
    """Pager-vs-baseline acceptance comparison on an identical trace."""
    n = 4 if SMOKE else 8
    rows, results = [], {}
    for policy in ("hotness", "static"):
        ecfg = EngineConfig(
            n_slots=4, max_seq=192, prefill_buckets=(128,), page_tokens=16,
            hot_window=32, local_budget_frac=0.4, pager_policy=policy,
            admission="greedy",
        )
        engine = _engine(ecfg, cfg)
        # all-at-once arrivals: identical admission order and step count
        # for both policies -> the comparison is at equal tokens/s
        reqs = long_context_stream(
            n, cfg.vocab_size, seed=2, prompt_bucket=128,
            gen_range=(16, 48), arrival_rate=1e9,
        )
        stats = engine.run(reqs)
        results[policy] = stats
        rows.append(_emit_scenario(
            f"serve_long32k_{policy}", stats, engine,
            extra=(f" evictions={engine.pager.evictions}"
                   f" promotions={engine.pager.promotions}"),
        ))

    hot, st = results["hotness"], results["static"]
    remote_hot = hot.pager["remote_share"]
    remote_static = st.pager["remote_share"]
    emit(
        "serve_long32k_pager_vs_static", 0.0,
        f"remote_hotness={remote_hot:.3f} remote_static={remote_static:.3f} "
        f"pager_remote_lower={remote_hot < remote_static} "
        f"equal_steps={hot.steps == st.steps} "
        f"tokens={hot.tokens}",
    )
    rows.append({
        "tag": "serve_long32k_pager_vs_static",
        "remote_hotness": float(remote_hot),
        "remote_static": float(remote_static),
        "pager_remote_lower": bool(remote_hot < remote_static),
        "equal_steps": bool(hot.steps == st.steps),
    })
    assert remote_hot < remote_static, (
        "tier-aware pager must cut the remote access share vs the "
        "no-paging baseline"
    )
    return rows


def run_bursty(cfg):
    n = 8 if SMOKE else 24
    ecfg = EngineConfig(
        n_slots=4, max_seq=96, prefill_buckets=(16, 32, 64), page_tokens=8,
        hot_window=16, local_budget_frac=0.5, admission="loi",
        catalog_arch=ARCH,
    )
    engine = _engine(ecfg, cfg)
    reqs = bursty_stream(n, cfg.vocab_size, seed=3,
                         prompt_buckets=(16, 32, 64), gen_range=(8, 24),
                         burst_size=6, burst_gap=1e-3)
    stats = engine.run(reqs)
    counts = engine.compile_counts()
    steady = all(v <= 1 for v in counts.values())  # 0 = unused bucket
    return [_emit_scenario("serve_bursty", stats, engine,
                           extra=f" steady_state_compiles={steady}")]


def run_chunked_prefill(cfg):
    """Serialized whole-prompt prefill vs chunked prefill on an identical
    trace of long prompts landing in an in-flight decode batch."""
    n = 8 if SMOKE else 24
    base = dict(
        n_slots=4, max_seq=160, prefill_buckets=(128,), page_tokens=16,
        hot_window=32, local_budget_frac=0.5, admission="greedy",
    )
    rows, results = [], {}
    for mode, extra in (("serial", {}), ("chunked", {"prefill_chunk": 32})):
        engine = _engine(EngineConfig(**base, **extra), cfg)
        # steady arrivals with short generations: most decode gaps contain
        # a long-prompt admission, so serialized prefill shows up directly
        # in the p95 inter-decode-step stall (pure arrival waits are
        # excluded from the metric; the prefill work after them counts)
        reqs = long_context_stream(
            n, cfg.vocab_size, seed=5, prompt_bucket=128,
            gen_range=(8, 16), arrival_rate=2e4,
        )
        stats = engine.run(reqs)
        results[mode] = stats
        rows.append(_emit_scenario(f"serve_chunked_{mode}", stats, engine))

    ser, chk = results["serial"], results["chunked"]
    stall_ser = ser.summary()["stall_p95_s"]
    stall_chk = chk.summary()["stall_p95_s"]
    max_ser = float(ser.decode_stall.max())
    max_chk = float(chk.decode_stall.max())
    tok_ratio = (chk.summary()["tok_per_s_virtual"]
                 / max(ser.summary()["tok_per_s_virtual"], 1e-12))
    emit(
        "serve_chunked_vs_serial", 0.0,
        f"stall_p95_serial={stall_ser:.2e} stall_p95_chunked={stall_chk:.2e} "
        f"stall_max_serial={max_ser:.2e} stall_max_chunked={max_chk:.2e} "
        f"stall_lower={stall_chk < stall_ser} tok_s_ratio={tok_ratio:.3f} "
        f"tokens={chk.tokens}",
    )
    rows.append({
        "tag": "serve_chunked_vs_serial",
        "stall_p95_serial": float(stall_ser),
        "stall_p95_chunked": float(stall_chk),
        "stall_max_serial": max_ser,
        "stall_max_chunked": max_chk,
        "stall_lower": bool(stall_chk < stall_ser),
        "tok_s_ratio": float(tok_ratio),
        "equal_tokens": bool(chk.tokens == ser.tokens),
    })
    assert chk.tokens == ser.tokens
    assert stall_chk < stall_ser, (
        "chunked prefill must cut the p95 decode-step stall vs "
        "serialized prefill"
    )
    # the worst gap is the headline: a serialized long prompt (or two
    # back-to-back) stalls in-flight decode for multiples of a chunk
    assert max_chk < 0.75 * max_ser
    assert tok_ratio > 0.85, "chunking must not trade away throughput"
    return rows


# documented int8 drift bound for the lockstep logit probe: max abs logit
# difference, teacher-forced fp vs int8 paged caches over a full decode
# stream (per-page error <= scale/2 keeps this in the 1e-1 regime on the
# reduced models; greedy margins are typically wider)
INT8_LOGIT_DRIFT = 0.5


def _logit_drift_probe(cfg, steps=24, page_tokens=16):
    """Teacher-forced lockstep decode over fp vs int8 paged caches: the
    SAME token stream feeds both cache dtypes (no greedy cascade), so
    the max abs logit gap isolates pure quantization drift vs the dense
    fp oracle path."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import model as M
    from repro.serving.kv_pager import KVPager, PagerConfig

    ctx = ParallelCtx(remat="none")
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    max_seq = -(-steps // page_tokens) * page_tokens
    pager = KVPager(1, max_seq, bytes_per_token=1.0, resident_bytes=0.0,
                    pcfg=PagerConfig(page_tokens=page_tokens,
                                     policy="none"))
    caches = {
        dt: M.make_paged_decode_caches(cfg, 1, max_seq, page_tokens,
                                       pool_dtype=dt)
        for dt in ("fp", "int8")
    }
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (steps,), 0, cfg.vocab_size))
    drift = 0.0
    for t in range(steps):
        pager.ensure_tail_pages(np.array([True]))
        pager.extend(0, t + 1)
        bt = jnp.asarray(pager.block_table())
        tok = jnp.asarray(toks[t:t + 1], jnp.int32)
        tv = jnp.full((1,), t, jnp.int32)
        logits = {}
        for dt in ("fp", "int8"):
            logits[dt], caches[dt] = M.decode_step(
                params, tok, caches[dt], tv, cfg, ctx,
                block_table=bt, page_tokens=page_tokens,
            )
        drift = max(drift, float(jnp.abs(
            logits["int8"] - logits["fp"]).max()))
    return drift


def run_int8(cfg):
    """fp16-class pool vs int8 block-quantized pool on an identical trace
    at the same ABSOLUTE local-tier budget (see module docstring)."""
    n = 4 if SMOKE else 8
    base = dict(
        n_slots=4, max_seq=192, prefill_buckets=(128,), page_tokens=16,
        hot_window=32, pager_policy="hotness", admission="greedy",
    )
    rows, results, engines = [], {}, {}
    budget = None
    for lane, pool_dtype in (("fp16", "bf16"), ("int8", "int8")):
        ecfg = EngineConfig(
            **base, pool_dtype=pool_dtype,
            # 0.3x of the fp16 peak: tight enough that BOTH lanes spill
            # to the pool tier (the int8 lane's cut must come from
            # smaller pooled bytes, not from quantization fitting the
            # whole working set locally)
            local_budget_frac=0.3 if budget is None else None,
            local_budget_bytes=budget,
        )
        engine = _engine(ecfg, cfg)
        if budget is None:
            # the fp16 lane's absolute budget carries over: same HBM
            budget = engine.pager.budget
        reqs = long_context_stream(
            n, cfg.vocab_size, seed=7, prompt_bucket=128,
            gen_range=(16, 48), arrival_rate=1e9,
        )
        stats = engine.run(reqs)
        results[lane], engines[lane] = stats, engine
        rows.append(_emit_scenario(f"serve_int8_{lane}", stats, engine))

    fp, i8 = results["fp16"], results["int8"]
    pool_ratio = i8.pager["pool_bytes"] / max(fp.pager["pool_bytes"], 1e-9)
    tok_ratio = (i8.summary()["tok_per_s_virtual"]
                 / max(fp.summary()["tok_per_s_virtual"], 1e-12))
    bpt_ratio = (engines["int8"].pager.bytes_per_token
                 / engines["fp16"].pager.bytes_per_token)
    drift = _logit_drift_probe(cfg)
    emit(
        "serve_int8_vs_fp16", 0.0,
        f"pool_bytes_ratio={pool_ratio:.3f} tok_s_ratio={tok_ratio:.3f} "
        f"bytes_per_token_ratio={bpt_ratio:.3f} "
        f"logit_drift={drift:.3e} "
        f"equal_tokens={i8.tokens == fp.tokens} tokens={i8.tokens}",
    )
    rows.append({
        "tag": "serve_int8_vs_fp16",
        "pool_bytes_ratio": float(pool_ratio),
        "tok_s_ratio": float(tok_ratio),
        "bytes_per_token_ratio": float(bpt_ratio),
        "logit_drift": float(drift),
        "equal_tokens": bool(i8.tokens == fp.tokens),
        "pool_bytes_fp16": float(fp.pager["pool_bytes"]),
        "pool_bytes_int8": float(i8.pager["pool_bytes"]),
    })
    assert i8.tokens == fp.tokens, "lanes must serve equal tokens"
    assert pool_ratio <= 0.30, (
        f"int8 pool must move <= 0.30x of the fp16 lane's pool bytes "
        f"(got {pool_ratio:.3f})"
    )
    assert tok_ratio >= 0.95, (
        f"int8 must hold >= 0.95x virtual tokens/s (got {tok_ratio:.3f})"
    )
    assert drift <= INT8_LOGIT_DRIFT, (
        f"int8 logit drift {drift:.3e} exceeds bound {INT8_LOGIT_DRIFT}"
    )
    return rows


SPEC_TOK_GAIN = 1.5


def run_speculative(cfg):
    """Greedy vs n-gram-speculative engine on an identical decode-bound
    chat trace (tentpole acceptance): same tokens bit-for-bit, >=
    `SPEC_TOK_GAIN`x virtual tokens/s, fewer pager bytes per token."""
    n = 12
    base = dict(
        n_slots=4, max_seq=48, prefill_buckets=(16,), page_tokens=4,
        hot_window=16, local_budget_frac=0.25, pager_policy="hotness",
        # fp pools: the parity gate is BIT-exact. (int8 speculation flips
        # to per-token sub-scales, a different quantization grid than the
        # greedy lane's per-page blocks — drift-bounded, not identical;
        # dev_serve and the serving tests cover that lane.)
        admission="greedy", pool_dtype="fp",
    )
    rows, results, outs, engines = [], {}, {}, {}
    for lane, spec in (("greedy", "off"), ("ngram", "ngram")):
        ecfg = EngineConfig(**base, speculative=spec, speculative_k=4)
        engine = _engine(ecfg, cfg)
        # all-at-once arrivals: the comparison must be decode-bound —
        # with Poisson gaps the virtual clock is dominated by idle wait
        # and both lanes report arrival-limited tokens/s
        reqs = chat_stream(n, cfg.vocab_size, seed=3,
                           prompt_buckets=(16,), gen_range=(16, 32),
                           arrival_rate=1e6)
        stats = engine.run(reqs)
        results[lane], engines[lane] = stats, engine
        outs[lane] = [r.output for r in reqs]
        extra = ""
        if spec != "off":
            extra = (f" accept_len={stats.spec['accept_len_mean']:.2f}"
                     f" verify_steps={stats.spec['verify_steps']}")
        rows.append(_emit_scenario(f"serve_speculative_{lane}", stats,
                                   engine, extra=extra))

    gr, ng = results["greedy"], results["ngram"]
    parity = outs["greedy"] == outs["ngram"]
    tok_ratio = (ng.summary()["tok_per_s_virtual"]
                 / max(gr.summary()["tok_per_s_virtual"], 1e-12))
    bpt = {lane: (results[lane].pager["local_bytes"]
                  + results[lane].pager["pool_bytes"])
           / max(results[lane].tokens, 1)
           for lane in ("greedy", "ngram")}
    accept = ng.spec["accept_len_mean"]
    emit(
        "serve_speculative_vs_greedy", 0.0,
        f"tok_s_ratio={tok_ratio:.3f} accept_len_mean={accept:.2f} "
        f"bytes_per_token_greedy={bpt['greedy']:.1f} "
        f"bytes_per_token_ngram={bpt['ngram']:.1f} "
        f"token_parity={parity} tokens={ng.tokens}",
    )
    rows.append({
        "tag": "serve_speculative_vs_greedy",
        "tok_s_ratio": float(tok_ratio),
        "accept_len_mean": float(accept),
        "verify_steps": float(ng.spec["verify_steps"]),
        "bytes_per_token_greedy": float(bpt["greedy"]),
        "bytes_per_token_ngram": float(bpt["ngram"]),
        "bytes_per_token_ratio": float(bpt["ngram"]
                                       / max(bpt["greedy"], 1e-9)),
        "token_parity": bool(parity),
        "equal_tokens": bool(ng.tokens == gr.tokens),
    })
    assert parity, "speculation must be invisible to the sampled tokens"
    assert ng.tokens == gr.tokens, "lanes must serve equal tokens"
    assert tok_ratio >= SPEC_TOK_GAIN, (
        f"speculative lane must reach >= {SPEC_TOK_GAIN}x virtual "
        f"tokens/s over greedy (got {tok_ratio:.3f})"
    )
    assert bpt["ngram"] < bpt["greedy"], (
        "accepted tokens must amortize the pager sweep bytes"
    )
    return rows


def run_substrate(cfg):
    """Physical-substrate traffic lane: a spilling long-context trace
    whose pool placement changes are MEASURED off the TierSubstrate
    ledger (emulated mode on CPU CI; identical accounting shapes to the
    pinned_host physical path — see repro.serving.substrate)."""
    from benchmarks.bench_tier_ratios import substrate_transfer_row

    n = 4 if SMOKE else 8
    ecfg = EngineConfig(
        n_slots=4, max_seq=192, prefill_buckets=(128,), page_tokens=16,
        hot_window=32, local_budget_frac=0.4, pager_policy="hotness",
        admission="greedy",
    )
    engine = _engine(ecfg, cfg)
    reqs = long_context_stream(
        n, cfg.vocab_size, seed=2, prompt_bucket=128,
        gen_range=(16, 48), arrival_rate=1e9,
    )
    stats = engine.run(reqs)
    row = substrate_transfer_row(engine, stats)
    assert row["placement_gap"] == 0.0, (
        "phys_tiers() pool bytes must equal the ledger's measured "
        "placement bytes after every drain")
    return [row]


def run():
    cfg = _cfg()
    return (run_chat(cfg) + run_long_context(cfg) + run_bursty(cfg)
            + run_chunked_prefill(cfg) + run_int8(cfg)
            + run_speculative(cfg) + run_substrate(cfg))
