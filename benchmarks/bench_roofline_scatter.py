"""Paper Fig 5: place every (arch x shape) cell on the TPU roofline —
arithmetic intensity vs attainable/achieved flops. Reads the dry-run JSONs
when present (HLO-derived), else the analytic model."""

from __future__ import annotations

from repro import configs
from repro.common import hw
from repro.core.quantify import analyze, load_dryrun_record
from benchmarks.common import emit, timed


def run():
    rows = []
    for arch, shape in configs.all_cells():
        rec = load_dryrun_record(arch, shape)

        def one():
            a = analyze(arch, shape, dryrun_record=rec)
            ai = a.level1["arithmetic_intensity"]
            ridge = hw.V5E.peak_flops_bf16 / hw.V5E.hbm_bw
            attain = min(hw.V5E.peak_flops_bf16, ai * hw.V5E.hbm_bw)
            if rec and rec.get("status") == "ok":
                achieved = (
                    rec["roofline"]["model_flops"] / 256
                    / rec["roofline"]["bound_overlap_s"]
                )
            else:
                achieved = attain
            return ai, attain, achieved, ridge

        (ai, attain, achieved, ridge), us = timed(one, repeats=1)
        bound = "compute" if ai > ridge else "memory"
        emit(
            f"fig5_roofline_{arch}_{shape}", us,
            f"AI={ai:.1f} bound={bound} "
            f"achieved={achieved / 1e12:.2f}Tflops "
            f"attainable={attain / 1e12:.2f}Tflops "
            f"frac={achieved / max(attain, 1):.3f}",
        )
        rows.append({"arch": arch, "shape": shape, "ai": ai,
                     "achieved": achieved, "attainable": attain})
    return rows
