"""Benchmark harness — one module per paper table/figure plus the serving
and dry-run lanes.

    PYTHONPATH=src python -m benchmarks.run [--only fig11,serve]
    PYTHONPATH=src python -m benchmarks.run --smoke --out results/bench

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).
`--smoke` shrinks the configurable lanes (serving request counts, dry-run
cells) via BENCH_SMOKE=1 — the CI benchmark job's config. `--out DIR`
writes one ``BENCH_<tag>.json`` per module (each module's returned rows),
which CI uploads as artifacts next to the regenerated `results/dryrun/`
records.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


MODULES = [
    ("table1", "benchmarks.bench_memory_cost"),
    ("fig5", "benchmarks.bench_roofline_scatter"),
    ("fig6", "benchmarks.bench_bwcap_curve"),
    ("fig8", "benchmarks.bench_prefetch"),
    ("bfs", "benchmarks.bench_bfs_case"),
    ("fig9", "benchmarks.bench_tier_ratios"),
    ("fig10", "benchmarks.bench_sensitivity"),
    ("fig11", "benchmarks.bench_lbench"),
    ("fig12", "benchmarks.bench_placement_case"),
    ("fig13", "benchmarks.bench_scheduler_case"),
    ("serve", "benchmarks.bench_serving"),
    ("pager", "benchmarks.bench_pager_churn"),
    ("fleet", "benchmarks.bench_fleet"),
    ("dryrun", "benchmarks.bench_dryrun_sweep"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated tags, e.g. fig11,serve")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest configs (sets BENCH_SMOKE=1)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_<tag>.json row dumps to this dir")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if only:
        # a typo'd lane name must fail loudly, not pass green doing no work
        known = {tag for tag, _ in MODULES}
        bad = sorted(only - known)
        if bad:
            ap.error(
                f"unknown --only lane(s) {', '.join(bad)}; "
                f"valid: {', '.join(tag for tag, _ in MODULES)}"
            )
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"
    if args.out:
        os.makedirs(args.out, exist_ok=True)

    print("name,us_per_call,derived")
    failures = []
    for tag, modname in MODULES:
        if only and tag not in only:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run()
            if args.out:
                with open(os.path.join(args.out,
                                       f"BENCH_{tag}.json"), "w") as f:
                    json.dump({"tag": tag, "module": modname,
                               "rows": rows}, f, indent=1, default=str)
        except Exception as e:
            failures.append((tag, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED: {[t for t, _ in failures]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
