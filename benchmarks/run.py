"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig11]

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).
"""

from __future__ import annotations

import argparse
import sys
import traceback


MODULES = [
    ("table1", "benchmarks.bench_memory_cost"),
    ("fig5", "benchmarks.bench_roofline_scatter"),
    ("fig6", "benchmarks.bench_bwcap_curve"),
    ("fig8", "benchmarks.bench_prefetch"),
    ("fig9", "benchmarks.bench_tier_ratios"),
    ("fig10", "benchmarks.bench_sensitivity"),
    ("fig11", "benchmarks.bench_lbench"),
    ("fig12", "benchmarks.bench_placement_case"),
    ("fig13", "benchmarks.bench_scheduler_case"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated tags, e.g. fig11,fig13")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for tag, modname in MODULES:
        if only and tag not in only:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run()
        except Exception as e:
            failures.append((tag, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED: {[t for t, _ in failures]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
