"""Paper Fig 7/8: prefetching accuracy / coverage / excess traffic / gain.

On TPU there is no hardware prefetcher; the analogue is the layer-ahead
prefetch of pool-tier tensors inside the scan (runtime design). Because the
access schedule of a training step is fully known, accuracy is structurally
100% (everything fetched is used); coverage is the fraction of pool bytes
whose transfer fits inside the previous layer's compute window; the gain is
the step-time ratio no-prefetch vs prefetch. This reproduces the paper's
qualitative finding — prefetch is NECESSARY for HPC-style workloads on a
pooled tier (gain up to the full pool stall), with near-zero excess traffic
(vs 37% excess for SuperLU's speculative HW prefetcher)."""

from __future__ import annotations

from repro import configs
from repro.common import hw
from repro.core.quantify import analyze
from benchmarks.common import emit, timed


def run():
    rows = []
    for arch in configs.list_archs():
        cfg = configs.get(arch)

        def one():
            a = analyze(arch, "train_4k", policy="hotness",
                        pool_fraction=0.5, use_dryrun=True)
            layers = max(cfg.num_layers, 1)
            t_layer_compute = a.profile.t_compute / layers
            t_layer_pool = a.profile.t_pool / layers
            coverage = min(1.0, t_layer_compute / max(t_layer_pool, 1e-12))
            accuracy = 1.0  # schedule-exact: nothing speculative
            excess = 0.0
            t_no_pf = a.profile.t_compute + a.profile.t_pool
            t_pf = max(a.profile.t_compute,
                       a.profile.t_pool) + t_layer_pool
            gain = t_no_pf / t_pf
            return accuracy, coverage, excess, gain

        (acc_, cov, exc, gain), us = timed(one, repeats=1)
        emit(
            f"fig8_prefetch_{arch}", us,
            f"accuracy={acc_:.2f} coverage={cov:.2f} excess={exc:.2f} "
            f"gain={gain:.2f}x",
        )
        rows.append({"arch": arch, "coverage": cov, "gain": gain})
    return rows
