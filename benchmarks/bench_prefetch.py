"""Paper Fig 7/8: prefetching accuracy / coverage / timeliness / excess
traffic — the predictor zoo swept over the three dynamic trace sources
(serving KV pager, rack-sim pool traffic, BFS frontier walk) plus the
statically-schedulable layer stream the old bench modeled analytically.

Each (trace, predictor) cell is one `PrefetchEngine` replay at matched
pool bandwidth; one row per cell lands in BENCH_fig8.json. The layer
stream reproduces the old headline structurally (static schedule =>
accuracy 1, zero excess); the dynamic traces add the paper's real story:
accuracy/coverage depend on how predictable the stream is, and excess
traffic from a speculative predictor feeds back into the interference
model (`core.access.with_prefetch_excess` -> injected LoI inflation,
the SuperLU-37%-excess effect)."""

from __future__ import annotations

import os

from benchmarks.common import emit, timed
from repro.core import tiers as tr
from repro.core.interference import InterferenceProfile
from repro.prefetch import (
    PrefetchConfig,
    bfs_trace,
    evaluate_zoo,
    kv_pager_trace,
    remote_reduction,
    sched_pool_trace,
)
from repro.prefetch.static import layer_stream_trace


def _traces(smoke: bool):
    scale = 1 if smoke else 4
    t_serve = kv_pager_trace(n_slots=2, max_seq=256 * scale,
                             prompt_len=192 * scale, steps=64 * scale,
                             cold_touch=0.1)
    t_sched = sched_pool_trace(n_jobs=4, steps=100 * scale,
                               pages_per_job=128 * scale)
    t_bfs = bfs_trace(n_vertices=2048 * scale, avg_degree=16,
                      page_bytes=1024, chunk=32).trace
    t_layer = layer_stream_trace(n_layers=16, pages_per_layer=8,
                                 epochs=3)
    return [
        (t_serve, PrefetchConfig(local_pages=max(8, t_serve.n_pages // 3),
                                 bw_pages_per_step=16, degree=8)),
        (t_sched, PrefetchConfig(local_pages=max(8, t_sched.n_pages // 8),
                                 bw_pages_per_step=24, degree=12)),
        (t_bfs, PrefetchConfig(local_pages=max(8, t_bfs.n_pages // 16),
                               bw_pages_per_step=40, degree=40)),
        (t_layer, PrefetchConfig(local_pages=32, bw_pages_per_step=16,
                                 degree=8)),
    ]


def _excess_loi_row(report, topo) -> dict:
    """Feed the worst predictor's excess back into the traffic model:
    fetched-but-unused bytes per step are pool-link traffic, inflating
    the injected LoI a scheduler would see."""
    per_step = report.remote_bytes / max(report.steps, 1)
    excess_per_step = report.excess_bytes / max(report.steps, 1)
    base = InterferenceProfile(
        arch=f"prefetch/{report.predictor}", shape=report.trace,
        pool_traffic=per_step, local_traffic=0.0,
        t_compute=per_step / topo.pool.bandwidth + 1e-9, topo=topo,
    )
    import dataclasses

    inflated = dataclasses.replace(
        base, pool_traffic=base.pool_traffic + excess_per_step
    )
    return {
        "kind": "excess_feedback",
        "trace": report.trace,
        "predictor": report.predictor,
        "excess_bytes_per_step": excess_per_step,
        "injected_loi": base.injected_loi(),
        "injected_loi_with_excess": inflated.injected_loi(),
    }


def run():
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    topo = tr.v5e_topology()
    rows = []
    for trace, cfg in _traces(smoke):
        (reports, _), us = timed(
            lambda t=trace, c=cfg: (evaluate_zoo(t, c), None), repeats=1
        )
        worst_excess = None
        for r in reports:
            red = remote_reduction(reports, r.predictor)
            emit(
                f"fig8_{trace.source}_{r.predictor}", us,
                f"acc={r.accuracy:.2f} cov={r.coverage:.2f} "
                f"time={r.timeliness:.2f} excess={r.excess:.2f} "
                f"remote_cut={red:.2f}",
            )
            rows.append({
                "kind": "fig8",
                "trace": r.trace,
                "source": r.source,
                "predictor": r.predictor,
                "accuracy": r.accuracy,
                "coverage": r.coverage,
                "timeliness": r.timeliness,
                "excess": r.excess,
                "remote_accesses": r.remote_accesses,
                "remote_reduction": red,
                "issued": r.issued,
                "total_time": r.total_time,
            })
            if r.issued and (worst_excess is None
                             or r.excess > worst_excess.excess):
                worst_excess = r
        if worst_excess is not None:
            rows.append(_excess_loi_row(worst_excess, topo))
    return rows
