"""Fleet router benchmark — placement-policy TTFT/throughput comparisons
over N engines (the rack-scale thesis one level up: the pool is shared
ACROSS engines, and placement policy — not capacity — decides tail
latency):

  fleet_bursty         — round-robin vs KV-load-aware on the rack-sim-
                         mapped traffic stream (`sched.workload.
                         fleet_request_stream`: quiet draws -> short
                         priority-0 interactive requests, loud draws ->
                         long-prompt priority-1 batch requests). Both
                         policies serve the identical trace; the
                         acceptance row asserts KV-aware placement cuts
                         p99 TTFT at equal total tokens — count-balanced
                         round-robin piles heavy batch work onto busy
                         engines, outstanding-token scoring doesn't.
  fleet_shared_prefix  — round-robin vs prefix-aware on the shared-
                         prefix stream (`n_systems` system prompts,
                         prefix radix cache ON in every engine). The
                         acceptance row asserts prefix-aware steering
                         reports a strictly higher aggregate
                         prefix_hit_rate at bit-identical tokens: the
                         router-side radix index keeps each system
                         prompt's pages on ONE engine instead of
                         cold-missing on all of them.
  fleet_roles          — disaggregated prefill/decode: every request
                         prefills on the prefill-role engine and decodes
                         on the decode-role engine after a pool page
                         transfer; the row reports the transfer ledger
                         (pages, bytes, mean handoff latency) and
                         asserts one transfer per request.
  fleet_faults         — chaos recovery pricing: the bursty trace served
                         fault-free and under the deterministic
                         `chaos_smoke` plan (engine 1 killed mid-decode
                         + 10% pool-link flaking). Bit parity on fp
                         pools is a hard assert; the row prices recovery
                         — recovery_overhead_tokens (teacher-forced
                         refill), retry_bytes (failed attempts re-priced
                         through the ledgers), and p99_ttft_ratio
                         (faulted p99 TTFT / fault-free p99 TTFT, the
                         watchdog + re-route tail cost).

Every row records p50/p95/p99 TTFT and virtual tokens/s on the fleet's
virtual clocks (wall time is reported but NOT gated — CI machines are
noisy; the virtual metrics are deterministic for a fixed trace, which is
what `scripts/check_bench.py` compares against the committed baselines).

`BENCH_SMOKE=1` (set by `benchmarks/run.py --smoke`, the CI lane)
shrinks request counts; shapes and code paths stay identical.
"""

from __future__ import annotations

import dataclasses
import os

from repro import configs
from repro.common.parallel import ParallelCtx
from repro.serving import EngineConfig, make_plan
from repro.serving.fleet import FleetConfig, FleetRouter
from repro.serving.queue import shared_prefix_stream
from repro.sched.workload import fleet_request_stream
from benchmarks.common import emit

ARCH = "smollm_360m"
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
N_ENGINES = 2


def _cfg():
    return dataclasses.replace(configs.reduced(ARCH), dtype="float32")


def _router(ecfg, cfg, policy, *, params=None, roles=False):
    return FleetRouter.build(
        cfg, ParallelCtx(remat="none"), ecfg,
        FleetConfig(n_engines=N_ENGINES, policy=policy, roles=roles),
        params=params,
    )


def _emit_fleet(tag, stats, extra=""):
    s = stats.summary()
    emit(
        tag, 1e6 * stats.wall_s,
        f"tok_s_virtual={s['tok_per_s_virtual']:.1f} "
        f"ttft_p50={s['ttft_p50']:.2e} ttft_p95={s['ttft_p95']:.2e} "
        f"ttft_p99={s['ttft_p99']:.2e} tpot_p50={s['tpot_p50']:.2e} "
        f"routed={s['routed']} prefix_hit_rate={s['prefix_hit_rate']:.3f} "
        f"cancelled={s['cancelled']}{extra}",
    )
    return {"tag": tag, **{k: (float(v) if isinstance(v, (int, float))
                               else v) for k, v in s.items()}}


def run_bursty(cfg, params):
    """KV-aware vs round-robin at p99 TTFT on the bursty rack-mapped
    stream — identical trace, equal total tokens."""
    n = 16 if SMOKE else 48
    ecfg = EngineConfig(
        n_slots=2, max_seq=96, prefill_buckets=(16, 32, 64),
        page_tokens=8, hot_window=16, local_budget_frac=0.5,
        admission="greedy",
    )
    rows, results = [], {}
    for policy in ("round_robin", "kv_aware"):
        router = _router(ecfg, cfg, policy, params=params)
        reqs = fleet_request_stream(
            n, cfg.vocab_size, seed=5, arrival_rate=4e4,
            gen_interactive=(4, 8), gen_batch=(24, 32),
        )
        stats = router.run(reqs)
        results[policy] = stats
        rows.append(_emit_fleet(f"fleet_bursty_{policy}", stats))

    rr, kv = results["round_robin"], results["kv_aware"]
    p99_rr = rr.summary()["ttft_p99"]
    p99_kv = kv.summary()["ttft_p99"]
    ratio = p99_kv / max(p99_rr, 1e-12)
    emit(
        "fleet_bursty_kv_vs_rr", 0.0,
        f"ttft_p99_rr={p99_rr:.3e} ttft_p99_kv={p99_kv:.3e} "
        f"p99_ratio={ratio:.3f} kv_lower={p99_kv < p99_rr} "
        f"equal_tokens={kv.tokens == rr.tokens} tokens={kv.tokens}",
    )
    rows.append({
        "tag": "fleet_bursty_kv_vs_rr",
        "ttft_p99_rr": float(p99_rr),
        "ttft_p99_kv": float(p99_kv),
        "p99_ratio": float(ratio),
        "kv_lower": bool(p99_kv < p99_rr),
        "equal_tokens": bool(kv.tokens == rr.tokens),
        "tokens": int(kv.tokens),
    })
    assert kv.tokens == rr.tokens, "policies must serve equal tokens"
    assert p99_kv < p99_rr, (
        f"KV-aware placement must cut p99 TTFT vs round-robin on the "
        f"bursty stream (rr={p99_rr:.3e} kv={p99_kv:.3e})"
    )
    return rows


def run_shared_prefix(cfg, params):
    """Prefix-aware vs round-robin hit rate on the shared-prefix stream
    — token parity required (placement must be invisible to tokens)."""
    n = 12 if SMOKE else 32
    ecfg = EngineConfig(
        n_slots=2, max_seq=36, prefill_buckets=(32,), page_tokens=4,
        hot_window=16, local_budget_frac=0.5, admission="greedy",
        prefix_cache=True,
    )
    rows, results, outs = [], {}, {}
    for policy in ("round_robin", "prefix_aware"):
        router = _router(ecfg, cfg, policy, params=params)
        reqs = shared_prefix_stream(
            n, cfg.vocab_size, seed=3, system_tokens=24,
            prompt_buckets=(32,), gen_range=(4, 4), arrival_rate=4e4,
            n_systems=N_ENGINES,
        )
        stats = router.run(reqs)
        results[policy] = stats
        outs[policy] = [r.output for r in reqs]
        rows.append(_emit_fleet(f"fleet_shared_prefix_{policy}", stats))

    rr, pa = results["round_robin"], results["prefix_aware"]
    hit_rr = rr.prefix["hit_rate"]
    hit_pa = pa.prefix["hit_rate"]
    parity = outs["round_robin"] == outs["prefix_aware"]
    emit(
        "fleet_prefix_aware_vs_rr", 0.0,
        f"hit_rate_rr={hit_rr:.3f} hit_rate_aware={hit_pa:.3f} "
        f"aware_higher={hit_pa > hit_rr} token_parity={parity} "
        f"steered={pa.policy.get('steered', 0)} tokens={pa.tokens}",
    )
    rows.append({
        "tag": "fleet_prefix_aware_vs_rr",
        "hit_rate_rr": float(hit_rr),
        "hit_rate_aware": float(hit_pa),
        "aware_higher": bool(hit_pa > hit_rr),
        "token_parity": bool(parity),
        "steered": int(pa.policy.get("steered", 0)),
        "tokens": int(pa.tokens),
    })
    assert parity, "placement policy must be invisible to the tokens"
    assert hit_pa > hit_rr, (
        f"prefix-aware steering must beat round-robin's aggregate "
        f"prefix_hit_rate (rr={hit_rr:.3f} aware={hit_pa:.3f})"
    )
    return rows


def run_roles(cfg, params):
    """Disaggregated prefill/decode: one page transfer per request
    through the pool-transfer ledger."""
    n = 8 if SMOKE else 24
    ecfg = EngineConfig(
        n_slots=2, max_seq=96, prefill_buckets=(16, 32, 64),
        page_tokens=8, hot_window=16, local_budget_frac=0.5,
        admission="greedy", prefill_chunk=8,
    )
    router = _router(ecfg, cfg, "round_robin", params=params, roles=True)
    reqs = fleet_request_stream(
        n, cfg.vocab_size, seed=5, arrival_rate=4e4,
        gen_interactive=(4, 8), gen_batch=(24, 32),
    )
    stats = router.run(reqs)
    t = stats.transfers
    row = _emit_fleet(
        "fleet_roles", stats,
        extra=(f" transfers={t['transfers']} pages={t['pages']} "
               f"bytes={t['bytes']:.0f} "
               f"xfer_latency={t['mean_latency_s']:.2e}"),
    )
    row.update({"transfer_pages": int(t["pages"]),
                "transfer_bytes": float(t["bytes"]),
                "transfer_latency_s": float(t["mean_latency_s"])})
    assert t["transfers"] == n, (
        f"every request must hand off prefill->decode exactly once "
        f"(got {t['transfers']} for {n} requests)"
    )
    assert stats.tokens > 0
    return [row]


def run_faults(cfg, params):
    """Chaos recovery pricing: the identical bursty trace served fault-
    free and under the chaos_smoke plan (engine 1 killed mid-decode +
    10% transfer flaking). Bit parity is a hard assert (fp pools ->
    greedy argmax is placement- and recovery-invariant); the row prices
    what recovery COSTS — teacher-forced refill tokens, retry bytes,
    and the p99 TTFT inflation from the watchdog + re-route."""
    n = 16 if SMOKE else 48
    ecfg = EngineConfig(
        n_slots=2, max_seq=96, prefill_buckets=(16, 32, 64),
        page_tokens=8, hot_window=16, local_budget_frac=0.5,
        admission="greedy", pool_dtype="fp",
    )

    def _trace():
        return fleet_request_stream(
            n, cfg.vocab_size, seed=5, arrival_rate=4e4,
            gen_interactive=(4, 8), gen_batch=(24, 32),
        )

    clean_router = _router(ecfg, cfg, "round_robin", params=params)
    clean = _trace()
    clean_stats = clean_router.run(clean)

    router = FleetRouter.build(
        cfg, ParallelCtx(remat="none"), ecfg,
        FleetConfig(n_engines=N_ENGINES, policy="round_robin",
                    faults=make_plan("chaos_smoke")),
        params=params,
    )
    faulted = _trace()
    stats = router.run(faulted)
    f = stats.faults
    p99_clean = clean_stats.summary()["ttft_p99"]
    p99_fault = stats.summary()["ttft_p99"]
    ratio = p99_fault / max(p99_clean, 1e-12)
    parity = [r.output for r in faulted] == [r.output for r in clean]
    row = _emit_fleet(
        "fleet_faults", stats,
        extra=(f" killed={f.get('engines_killed', 0)} "
               f"refill={f.get('reprefilled_tokens', 0)} "
               f"retries={f.get('retries', 0)} "
               f"retry_bytes={f.get('retry_bytes', 0.0):.0f} "
               f"p99_ttft_ratio={ratio:.3f} parity={parity}"),
    )
    row.update({
        "recovery_overhead_tokens": float(f.get("reprefilled_tokens", 0)),
        "retry_bytes": float(f.get("retry_bytes", 0.0)),
        "p99_ttft_ratio": float(ratio),
        "token_parity": bool(parity),
    })
    assert parity, "recovery must be invisible to the tokens (fp pools)"
    assert f.get("engines_killed", 0) == 1
    assert f.get("reprefilled_tokens", 0) > 0, (
        "the kill must land mid-decode so adoption has tokens to refill"
    )
    for h in router.handles:
        p = h.engine.pager
        assert p.counters()["free_pages"] == p.n_phys
        if h.engine.substrate is not None:
            assert (p.pool_bytes_used()
                    == h.engine.substrate.ledger.placement_bytes())
    return [row]


def run():
    cfg = _cfg()
    # one param tree + one compiled cell set per EngineConfig shape; the
    # policies being compared share everything but the router policy
    import jax
    from repro.models import model as M
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    return (run_bursty(cfg, params) + run_shared_prefix(cfg, params)
            + run_roles(cfg, params) + run_faults(cfg, params))
