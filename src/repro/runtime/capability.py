"""Backend capability probes for memory-kind (tier) annotations.

XLA:TPU supports pinned_host placement on inputs, outputs and internal
transfers; XLA:CPU (this container) accepts pinned_host *inputs* but hits
UNIMPLEMENTED on output placement annotations. The tier engine degrades
gracefully: placements are always tracked logically; physical annotations are
applied per capability.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@functools.cache
def supports_host_input() -> bool:
    try:
        mesh = jax.sharding.Mesh(jax.devices()[:1], ("x",))
        s = NamedSharding(mesh, P(), memory_kind="pinned_host")
        x = jax.ShapeDtypeStruct((8,), jnp.float32)
        jax.jit(lambda a: a * 2, in_shardings=s).lower(x).compile()
        return True
    except Exception:
        return False


@functools.cache
def supports_host_output() -> bool:
    try:
        mesh = jax.sharding.Mesh(jax.devices()[:1], ("x",))
        s = NamedSharding(mesh, P(), memory_kind="pinned_host")
        x = jax.ShapeDtypeStruct((8,), jnp.float32)
        jax.jit(lambda a: a * 2, out_shardings=s).lower(x).compile()
        return True
    except Exception:
        return False


@functools.cache
def supports_internal_transfer() -> bool:
    try:
        x = jnp.ones((8,))

        def f(a):
            b = jax.device_put(
                a, jax.memory.TransferToMemoryKind("pinned_host")
            )
            return jax.device_put(
                b, jax.memory.TransferToMemoryKind("device")
            ) * 2

        jax.jit(f).lower(x).compile()
        return True
    except Exception:
        return False


def resolve_substrate_mode(requested: str, *, host_input: bool,
                           host_output: bool, internal: bool) -> str:
    """Pure mode resolution for the physical KV substrate.

    The substrate keeps a host-resident twin of the pool pages: it needs
    pinned_host *placement* of standing arrays (host_input — the twin is
    an input to nothing but device_put, but placement uses the same
    compile path) and jittable internal transfers for the page streams.
    host_output alone is not enough (can't round-trip pages back out).

      requested="physical"  — demand the real thing; raise if unsupported
      requested="emulated"  — force default-memory twin (same code shape,
                              same ledger; bytes counted, not moved
                              across memory kinds)
      requested="auto"      — physical when the backend can, else emulated
      requested="off"       — no substrate at all
    """
    if requested not in ("auto", "off", "emulated", "physical"):
        raise ValueError(
            f"substrate={requested!r} not in ('auto', 'off', 'emulated', "
            f"'physical')")
    if requested in ("off", "emulated"):
        return requested
    physical_ok = host_input and internal
    if requested == "physical":
        if not physical_ok:
            raise RuntimeError(
                "substrate='physical' requested but the backend probes "
                f"report host_input={host_input} internal={internal} "
                f"(host_output={host_output}); use 'auto' or 'emulated'")
        return "physical"
    return "physical" if physical_ok else "emulated"


def substrate_mode(requested: str = "auto") -> str:
    """Resolve the substrate mode against this process's backend probes."""
    return resolve_substrate_mode(
        requested,
        host_input=supports_host_input(),
        host_output=supports_host_output(),
        internal=supports_internal_transfer(),
    )
