"""Backend capability probes for memory-kind (tier) annotations.

XLA:TPU supports pinned_host placement on inputs, outputs and internal
transfers; XLA:CPU (this container) accepts pinned_host *inputs* but hits
UNIMPLEMENTED on output placement annotations. The tier engine degrades
gracefully: placements are always tracked logically; physical annotations are
applied per capability.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@functools.cache
def supports_host_input() -> bool:
    try:
        mesh = jax.sharding.Mesh(jax.devices()[:1], ("x",))
        s = NamedSharding(mesh, P(), memory_kind="pinned_host")
        x = jax.ShapeDtypeStruct((8,), jnp.float32)
        jax.jit(lambda a: a * 2, in_shardings=s).lower(x).compile()
        return True
    except Exception:
        return False


@functools.cache
def supports_host_output() -> bool:
    try:
        mesh = jax.sharding.Mesh(jax.devices()[:1], ("x",))
        s = NamedSharding(mesh, P(), memory_kind="pinned_host")
        x = jax.ShapeDtypeStruct((8,), jnp.float32)
        jax.jit(lambda a: a * 2, out_shardings=s).lower(x).compile()
        return True
    except Exception:
        return False


@functools.cache
def supports_internal_transfer() -> bool:
    try:
        x = jnp.ones((8,))

        def f(a):
            b = jax.device_put(
                a, jax.memory.TransferToMemoryKind("pinned_host")
            )
            return jax.device_put(
                b, jax.memory.TransferToMemoryKind("device")
            ) * 2

        jax.jit(f).lower(x).compile()
        return True
    except Exception:
        return False
