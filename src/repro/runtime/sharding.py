"""Logical-axis -> mesh-axis sharding rules.

Every parameter carries logical axis names (models/module.ParamSpec). Rules
map each logical axis to an ordered list of candidate mesh axes; resolution
is greedy per tensor: a candidate is taken iff the dim is divisible by the
mesh axis size and the mesh axis is not already used by an earlier dim.
Non-divisible dims fall back to replication (e.g. kv_heads=8 on a 16-way
model axis — the Megatron GQA duplication), and qwen's 40 heads fall through
to head_dim sharding.

This resolution strategy is what lets ONE rule table serve all 10 assigned
architectures on the fixed production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.module import ParamSpec, is_spec


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """mapping: logical axis -> tuple of candidate mesh axes (in order)."""

    mapping: dict
    memory_kind: Optional[str] = None

    @staticmethod
    def for_training(fsdp_axis: Optional[str] = "data",
                     tp_axis: Optional[str] = "model"):
        tp = (tp_axis,) if tp_axis else ()
        fsdp = (fsdp_axis,) if fsdp_axis else ()
        return ShardingRules(
            mapping={
                "layers": (),
                "embed": fsdp,
                "vocab": tp,
                "qheads": tp,
                "kvheads": tp,
                # NB: head_dim is deliberately NOT sharded — a contraction
                # over a sharded head_dim psums the score matrix inside the
                # attention inner loop (measured: 19 TB of all-reduce for
                # smollm train_4k). Archs whose head counts don't divide the
                # model axis replicate attention weights instead.
                "head_dim": (),
                "ff": tp,
                "experts": tp,
                "moe_ff": fsdp,
                "ssm_inner": tp,
                "ssm_heads": (),
            }
        )

    @staticmethod
    def for_serving(data_axis: Optional[str] = "data",
                    tp_axis: Optional[str] = "model"):
        """Weight-stationary serving: no FSDP weight gathers on the decode
        path (measured: 40 GB of all-gather per decoded token with training
        rules). Dense projections are TP-sharded or replicated; only the
        huge MoE expert tensors keep a second shard axis (contraction-psum
        of token-sized activations is cheap at decode batch sizes)."""
        tp = (tp_axis,) if tp_axis else ()
        d = (data_axis,) if data_axis else ()
        return ShardingRules(
            mapping={
                "layers": (),
                "embed": (),
                "vocab": tp,
                "qheads": tp,
                "kvheads": tp,
                "head_dim": (),
                "ff": tp,
                "experts": tp,
                "moe_ff": d,
                "ssm_inner": tp,
                "ssm_heads": (),
            }
        )

    @staticmethod
    def replicated():
        return ShardingRules(mapping={})


def _resolve(axes: ParamSpec, shape, rules: ShardingRules, mesh) -> P:
    used = set()
    out = []
    for dim, logical in zip(shape, axes.axes):
        chosen = None
        if logical is not None:
            for cand in rules.mapping.get(logical, ()):
                if cand is None or cand in used or cand not in mesh.shape:
                    continue
                if dim % mesh.shape[cand] != 0:
                    continue
                chosen = cand
                break
        out.append(chosen)
        if chosen is not None:
            used.add(chosen)
    return P(*out)


def shardings_for_tree(values, axes_tree, rules: ShardingRules, mesh):
    """Matching tree of NamedSharding for a (params|moments) tree."""

    def one(value, spec):
        assert is_spec(spec), spec
        pspec = _resolve(spec, value.shape, rules, mesh)
        kwargs = {}
        if rules.memory_kind is not None:
            kwargs["memory_kind"] = rules.memory_kind
        return NamedSharding(mesh, pspec, **kwargs)

    return jax.tree.map(one, values, axes_tree,
                        is_leaf=lambda x: is_spec(x))


def pspecs_for_tree(values, axes_tree, rules: ShardingRules, mesh):
    return jax.tree.map(
        lambda v, s: _resolve(s, v.shape, rules, mesh),
        values, axes_tree, is_leaf=lambda x: is_spec(x),
    )


def batch_pspec(batch, dp_axes, mesh) -> dict:
    """Shard dim0 (global batch) over dp axes when divisible."""
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    def one(x):
        if x.shape and x.shape[0] % dp_size == 0 and dp_size > 1:
            return P(dp_axes)
        return P()

    return jax.tree.map(one, batch)


def cache_pspec(caches, dp_axes, tp_axis, mesh):
    """Decode-cache sharding: batch over dp when divisible; the long seq dim
    of attention KV over the model axis (sequence-sharded KV); SSM heads over
    model. Leaf layout (see blocks.init_caches):
      k/v/cross_k/cross_v: (nb, B, S, KV, hd)
      state:               (nb, B, H, P, N)
      tail_*:              (nb, B, W-1, C)
    """
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    tp = mesh.shape.get(tp_axis, 1) if tp_axis else 1

    def path_aware(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        b_ax = dp_axes if (x.shape[1] % dp_size == 0 and dp_size > 1) else None
        if name in ("k", "v", "cross_k", "cross_v"):
            s_ax = tp_axis if (tp > 1 and x.shape[2] % tp == 0) else None
            return P(None, b_ax, s_ax, None, None)
        if name == "state":
            h_ax = tp_axis if (tp > 1 and x.shape[2] % tp == 0) else None
            return P(None, b_ax, h_ax, None, None)
        # conv tails: (nb, B, W-1, C): channel over model if divisible
        c_ax = tp_axis if (tp > 1 and x.shape[3] % tp == 0) else None
        return P(None, b_ax, None, c_ax)

    return jax.tree_util.tree_map_with_path(path_aware, caches)


def paged_cache_pspec(caches, dp_axes, tp_axis, mesh):
    """Paged-layout cache sharding (see blocks.init_paged_caches):

      k/v:        (nb, P_phys, page_tokens, KV, hd) — KV heads over the
                  model (tp) axis when divisible. The physical page axis
                  is gathered through the block table, so it must stay
                  unsharded; page_tokens/head_dim stay local to keep the
                  attention contraction shard-local per head group.
      k_sz/v_sz:  (nb, P_phys, KV, 2) per-page, or (nb, P_phys,
                  page_tokens, KV, 2) per-token (rank-dispatched like
                  the kernels) — the int8 (scale, zero) leaves split on
                  the SAME head axis as the payload: each tp shard
                  dequantizes exactly its own heads.
      resident leaves (dense per-slot axis 1): slots over dp when
                  divisible — state (nb, B, H, P, N) also takes heads
                  over tp, conv tails (nb, B, W-1, C) channel over tp,
                  cross_k/v (nb, B, enc, KV, hd) heads over tp.

    The (n_slots, n_pages) block tables are REPLICATED (passed to the
    cells with a None in_sharding): every shard resolves the same
    logical->physical mapping and gathers its own head slice.
    """
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    tp = mesh.shape.get(tp_axis, 1) if tp_axis else 1

    def tp_ax(dim):
        return tp_axis if (tp > 1 and dim % tp == 0) else None

    def path_aware(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v"):
            return P(None, None, None, tp_ax(x.shape[3]), None)
        if name in ("k_sz", "v_sz"):
            if x.ndim == 5:                    # per-token sub-scales
                return P(None, None, None, tp_ax(x.shape[3]), None)
            return P(None, None, tp_ax(x.shape[2]), None)
        b_ax = dp_axes if (x.shape[1] % dp_size == 0 and dp_size > 1) \
            else None
        if name in ("cross_k", "cross_v"):
            return P(None, b_ax, None, tp_ax(x.shape[3]), None)
        if name == "state":
            return P(None, b_ax, tp_ax(x.shape[2]), None, None)
        # conv tails: (nb, B, W-1, C)
        return P(None, b_ax, None, tp_ax(x.shape[3]))

    return jax.tree_util.tree_map_with_path(path_aware, caches)


def named(mesh, pspec_tree, memory_kind=None):
    kwargs = {"memory_kind": memory_kind} if memory_kind else {}

    def one(s):
        return NamedSharding(mesh, s, **kwargs)

    return jax.tree.map(one, pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))
