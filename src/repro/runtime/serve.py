"""Serve-step builders: prefill (prompt -> caches) and decode (one token vs
the KV cache / SSM state). `decode_32k` and `long_500k` cells lower the
decode step; `prefill_32k` lowers prefill — per the brief.

Two consumers:

* the dry-run/launcher path keeps the classic `ServeBundle` (one jitted
  prefill + one jitted decode over a uniform batch of per-slot contiguous
  caches);
* the continuous-batching engine (`repro.serving.engine`) uses
  `make_engine_cells`: a fixed set of jitted cells — one greedy decode cell
  over the whole slot batch with per-slot positions, one prefill cell per
  prompt bucket, and one cache-insert cell per bucket. Every shape is
  fixed at build time, so a steady-state serve loop never recompiles
  regardless of admissions/completions (slot masking via parked write
  positions, see `models.attention._cache_insert`).

With `paged=True` (the engine's default) the KV cache IS a physical page
pool: self-attention K/V leaves are (nb, n_slots * n_pages, page_tokens,
KV, hd) and every cell takes the live (n_slots, n_pages) block table from
`serving.kv_pager.KVPager.block_table()` — the single allocator whose
free list and tier tags drive both the kernel gather and the byte
accounting. The decode cell runs `kernels/decode_attention/paged.py`
(interpret mode on CPU, compiled pallas on TPU) over that table; the
insert cell lands a prefilled request's pages in the pool through the
aliased page-writer kernel (`kernels.page_io.write_pages` — in-place via
`input_output_aliases`, zero standalone scatters on the kernel
backends); and on attention-only stacks (`chunked_prefill_supported`) a
chunked-prefill cell (`kernels/flash_attention/paged_prefill.py`)
processes one page-aligned prompt chunk per call — the chunk's K/V
write is FUSED into the paged-prefill kernel itself (the chunk tiles
are operands, the pool arrays alias input->output), so the cell
flash-attends to everything prefilled so far without the separate jnp
page-scatter's extra read+write of the chunk — and the engine can
interleave prefill chunks with decode steps instead of stalling the
whole slot batch for a long prompt. The block table and the chunk index
are runtime arrays, never Python constants: slot churn, page churn and
chunk progress all replay through the same compiled cells.

`pool_dtype` makes the pool payload polymorphic ("fp" exact | "bf16"
cast | "int8" per-page block quantization): with int8 the attention
cache dicts carry per-page float32 (scale, zero) leaves ("k_sz"/"v_sz",
(nb, n_slots * n_pages, KV, 2), `repro.kernels.quant`), the insert and
chunk cells quantize whole pages on the way in (the decode cell
requantizes the slot's tail page around each new token), and both paged
kernels dequantize in their gather epilogue. `sz_granularity="token"`
swaps the per-page (scale, zero) rows for PER-TOKEN sub-scales
((nb, P, page_tokens, KV, 2) — rank-dispatched everywhere on
`sz.ndim == pool.ndim`): each cached token quantizes independently over
its head dim, so inserting a token is a pure disjoint scatter with no
read-modify-write of the page's neighbours — the layout speculative
verify requires (k candidate rows of one slot land in the same tail
page concurrently) and the KV-side twin of the W8A8 activation-row
quantization in `kernels/matmul_w8a8`.

SPECULATIVE DECODING: `build_decode_verify_paged` scores k candidate
tokens per slot in ONE paged-decode call by flattening (S, k)
candidates to S*k decode rows with vector positions t[s]+j and
k-repeated block-table rows. Greedy acceptance
(`serving.speculative.accept_greedy`) emits the longest candidate
prefix that matches what greedy decode would have produced — bit-
identical token streams by construction — so each sweep of the pool-
resident KV pages is amortized over `1 + accepted` tokens instead of
exactly one (decode is the lowest-arithmetic-intensity loop in the
system; this is the AI lever). Proposers live in `serving.speculative`:
"ngram" (self-speculative suffix matching over the slot's own history,
zero extra parameters) and "draft" (a small draft model decoded by
`build_decode_draft` against its own contiguous caches, weights shared
across a fleet through `EngineCells`). Rejected positions leave garbage
KV beyond the frontier; every kernel already masks beyond the slot
length and `KVPager.truncate` rolls back the page accounting. Bytes per cached token =
2 * KV * hd * payload_bytes * nb (+ 2 * KV * 8 * nb / page_tokens for
the int8 scale arrays) — `core.access.kv_pool_token_bytes` — which is
what the pager and admission corridor price.

Block tables may ALIAS (shared prompt prefixes, `serving.prefix_cache`):
the gather side reads an aliased page identically for every sharer, and
the write side never sees one — the pager guarantees write targets are
private, COW-splitting shared tail pages via `build_page_copy` (the
one cell sharing adds; the kernels themselves need zero changes). The
deduplicated footprint is then
(n_sharers * (n_tokens - shared) + shared) * token_bytes instead of
n_sharers * n_tokens * token_bytes — `core.access.kv_dedup_token_bytes`
is the closed-form twin of `KVPager.phys_tiers()` under sharing.

MESH-SHARDED PAGED SERVING: `make_engine_cells(mesh=...)` jits every
cell (paged decode, bucketed prefill, paged insert, chunked prefill,
COW page-copy) with explicit in/out shardings over two axes — KV heads
over the model (`tp`) axis for the pool payload and int8 scale leaves,
slots over the data (`dp`) axis for the resident leaves
(`runtime.sharding.paged_cache_pspec`; params follow the weight-
stationary `ShardingRules.for_serving` table). Block tables and the
per-slot token/position vectors are REPLICATED: each shard resolves
the identical logical->physical page mapping and gathers only its own
head slice, so the page allocator stays a single host-side object and
the token stream is bit-identical to the single-device engine (the CI
`sharded-parity` lane forces an 8-device host mesh and asserts exactly
that). Interpret-mode pallas lowers the paged kernels to plain HLO, so
GSPMD partitions them like any jnp program; compiled-TPU kernel
partitioning rides the same shardings.

TIER LAYOUT / TRANSFER-STREAM CONTRACT (`repro.serving.substrate`):
the cells only ever touch the DEVICE pool — the authoritative copy.
The engine additionally owns a `TierSubstrate` holding a pinned_host
(or emulated default-memory) zeros twin of the paged leaves; after
each pager step it reconciles the twin against
`KVPager.pool_page_ids()`, issuing async jitted gather/scatter streams
(page_out device->host, page_in host->device, drop on free) whose
completion-tracked `SubstrateLedger` measures real array bytes. The
contract: after every drain, `pager.pool_bytes_used()` equals
`ledger.placement_bytes()` — `phys_tiers()` pool accounting is actual
placement, not a derived price.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.module import shape_mode
from repro.runtime import sharding as shd


def abstract_params(cfg: ModelConfig, serve_dtype: bool = True):
    """Abstract param tree; serving uses inference dtype (bf16) weights."""
    with shape_mode():
        params, axes = M.init_model(cfg, jax.random.PRNGKey(0))
    if serve_dtype:
        dt = jnp.dtype(cfg.dtype)

        def cast(p):
            if jnp.issubdtype(p.dtype, jnp.floating):
                return jax.ShapeDtypeStruct(p.shape, dt)
            return p

        params = jax.tree.map(cast, params)
    return params, axes


def abstract_caches(cfg: ModelConfig, batch: int, max_seq: int,
                    enc_len: int = 0):
    caches = jax.eval_shape(
        lambda: M.make_decode_caches(cfg, batch, max_seq, enc_len)
    )
    return caches


def build_prefill(cfg: ModelConfig, ctx: ParallelCtx, max_seq: int):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, ctx, max_seq)

    return prefill_step


def build_decode(cfg: ModelConfig, ctx: ParallelCtx):
    def decode_step(params, token, caches, t):
        return M.decode_step(params, token, caches, t, cfg, ctx)

    return decode_step


@dataclasses.dataclass
class ServeBundle:
    prefill_fn: Any
    decode_fn: Any
    param_shardings: Any
    cache_shardings: Any
    abstract_params: Any
    abstract_caches: Any


def make_bundle(cfg: ModelConfig, ctx: ParallelCtx,
                rules: shd.ShardingRules, mesh,
                batch: int, max_seq: int, enc_len: int = 0,
                param_shardings_override=None) -> ServeBundle:
    aparams, axes = abstract_params(cfg)
    param_sh = param_shardings_override or shd.shardings_for_tree(
        aparams, axes, rules, mesh
    )
    acaches = abstract_caches(cfg, batch, max_seq, enc_len)
    cache_sh = shd.named(
        mesh, shd.cache_pspec(acaches, ctx.dp_axes, ctx.tp_axis, mesh)
    )
    batch_shardable = (
        ctx.dp_axes and batch % max(ctx.dp_size, 1) == 0 and ctx.dp_size > 1
    )
    tok_sh = shd.named(
        mesh, P(ctx.dp_axes) if batch_shardable else P()
    )
    # prompt batch: dim0 (requests) over dp axes — a prefix sharding covers
    # every leaf of the batch dict (tokens / patches / frames)
    prompt_sh = shd.named(
        mesh, P(ctx.dp_axes) if batch_shardable else P()
    )
    prefill = jax.jit(
        build_prefill(cfg, ctx, max_seq),
        in_shardings=(param_sh, prompt_sh),
    )
    decode = jax.jit(
        build_decode(cfg, ctx),
        in_shardings=(param_sh, tok_sh, cache_sh, None),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    return ServeBundle(prefill, decode, param_sh, cache_sh, aparams, acaches)


# ------------------------------------------------- continuous batching
def chunked_prefill_supported(cfg: ModelConfig) -> bool:
    """Chunked prefill needs a pure-attention decoder with no frontend
    prefix and no encoder: an SSM/conv layer's prompt pass is a sequential
    reduction that cannot restart mid-stream from paged KV alone."""
    from repro.models import blocks

    if cfg.num_encoder_layers or cfg.frontend:
        return False
    return all(
        cfg.is_attn_layer(j) for j in range(blocks.super_period(cfg))
    )


def abstract_paged_caches(cfg: ModelConfig, n_slots: int, max_seq: int,
                          page_tokens: int, enc_len: int = 0,
                          pool_dtype: str = "fp",
                          sz_granularity: str = "page"):
    return jax.eval_shape(
        lambda: M.make_paged_decode_caches(
            cfg, n_slots, max_seq, page_tokens, enc_len,
            pool_dtype=pool_dtype, sz_granularity=sz_granularity,
        )
    )


def build_decode_greedy(cfg: ModelConfig, ctx: ParallelCtx):
    """Greedy decode cell: one token per slot, argmax inside the jit so the
    host only ever syncs an int32 vector plus a scalar finiteness flag
    (argmax of NaN logits would otherwise turn a numerical blow-up into
    silently wrong token streams). `t` is the per-slot position vector
    (see models.model.decode_step)."""

    def cell(params, token, caches, t):
        logits, caches = M.decode_step(params, token, caches, t, cfg, ctx)
        finite = jnp.isfinite(logits).all(axis=-1)   # per slot: parked
        # slots carry garbage caches, so the engine masks them out
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), finite, caches

    return cell


def build_prefill_greedy(cfg: ModelConfig, ctx: ParallelCtx, bucket: int):
    """Prefill cell for one prompt bucket: returns the request's decode
    caches (seq extent `bucket` + frontend prefix; cross-KV extent follows
    the frames in the batch) and its greedy first token. Prompts must be
    exactly `bucket` long (see serving.batcher)."""

    def cell(params, batch):
        caches, logits = M.prefill(params, batch, cfg, ctx, max_seq=bucket)
        return caches, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return cell


def build_decode_greedy_paged(cfg: ModelConfig, ctx: ParallelCtx,
                              page_tokens: int):
    """Greedy decode cell over the PAGED caches: same contract as
    `build_decode_greedy` plus the live block table — the decode step
    reads and writes the physical page pool through
    `kernels/decode_attention/paged.py`."""

    def cell(params, token, caches, t, block_table):
        logits, caches = M.decode_step(
            params, token, caches, t, cfg, ctx,
            block_table=block_table, page_tokens=page_tokens,
        )
        finite = jnp.isfinite(logits).all(axis=-1)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), finite, caches

    return cell


def build_decode_verify_paged(cfg: ModelConfig, ctx: ParallelCtx,
                              page_tokens: int, k: int):
    """SPECULATIVE VERIFY cell: score k candidate tokens per slot in ONE
    paged-decode call — the vector-`t` extension of
    `build_decode_greedy_paged` that amortizes each sweep of the
    pool-resident KV pages over k tokens instead of one (the
    arithmetic-intensity lever the paper's pooled-memory corridor prices;
    greedy decode is the lowest-AI loop in the system).

    Contract: `cand` (S, k) int32 with cand[s, 0] the slot's last emitted
    (not yet inserted) token and cand[s, 1:] the proposer's drafts; `t`
    (S,) the position cand[s, 0] will occupy. The (S, k) batch flattens
    to S*k decode rows: row j of slot s feeds cand[s, j] at position
    t[s]+j against the slot's OWN block-table row (repeated k times), so
    the flattened KV insert lands all k candidate tokens before
    attention and row j's length mask (t+j+1) lets it see candidates
    0..j — teacher-forced causal scoring. Returns (greedy (S, k) int32,
    finite (S,), caches) where greedy[s, j] is the model's pick FOR
    position t[s]+j+1, i.e. the token that follows cand[s, j]:

        accept a = max prefix with cand[s, i+1] == greedy[s, i];
        emit greedy[s, 0..a] (a+1 tokens) — bit-identical to running
        greedy decode a+1 times, by construction.

    Positions t+e..t+k-1 of a partially-accepted slot hold wrong-token
    KV afterwards; every kernel masks them out (length <= frontier) and
    the next verify call overwrites them, so only the pager's page
    accounting needs rollback (`KVPager.truncate`). int8 pools MUST use
    the per-token sub-scale layout (`sz_granularity="token"`): the
    per-page requantize round trip would make a slot's k rows
    read-modify-write the same tail page concurrently."""

    def cell(params, cand, caches, t, block_table):
        S = cand.shape[0]
        tok_flat = cand.reshape(S * k)
        t_flat = (
            t[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
        ).reshape(S * k)
        bt_flat = jnp.repeat(block_table, k, axis=0)
        logits, caches = M.decode_step(
            params, tok_flat, caches, t_flat, cfg, ctx,
            block_table=bt_flat, page_tokens=page_tokens,
        )
        finite = jnp.isfinite(logits).all(axis=-1).reshape(S, k).all(axis=1)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy.reshape(S, k), finite, caches

    return cell


def build_cache_insert():
    """Splice a prefilled request's caches (batch=1, short seq extent) into
    the global slot caches at a traced slot index. A dynamic-update-slice
    per leaf: leading (stack, batch) dims, then the seq/state extents."""

    def insert(caches, slot_caches, slot):
        slot = jnp.asarray(slot, jnp.int32)

        def ins(big, small):
            idx = (jnp.int32(0), slot) + (jnp.int32(0),) * (big.ndim - 2)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), idx
            )

        return jax.tree.map(ins, caches, slot_caches)

    return insert


def build_decode_draft(cfg: ModelConfig, ctx: ParallelCtx):
    """Draft-model decode cell for the speculative "draft" proposer: one
    greedy token per slot against the draft's own CONTIGUOUS caches
    (`M.make_decode_caches` — the draft prefix is short-lived scratch,
    so it skips the paged pool entirely). Same vector-`t` contract as
    `build_decode_greedy`; the finite flag is dropped (a non-finite
    draft can only propose tokens the verify cell rejects)."""

    def cell(params, token, caches, t):
        logits, caches = M.decode_step(params, token, caches, t, cfg, ctx)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    return cell


def build_paged_cache_insert(bucket_total: int, page_tokens: int,
                             pool_dtype: str = "fp",
                             sz_granularity: str = "page"):
    """Land a prefilled request's caches in the PAGED layout: the
    request's `bucket_total` tokens of K/V (batch=1, dense from the
    prefill cell) go whole-page into the physical pool at the pages the
    block table assigns to the traced slot index — through the aliased
    page-writer kernel (`kernels.page_io.write_pages`), the same
    in-place treatment the fused chunk kernel gives the chunked path,
    so the insert cell issues zero standalone page-scatter ops on the
    kernel backends. With `pool_dtype="int8"` the prompt pages are
    block-quantized first (`kernels.quant.quantize_pages` — elementwise)
    and the per-page (scale, zero) rows land through the same writer.
    Resident leaves (SSM state, conv tails, cross-KV) keep the dense
    dynamic-update-slice. The final partial page carries garbage beyond
    `bucket_total` — those positions are >= the slot's length, so the
    kernels' masks exclude them and decode overwrites them before the
    length ever reaches them (the quantized insert zero-fills them so
    they cannot pollute the page's range). With
    `sz_granularity="token"` the prompt pages get per-token sub-scales
    instead (`kernels.quant.quantize_tokens`) and the (page_tokens, KV,
    2) sz tiles land through the same generic page writer."""
    from repro.kernels import quant
    from repro.kernels.page_io import ops as page_ops

    n_wp = -(-bucket_total // page_tokens)     # pages the prompt spans
    pad = n_wp * page_tokens - bucket_total
    quantized = pool_dtype == "int8"
    per_token = quantized and sz_granularity == "token"

    def insert(caches, slot_caches, slot, block_table):
        slot = jnp.asarray(slot, jnp.int32)
        row = jax.lax.dynamic_index_in_dim(
            block_table, slot, 0, keepdims=False
        )                                      # (n_pages,)
        phys = row[:n_wp]

        def ins_dense(big, small):
            idx = (jnp.int32(0), slot) + (jnp.int32(0),) * (big.ndim - 2)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), idx
            )

        def page_tiles(small):
            sm = small[:, 0]                   # (nb, bucket_total, KV, hd)
            # zero-pad the partial-page tail: masked out of attention, and
            # under int8 it cannot widen the last page's quantization range
            sm = jnp.pad(sm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            nb, _, kv, hd = sm.shape
            return sm.reshape(nb, n_wp, page_tokens, kv, hd)

        out = {}
        for pos, c in caches.items():
            oc = {}
            for key, big in c.items():
                if key in ("k", "v", "k_sz", "v_sz"):
                    continue
                oc[key] = ins_dense(big, slot_caches[pos][key])
            for key in ("k", "v"):
                if key not in c:
                    continue
                tiles = page_tiles(slot_caches[pos][key])
                if quantized:
                    if per_token:
                        q8, sz_rows = quant.quantize_tokens(tiles)
                    else:
                        q8, sz_rows = quant.quantize_pages(tiles)
                    oc[key] = page_ops.write_pages(c[key], q8, phys)
                    oc[key + "_sz"] = page_ops.write_pages(
                        c[key + "_sz"], sz_rows, phys
                    )
                else:
                    oc[key] = page_ops.write_pages(c[key], tiles, phys)
            out[pos] = oc
        return out

    return insert


def build_page_copy():
    """Copy one PHYSICAL page (payload + int8 scale/zero rows when
    present) to another — the copy-on-write cell behind the prefix
    cache's shared pages (`serving.prefix_cache`): when a slot is about
    to write into a page whose refcount > 1, the pager repoints it at a
    free page (`KVPager.cow_split`) and the engine runs this cell to
    materialize the private duplicate BEFORE the decode cell's scatter —
    so a shared page is never mutated, which is the whole COW contract.
    One dynamic_slice + dynamic_update_slice per paged leaf along the
    physical-page axis; resident leaves pass through untouched (they are
    per-slot, never shared). `src`/`dst` are traced scalars: page churn
    replays through one compiled cell."""

    def copy(caches, src, dst):
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)

        def cp(big):
            page = jax.lax.dynamic_slice_in_dim(big, src, 1, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(
                big, page, dst, axis=1
            )

        out = {}
        for pos, c in caches.items():
            oc = dict(c)
            for key in ("k", "v", "k_sz", "v_sz"):
                if key in c:
                    oc[key] = cp(c[key])
            out[pos] = oc
        return out

    return copy


def build_prefill_chunk(cfg: ModelConfig, ctx: ParallelCtx,
                        page_tokens: int):
    """Chunked-prefill cell: one page-aligned chunk of one request's
    prompt against the global PAGED caches — no separate per-request
    caches, no insert step; the chunk's K/V goes straight through the
    block table into the pool. Returns the chunk's last-token greedy
    pick (the engine uses it only on the final chunk)."""

    def cell(params, tokens, caches, slot, chunk_idx, block_table):
        row = jax.lax.dynamic_index_in_dim(
            block_table, jnp.asarray(slot, jnp.int32), 0, keepdims=True
        )                                      # (1, n_pages)
        logits, caches = M.prefill_chunk(
            params, tokens, caches, chunk_idx, cfg, ctx, row, page_tokens
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    return cell


@dataclasses.dataclass
class EngineCells:
    """The fixed-shape jitted cells of the continuous-batching engine.

    Paged mode: `decode_fn` and `insert_fns` additionally take the live
    (n_slots, n_pages) int32 block table as their last argument, and
    `chunk_fn` (attention-only archs with `prefill_chunk` set) processes
    one page-aligned prompt chunk: (params, tokens (1, C), caches, slot,
    chunk_idx, block_table) -> (tok (1,), caches) [donates caches]."""

    decode_fn: Any                 # (params, tok (S,), caches, t (S,)[, bt])
    #                     -> (next_tok (S,), finite, caches) [donates caches]
    prefill_fns: Dict[int, Any]    # bucket -> (params, batch) -> (caches, tok)
    insert_fns: Dict[int, Any]     # bucket -> (caches, slot_caches, slot[, bt])
    param_shardings: Any
    cache_shardings: Any
    abstract_params: Any
    abstract_caches: Any
    n_prefix: int                  # frontend prefix tokens (vision)
    max_seq_total: int             # cache seq extent incl. n_prefix
    paged: bool = False            # physical page-pool cache layout
    page_tokens: int = 0           # tokens per page (paged mode)
    n_pages: int = 0               # logical pages per slot (paged mode)
    pool_dtype: str = "fp"         # pool payload: fp | bf16 | int8
    chunk_fn: Any = None           # chunked-prefill cell (paged mode only)
    chunk: int = 0                 # tokens per prefill chunk
    copy_fn: Any = None            # COW page-copy cell (paged mode):
    #                     (caches, src_phys, dst_phys) -> caches [donates]
    sz_granularity: str = "page"   # int8 sub-scale layout: page | token
    verify_fn: Any = None          # speculative verify cell (paged mode):
    #    (params, cand (S, k), caches, t (S,), bt) ->
    #    (greedy (S, k), finite (S,), caches) [donates caches]
    spec_k: int = 0                # candidate tokens per verify call
    draft_fn: Any = None           # draft-proposer decode cell:
    #    (params, tok (S,), caches, t (S,)) -> (tok (S,), caches) [donates]
    draft_params: Any = None       # draft weights (PRNGKey(0); one tree
    #                                shared across a fleet via the cells)
    draft_cfg: Any = None          # draft ModelConfig (sizes draft caches)

    def compile_counts(self) -> Dict[str, int]:
        """Executable-cache sizes of every cell — the no-recompile
        assertion reads this before/after steady state (-1 when the jax
        build does not expose `_cache_size`)."""

        def size(fn):
            probe = getattr(fn, "_cache_size", None)
            return int(probe()) if probe is not None else -1

        out = {"decode": size(self.decode_fn)}
        for b, fn in self.prefill_fns.items():
            out[f"prefill_{b}"] = size(fn)
        for b, fn in self.insert_fns.items():
            out[f"insert_{b}"] = size(fn)
        if self.chunk_fn is not None:
            out["prefill_chunk"] = size(self.chunk_fn)
        if self.copy_fn is not None:
            out["page_copy"] = size(self.copy_fn)
        if self.verify_fn is not None:
            out["verify"] = size(self.verify_fn)
        if self.draft_fn is not None:
            out["draft"] = size(self.draft_fn)
        return out


def make_engine_cells(cfg: ModelConfig, ctx: ParallelCtx,
                      rules=None, mesh=None, *,
                      n_slots: int, max_seq: int,
                      buckets: Sequence[int], enc_len: int = 0,
                      paged: bool = False, page_tokens: int = 16,
                      prefill_chunk: int = 0, pool_dtype: str = "fp",
                      sz_granularity: str = "page",
                      speculative: str = "off", spec_k: int = 4,
                      draft_cfg: ModelConfig | None = None,
                      ) -> EngineCells:
    """Build the engine's cells. With a mesh, shardings come from the same
    rules as `make_bundle` (this is the ServeBundle path refactored for
    slot batching); meshless builds plain single-device jits.

    `paged=True` lays the self-attention KV cache out as the physical
    page pool the serving pager allocates from (see module docstring);
    `prefill_chunk > 0` (paged, attention-only archs) additionally builds
    the chunked-prefill cell. `pool_dtype` picks the pool payload
    (models.blocks.POOL_DTYPES): "fp" is the exact safety net, "int8"
    block-quantizes every pool page (quantize-on-insert in the insert/
    chunk/decode cells, dequantize-in-kernel on the gather side).

    `speculative` ("off" | "ngram" | "draft") additionally builds the
    k-candidate verify cell (`build_decode_verify_paged`) and, for
    "draft", the draft-proposer cell + its weights. Speculative mode
    requires the paged layout and an attention-only decoder (the verify
    cell flattens S slots to S*k decode rows, which only the paged
    attention path supports), and int8 pools must use
    `sz_granularity="token"` (see module docstring)."""
    from repro.models import blocks as blk

    blk.pool_kv_dtype(cfg, pool_dtype)         # validate early
    if pool_dtype != "fp" and not paged:
        raise ValueError("pool_dtype applies to the paged layout only")
    if sz_granularity not in ("page", "token"):
        raise ValueError(f"unknown sz_granularity {sz_granularity!r}")
    if sz_granularity == "token" and pool_dtype != "int8":
        raise ValueError("sz_granularity='token' applies to int8 pools only")
    if speculative not in ("off", "ngram", "draft"):
        raise ValueError(f"unknown speculative mode {speculative!r}")
    if speculative != "off":
        if not paged:
            raise ValueError("speculative decoding requires the paged layout")
        if not chunked_prefill_supported(cfg):
            raise ValueError(
                f"{cfg.name}: speculative decoding needs an attention-only "
                "decoder without frontend/encoder (the verify cell batches "
                "S*k rows, which SSM/conv state cannot follow)"
            )
        if spec_k < 2:
            raise ValueError("spec_k must be >= 2 (k=1 is plain greedy)")
        if pool_dtype == "int8" and sz_granularity != "token":
            raise ValueError(
                "speculative + int8 pools need sz_granularity='token': the "
                "per-page requantize round trip would make a slot's k "
                "candidate rows read-modify-write the same tail page"
            )
        if speculative == "draft" and draft_cfg is None:
            raise ValueError("speculative='draft' needs a draft_cfg")
    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    if cfg.num_encoder_layers and len(set(buckets)) != 1:
        raise ValueError(
            "enc-dec archs need a single prefill bucket (cross-KV extent "
            "is fixed by the encoder length)"
        )
    max_seq_total = max_seq + npfx
    n_pages = -(-max_seq_total // page_tokens) if paged else 0
    if prefill_chunk:
        if not paged:
            raise ValueError("chunked prefill requires the paged layout")
        if not chunked_prefill_supported(cfg):
            raise ValueError(
                f"{cfg.name}: chunked prefill needs an attention-only "
                "decoder without frontend/encoder"
            )
        if prefill_chunk % page_tokens:
            raise ValueError(
                f"prefill_chunk {prefill_chunk} must be a multiple of "
                f"page_tokens {page_tokens} (chunks write whole pages)"
            )
        bad = [b for b in buckets if b % prefill_chunk]
        if bad:
            raise ValueError(
                f"prefill_chunk {prefill_chunk} must divide every prompt "
                f"bucket (got {bad}): prompts advance whole chunks"
            )

    param_sh = cache_sh = tok_sh = None
    aparams = acaches = None
    if mesh is not None:
        if rules is None:
            rules = shd.ShardingRules.for_serving(
                data_axis=ctx.fsdp_axis, tp_axis=ctx.tp_axis
            )
        bundle = make_bundle(
            cfg, ctx, rules, mesh, batch=n_slots, max_seq=max_seq_total,
            enc_len=enc_len,
        )
        param_sh = bundle.param_shardings
        aparams = bundle.abstract_params
        if paged:
            # paged mesh layout (shd.paged_cache_pspec): pool payload +
            # int8 scale leaves split on KV heads over tp, resident
            # leaves on slots over dp, block tables replicated (the
            # trailing None in_sharding below)
            acaches = abstract_paged_caches(
                cfg, n_slots, max_seq_total, page_tokens, enc_len,
                pool_dtype=pool_dtype, sz_granularity=sz_granularity,
            )
            cache_sh = shd.named(
                mesh,
                shd.paged_cache_pspec(
                    acaches, ctx.dp_axes, ctx.tp_axis, mesh
                ),
            )
        else:
            cache_sh = bundle.cache_shardings
            acaches = bundle.abstract_caches
        tok_sh = shd.named(mesh, P())
        decode_cell = (
            build_decode_greedy_paged(cfg, ctx, page_tokens) if paged
            else build_decode_greedy(cfg, ctx)
        )
        in_sh = (param_sh, tok_sh, cache_sh, None)
        decode = jax.jit(
            decode_cell,
            in_shardings=in_sh + (None,) if paged else in_sh,
            out_shardings=(None, None, cache_sh),
            donate_argnums=(2,),
        )
    else:
        aparams, _ = abstract_params(cfg)
        acaches = (
            abstract_paged_caches(cfg, n_slots, max_seq_total, page_tokens,
                                  enc_len, pool_dtype=pool_dtype,
                                  sz_granularity=sz_granularity)
            if paged else abstract_caches(cfg, n_slots, max_seq_total,
                                          enc_len)
        )
        decode_cell = (
            build_decode_greedy_paged(cfg, ctx, page_tokens) if paged
            else build_decode_greedy(cfg, ctx)
        )
        decode = jax.jit(decode_cell, donate_argnums=(2,))

    prefills, inserts = {}, {}
    for b in sorted(set(buckets)):
        cell = build_prefill_greedy(cfg, ctx, b)
        ins_cell = (
            build_paged_cache_insert(b + npfx, page_tokens, pool_dtype,
                                     sz_granularity)
            if paged else build_cache_insert()
        )
        if mesh is not None:
            prefills[b] = jax.jit(cell, in_shardings=(param_sh, None))
            # pin the global caches to the decode cell's sharding so the
            # insert->decode round trip never re-lays-out (and never
            # recompiles either cell after the first call)
            ins_in = (cache_sh, None, None)
            inserts[b] = jax.jit(
                ins_cell,
                in_shardings=ins_in + (None,) if paged else ins_in,
                out_shardings=cache_sh,
                donate_argnums=(0,),
            )
        else:
            prefills[b] = jax.jit(cell)
            inserts[b] = jax.jit(ins_cell, donate_argnums=(0,))

    chunk_fn = None
    if prefill_chunk:
        chunk_cell = build_prefill_chunk(cfg, ctx, page_tokens)
        if mesh is not None:
            chunk_fn = jax.jit(
                chunk_cell,
                in_shardings=(param_sh, None, cache_sh, None, None, None),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,),
            )
        else:
            chunk_fn = jax.jit(chunk_cell, donate_argnums=(2,))

    copy_fn = None
    if paged:
        copy_cell = build_page_copy()
        if mesh is not None:
            copy_fn = jax.jit(
                copy_cell,
                in_shardings=(cache_sh, None, None),
                out_shardings=cache_sh,
                donate_argnums=(0,),
            )
        else:
            copy_fn = jax.jit(copy_cell, donate_argnums=(0,))

    verify_fn = draft_fn = draft_params = None
    if speculative != "off":
        verify_cell = build_decode_verify_paged(cfg, ctx, page_tokens,
                                                spec_k)
        if mesh is not None:
            # cand (S, k) and t (S,) replicated like the greedy token
            # vector; caches keep the decode cell's sharding
            verify_fn = jax.jit(
                verify_cell,
                in_shardings=(param_sh, None, cache_sh, None, None),
                out_shardings=(None, None, cache_sh),
                donate_argnums=(2,),
            )
        else:
            verify_fn = jax.jit(verify_cell, donate_argnums=(2,))
    if speculative == "draft":
        # the draft model is small scratch state: plain replicated jit
        # even under a mesh (its caches never join the paged pool).
        # PRNGKey(0) init makes the weights deterministic, so every
        # engine in a fleet — and every process — shares one bit-exact
        # draft tree through the shared EngineCells.
        draft_fn = jax.jit(build_decode_draft(draft_cfg, ctx),
                           donate_argnums=(2,))
        dparams, _ = M.init_model(draft_cfg, jax.random.PRNGKey(0))
        ddt = jnp.dtype(draft_cfg.dtype)
        draft_params = jax.tree.map(
            lambda p: p.astype(ddt)
            if jnp.issubdtype(p.dtype, jnp.floating) else p,
            dparams,
        )

    return EngineCells(
        decode_fn=decode,
        prefill_fns=prefills,
        insert_fns=inserts,
        param_shardings=param_sh,
        cache_shardings=cache_sh,
        abstract_params=aparams,
        abstract_caches=acaches,
        n_prefix=npfx,
        max_seq_total=max_seq_total,
        paged=paged,
        page_tokens=page_tokens if paged else 0,
        n_pages=n_pages,
        pool_dtype=pool_dtype if paged else "fp",
        chunk_fn=chunk_fn,
        chunk=prefill_chunk,
        copy_fn=copy_fn,
        sz_granularity=sz_granularity if paged else "page",
        verify_fn=verify_fn,
        spec_k=spec_k if speculative != "off" else 0,
        draft_fn=draft_fn,
        draft_params=draft_params,
        draft_cfg=draft_cfg if speculative == "draft" else None,
    )
