"""Serve-step builders: prefill (prompt -> caches) and decode (one token vs
the KV cache / SSM state). `decode_32k` and `long_500k` cells lower the
decode step; `prefill_32k` lowers prefill — per the brief.

Two consumers:

* the dry-run/launcher path keeps the classic `ServeBundle` (one jitted
  prefill + one jitted decode over a uniform batch);
* the continuous-batching engine (`repro.serving.engine`) uses
  `make_engine_cells`: a fixed set of jitted cells — one greedy decode cell
  over the whole slot batch with per-slot positions, one prefill cell per
  prompt bucket, and one cache-insert cell per bucket that splices a
  prefilled request into the global decode caches at a (traced) slot index.
  Every shape is fixed at build time, so a steady-state serve loop never
  recompiles regardless of admissions/completions (slot masking via parked
  write positions, see `models.attention._cache_insert`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.module import shape_mode
from repro.runtime import sharding as shd


def abstract_params(cfg: ModelConfig, serve_dtype: bool = True):
    """Abstract param tree; serving uses inference dtype (bf16) weights."""
    with shape_mode():
        params, axes = M.init_model(cfg, jax.random.PRNGKey(0))
    if serve_dtype:
        dt = jnp.dtype(cfg.dtype)

        def cast(p):
            if jnp.issubdtype(p.dtype, jnp.floating):
                return jax.ShapeDtypeStruct(p.shape, dt)
            return p

        params = jax.tree.map(cast, params)
    return params, axes


def abstract_caches(cfg: ModelConfig, batch: int, max_seq: int,
                    enc_len: int = 0):
    caches = jax.eval_shape(
        lambda: M.make_decode_caches(cfg, batch, max_seq, enc_len)
    )
    return caches


def build_prefill(cfg: ModelConfig, ctx: ParallelCtx, max_seq: int):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, ctx, max_seq)

    return prefill_step


def build_decode(cfg: ModelConfig, ctx: ParallelCtx):
    def decode_step(params, token, caches, t):
        return M.decode_step(params, token, caches, t, cfg, ctx)

    return decode_step


@dataclasses.dataclass
class ServeBundle:
    prefill_fn: Any
    decode_fn: Any
    param_shardings: Any
    cache_shardings: Any
    abstract_params: Any
    abstract_caches: Any


def make_bundle(cfg: ModelConfig, ctx: ParallelCtx,
                rules: shd.ShardingRules, mesh,
                batch: int, max_seq: int, enc_len: int = 0,
                param_shardings_override=None) -> ServeBundle:
    aparams, axes = abstract_params(cfg)
    param_sh = param_shardings_override or shd.shardings_for_tree(
        aparams, axes, rules, mesh
    )
    acaches = abstract_caches(cfg, batch, max_seq, enc_len)
    cache_sh = shd.named(
        mesh, shd.cache_pspec(acaches, ctx.dp_axes, ctx.tp_axis, mesh)
    )
    batch_shardable = (
        ctx.dp_axes and batch % max(ctx.dp_size, 1) == 0 and ctx.dp_size > 1
    )
    tok_sh = shd.named(
        mesh, P(ctx.dp_axes) if batch_shardable else P()
    )
    # prompt batch: dim0 (requests) over dp axes — a prefix sharding covers
    # every leaf of the batch dict (tokens / patches / frames)
    prompt_sh = shd.named(
        mesh, P(ctx.dp_axes) if batch_shardable else P()
    )
    prefill = jax.jit(
        build_prefill(cfg, ctx, max_seq),
        in_shardings=(param_sh, prompt_sh),
    )
    decode = jax.jit(
        build_decode(cfg, ctx),
        in_shardings=(param_sh, tok_sh, cache_sh, None),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    return ServeBundle(prefill, decode, param_sh, cache_sh, aparams, acaches)


# ------------------------------------------------- continuous batching
def build_decode_greedy(cfg: ModelConfig, ctx: ParallelCtx):
    """Greedy decode cell: one token per slot, argmax inside the jit so the
    host only ever syncs an int32 vector plus a scalar finiteness flag
    (argmax of NaN logits would otherwise turn a numerical blow-up into
    silently wrong token streams). `t` is the per-slot position vector
    (see models.model.decode_step)."""

    def cell(params, token, caches, t):
        logits, caches = M.decode_step(params, token, caches, t, cfg, ctx)
        finite = jnp.isfinite(logits).all(axis=-1)   # per slot: parked
        # slots carry garbage caches, so the engine masks them out
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), finite, caches

    return cell


def build_prefill_greedy(cfg: ModelConfig, ctx: ParallelCtx, bucket: int):
    """Prefill cell for one prompt bucket: returns the request's decode
    caches (seq extent `bucket` + frontend prefix; cross-KV extent follows
    the frames in the batch) and its greedy first token. Prompts must be
    exactly `bucket` long (see serving.batcher)."""

    def cell(params, batch):
        caches, logits = M.prefill(params, batch, cfg, ctx, max_seq=bucket)
        return caches, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return cell


def build_cache_insert():
    """Splice a prefilled request's caches (batch=1, short seq extent) into
    the global slot caches at a traced slot index. A dynamic-update-slice
    per leaf: leading (stack, batch) dims, then the seq/state extents."""

    def insert(caches, slot_caches, slot):
        slot = jnp.asarray(slot, jnp.int32)

        def ins(big, small):
            idx = (jnp.int32(0), slot) + (jnp.int32(0),) * (big.ndim - 2)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), idx
            )

        return jax.tree.map(ins, caches, slot_caches)

    return insert


@dataclasses.dataclass
class EngineCells:
    """The fixed-shape jitted cells of the continuous-batching engine."""

    decode_fn: Any                 # (params, tok (S,), caches, t (S,)) ->
    #                        (next_tok (S,), finite, caches) [donates caches]
    prefill_fns: Dict[int, Any]    # bucket -> (params, batch) -> (caches, tok)
    insert_fns: Dict[int, Any]     # bucket -> (caches, slot_caches, slot)
    param_shardings: Any
    cache_shardings: Any
    abstract_params: Any
    abstract_caches: Any
    n_prefix: int                  # frontend prefix tokens (vision)
    max_seq_total: int             # cache seq extent incl. n_prefix

    def compile_counts(self) -> Dict[str, int]:
        """Executable-cache sizes of every cell — the no-recompile
        assertion reads this before/after steady state (-1 when the jax
        build does not expose `_cache_size`)."""

        def size(fn):
            probe = getattr(fn, "_cache_size", None)
            return int(probe()) if probe is not None else -1

        out = {"decode": size(self.decode_fn)}
        for b, fn in self.prefill_fns.items():
            out[f"prefill_{b}"] = size(fn)
        for b, fn in self.insert_fns.items():
            out[f"insert_{b}"] = size(fn)
        return out


def make_engine_cells(cfg: ModelConfig, ctx: ParallelCtx,
                      rules=None, mesh=None, *,
                      n_slots: int, max_seq: int,
                      buckets: Sequence[int], enc_len: int = 0
                      ) -> EngineCells:
    """Build the engine's cells. With a mesh, shardings come from the same
    rules as `make_bundle` (this is the ServeBundle path refactored for
    slot batching); meshless builds plain single-device jits."""
    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    if cfg.num_encoder_layers and len(set(buckets)) != 1:
        raise ValueError(
            "enc-dec archs need a single prefill bucket (cross-KV extent "
            "is fixed by the encoder length)"
        )
    max_seq_total = max_seq + npfx

    param_sh = cache_sh = tok_sh = None
    aparams = acaches = None
    if mesh is not None:
        if rules is None:
            rules = shd.ShardingRules.for_serving(
                data_axis=ctx.fsdp_axis, tp_axis=ctx.tp_axis
            )
        bundle = make_bundle(
            cfg, ctx, rules, mesh, batch=n_slots, max_seq=max_seq_total,
            enc_len=enc_len,
        )
        param_sh, cache_sh = bundle.param_shardings, bundle.cache_shardings
        aparams, acaches = bundle.abstract_params, bundle.abstract_caches
        tok_sh = shd.named(mesh, P())
        decode = jax.jit(
            build_decode_greedy(cfg, ctx),
            in_shardings=(param_sh, tok_sh, cache_sh, None),
            out_shardings=(None, None, cache_sh),
            donate_argnums=(2,),
        )
    else:
        aparams, _ = abstract_params(cfg)
        acaches = abstract_caches(cfg, n_slots, max_seq_total, enc_len)
        decode = jax.jit(build_decode_greedy(cfg, ctx), donate_argnums=(2,))

    prefills, inserts = {}, {}
    for b in sorted(set(buckets)):
        cell = build_prefill_greedy(cfg, ctx, b)
        if mesh is not None:
            prefills[b] = jax.jit(cell, in_shardings=(param_sh, None))
            # pin the global caches to the decode cell's sharding so the
            # insert->decode round trip never re-lays-out (and never
            # recompiles either cell after the first call)
            inserts[b] = jax.jit(
                build_cache_insert(),
                in_shardings=(cache_sh, None, None),
                out_shardings=cache_sh,
                donate_argnums=(0,),
            )
        else:
            prefills[b] = jax.jit(cell)
            inserts[b] = jax.jit(build_cache_insert(), donate_argnums=(0,))

    return EngineCells(
        decode_fn=decode,
        prefill_fns=prefills,
        insert_fns=inserts,
        param_shardings=param_sh,
        cache_shardings=cache_sh,
        abstract_params=aparams,
        abstract_caches=acaches,
        n_prefix=npfx,
        max_seq_total=max_seq_total,
    )
