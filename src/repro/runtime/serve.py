"""Serve-step builders: prefill (prompt -> caches) and decode (one token vs
the KV cache / SSM state). `decode_32k` and `long_500k` cells lower the
decode step; `prefill_32k` lowers prefill — per the brief.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.module import shape_mode
from repro.runtime import sharding as shd


def abstract_params(cfg: ModelConfig, serve_dtype: bool = True):
    """Abstract param tree; serving uses inference dtype (bf16) weights."""
    with shape_mode():
        params, axes = M.init_model(cfg, jax.random.PRNGKey(0))
    if serve_dtype:
        dt = jnp.dtype(cfg.dtype)

        def cast(p):
            if jnp.issubdtype(p.dtype, jnp.floating):
                return jax.ShapeDtypeStruct(p.shape, dt)
            return p

        params = jax.tree.map(cast, params)
    return params, axes


def abstract_caches(cfg: ModelConfig, batch: int, max_seq: int,
                    enc_len: int = 0):
    caches = jax.eval_shape(
        lambda: M.make_decode_caches(cfg, batch, max_seq, enc_len)
    )
    return caches


def build_prefill(cfg: ModelConfig, ctx: ParallelCtx, max_seq: int):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, ctx, max_seq)

    return prefill_step


def build_decode(cfg: ModelConfig, ctx: ParallelCtx):
    def decode_step(params, token, caches, t):
        return M.decode_step(params, token, caches, t, cfg, ctx)

    return decode_step


@dataclasses.dataclass
class ServeBundle:
    prefill_fn: Any
    decode_fn: Any
    param_shardings: Any
    cache_shardings: Any
    abstract_params: Any
    abstract_caches: Any


def make_bundle(cfg: ModelConfig, ctx: ParallelCtx,
                rules: shd.ShardingRules, mesh,
                batch: int, max_seq: int, enc_len: int = 0,
                param_shardings_override=None) -> ServeBundle:
    aparams, axes = abstract_params(cfg)
    param_sh = param_shardings_override or shd.shardings_for_tree(
        aparams, axes, rules, mesh
    )
    acaches = abstract_caches(cfg, batch, max_seq, enc_len)
    cache_sh = shd.named(
        mesh, shd.cache_pspec(acaches, ctx.dp_axes, ctx.tp_axis, mesh)
    )
    batch_shardable = (
        ctx.dp_axes and batch % max(ctx.dp_size, 1) == 0 and ctx.dp_size > 1
    )
    tok_sh = shd.named(
        mesh, P(ctx.dp_axes) if batch_shardable else P()
    )
    # prompt batch: dim0 (requests) over dp axes — a prefix sharding covers
    # every leaf of the batch dict (tokens / patches / frames)
    prompt_sh = shd.named(
        mesh, P(ctx.dp_axes) if batch_shardable else P()
    )
    prefill = jax.jit(
        build_prefill(cfg, ctx, max_seq),
        in_shardings=(param_sh, prompt_sh),
    )
    decode = jax.jit(
        build_decode(cfg, ctx),
        in_shardings=(param_sh, tok_sh, cache_sh, None),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    return ServeBundle(prefill, decode, param_sh, cache_sh, aparams, acaches)
