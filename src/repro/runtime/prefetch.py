"""Thin re-export shim — the layer-ahead scan prefetch moved into the
predictive prefetch subsystem as its statically-schedulable corner
(`repro.prefetch.static`; the `static` predictor scores the same schedule
through the shared `PrefetchEngine`). Existing imports keep working."""

from repro.prefetch.static import scan_with_prefetch, to_device

__all__ = ["scan_with_prefetch", "to_device"]
