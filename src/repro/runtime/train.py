"""Train-step builder: microbatched grad accumulation, clipping, AdamW,
sharding-annotated jit. The returned bundle carries everything the launcher
and the dry-run need (abstract state, shardings, the jittable step).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig, TrainConfig
from repro.common.parallel import ParallelCtx
from repro.models import model as M
from repro.models.module import shape_mode
from repro.optim import adamw, schedule
from repro.runtime import sharding as shd


def init_train_state(cfg: ModelConfig, key):
    params, axes = M.init_model(cfg, key)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "opt": adamw.adamw_init(params),
    }
    return state, axes


def abstract_train_state(cfg: ModelConfig):
    """Allocation-free state skeleton (ShapeDtypeStructs) + axes tree."""
    with shape_mode():
        params, axes = M.init_model(cfg, jax.random.PRNGKey(0))
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "params": params,
        "opt": {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
    }
    return state, axes


def state_pspecs(state, axes, rules: shd.ShardingRules, mesh):
    p = shd.pspecs_for_tree(state["params"], axes, rules, mesh)
    return {
        "step": P(),
        "params": p,
        "opt": {
            "m": p,
            "v": p,
            "count": P(),
        },
    }


def _microbatch(batch, k: int):
    def split(x):
        return x.reshape((k, x.shape[0] // k) + x.shape[1:])

    return jax.tree.map(split, batch)


def build_train_step(cfg: ModelConfig, ctx: ParallelCtx, tcfg: TrainConfig,
                     opt_cfg: Optional[adamw.AdamWConfig] = None):
    opt_cfg = opt_cfg or adamw.AdamWConfig(weight_decay=tcfg.weight_decay)

    compute_dtype = jnp.dtype(cfg.dtype)

    def train_step(state, batch):
        params = state["params"]
        lr = schedule.warmup_cosine(
            state["step"],
            peak_lr=tcfg.learning_rate,
            warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.total_steps,
        )

        def cast(p):
            if jnp.issubdtype(p.dtype, jnp.floating):
                return p.astype(compute_dtype)
            return p

        def loss_of(p, mb):
            # cast master->compute INSIDE the differentiated function: the
            # FSDP all-gathers then move bf16 tensors and the backward's
            # data-parallel reductions psum bf16 partials (the fp32 convert
            # lands after the collective) — halves the two dominant wire
            # terms on jamba/kimi train
            return M.loss_fn(jax.tree.map(cast, p), mb, cfg, ctx)

        if tcfg.microbatches > 1:
            mbs = _microbatch(batch, tcfg.microbatches)

            def acc(carry, mb):
                g_acc, metric_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_of, has_aux=True
                )(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                metric_acc = jax.tree.map(jnp.add, metric_acc, metrics)
                return (g_acc, metric_acc), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            zero_m = {
                k: jnp.zeros((), jnp.float32)
                for k in ("loss", "nll", "z_loss", "moe_aux", "accuracy")
            }
            (grads, metrics), _ = jax.lax.scan(
                acc, (zero_g, zero_m), mbs
            )
            grads = jax.tree.map(
                lambda g: g / tcfg.microbatches, grads
            )
            metrics = jax.tree.map(
                lambda m: m / tcfg.microbatches, metrics
            )
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True
            )(params, batch)

        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        new_params, new_opt = adamw.adamw_update(
            grads, state["opt"], params, lr, opt_cfg
        )
        new_state = {
            "step": state["step"] + 1,
            "params": new_params,
            "opt": new_opt,
        }
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


@dataclasses.dataclass
class TrainBundle:
    step_fn: Any
    state_shardings: Any
    batch_shardings: Any
    abstract_state: Any
    axes: Any


def make_bundle(cfg: ModelConfig, ctx: ParallelCtx, tcfg: TrainConfig,
                rules: shd.ShardingRules, mesh, batch_example,
                state_shardings_override=None,
                donate: bool = True) -> TrainBundle:
    """Everything needed to lower/run a training step on `mesh`."""
    astate, axes = abstract_train_state(cfg)
    pspecs = state_pspecs(astate, axes, rules, mesh)
    state_sh = state_shardings_override or shd.named(mesh, pspecs)
    batch_sh = shd.named(
        mesh, shd.batch_pspec(batch_example, ctx.dp_axes, mesh)
    )
    step = build_train_step(cfg, ctx, tcfg)
    jitted = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        donate_argnums=(0,) if donate else (),
    )
    return TrainBundle(jitted, state_sh, batch_sh, astate, axes)
