"""Fault-tolerance runtime: straggler watchdog + restart orchestration.

At 1000+ nodes the common failures are (a) a host dying (handled by
checkpoint/restart + elastic re-shard restore, see checkpoint/manager.py)
and (b) stragglers — hosts that silently run 2-10x slow (thermal, ECC,
network). The watchdog keeps an EWMA of step times and flags outliers; the
driver's response is configurable (log, skip-ahead via the data pipeline,
or checkpoint-and-halt so the scheduler can replace the host).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    ewma: float
    ratio: float


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup_steps: int = 5,
                 on_straggler: Optional[Callable] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup_steps = warmup_steps
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.count = 0
        self.flagged: list = []
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> Optional[StragglerReport]:
        assert self._t0 is not None, "start_step not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> Optional[StragglerReport]:
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return None
        report = None
        if self.count > self.warmup_steps and dt > self.threshold * self.ewma:
            report = StragglerReport(step, dt, self.ewma, dt / self.ewma)
            self.flagged.append(report)
            if self.on_straggler is not None:
                self.on_straggler(report)
        # EWMA update excludes flagged outliers (keep the baseline clean)
        if report is None:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return report


class RestartPolicy:
    """Crash-recovery driver logic: how far to restart, when to give up."""

    def __init__(self, max_restarts: int = 3, backoff_s: float = 1.0):
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.restarts = 0

    def should_restart(self, exc: BaseException) -> bool:
        if self.restarts >= self.max_restarts:
            return False
        self.restarts += 1
        time.sleep(self.backoff_s * self.restarts)
        return True
