"""Glue: paper's placement engine -> jit sharding annotations.

Takes a built TrainBundle, derives the per-device access profile of the
training state, runs the placement policy against the emulated tier topology
(paper-style pool_fraction), and re-jits the step with pinned_host memory
kinds on the pool-tier leaves. Degrades per backend capability (XLA:CPU only
supports host placement on inputs — see runtime/capability.py); the serving
KV substrate (`repro.serving.substrate`) resolves the SAME probes through
`capability.substrate_mode`, so `info["substrate_mode"]` reports whether a
physical pinned_host pool would be live on this backend.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding

from repro.common.config import ModelConfig, ShapeConfig, TrainConfig
from repro.common.parallel import ParallelCtx
from repro.common.pytree import leaf_bytes, named_leaves
from repro.core import access as acc
from repro.core import placement as plc
from repro.core import tiers as tr
from repro.runtime import capability
from repro.runtime import sharding as shd
from repro.runtime import train as train_rt


def shard_counts(pspec_tree, mesh) -> dict:
    out = {}
    for name, spec in named_leaves(pspec_tree):
        n = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                n *= mesh.shape[a]
        out[name] = n
    return out


def per_device_profile(state, pspecs, mesh, cfg: ModelConfig,
                       shape: ShapeConfig, remat: str):
    profile = acc.train_profile(state, cfg, shape, remat)
    counts = shard_counts(pspecs, mesh)
    out = []
    for a in profile:
        n = counts.get(a.name, 1)
        out.append(dataclasses.replace(a, bytes=a.bytes // max(n, 1)))
    return out


def apply_tier_shardings(cfg: ModelConfig, ctx: ParallelCtx,
                         tcfg: TrainConfig, rules: shd.ShardingRules,
                         mesh, batch_specs, bundle: train_rt.TrainBundle,
                         shape: ShapeConfig, *, policy: str,
                         pool_fraction: float):
    """Returns (abstract_state, new bundle, tier_info dict)."""
    astate = bundle.abstract_state
    pspecs = train_rt.state_pspecs(astate, bundle.axes, rules, mesh)
    profile = per_device_profile(astate, pspecs, mesh, cfg, shape, ctx.remat)

    working_set = sum(a.bytes for a in profile)
    topo = tr.emulated(pool_fraction, working_set)
    placement = plc.place(profile, topo, policy, pool_fraction)

    host_ok = capability.supports_host_input()
    out_ok = capability.supports_host_output()

    def retier(path_sh):
        name, sh = path_sh
        if host_ok and placement.tier_of(name) == "host":
            return NamedSharding(
                sh.mesh, sh.spec, memory_kind="pinned_host"
            )
        return sh

    flat = named_leaves(bundle.state_shardings)
    new_flat = [retier(p) for p in flat]
    treedef = jax.tree_util.tree_structure(bundle.state_shardings)
    state_sh = jax.tree_util.tree_unflatten(treedef, new_flat)

    step = train_rt.build_train_step(cfg, ctx, tcfg)
    jit_kwargs = dict(in_shardings=(state_sh, bundle.batch_shardings))
    if out_ok:
        jit_kwargs["out_shardings"] = (state_sh, None)
        jit_kwargs["donate_argnums"] = (0,)
    jitted = jax.jit(step, **jit_kwargs)

    new_bundle = train_rt.TrainBundle(
        jitted, state_sh, bundle.batch_shardings, astate, bundle.axes
    )
    info = {
        "policy": policy,
        "pool_fraction": pool_fraction,
        "corridor": plc.corridor_check(placement),
        "pool_bytes_per_dev": placement.pool_bytes,
        "local_bytes_per_dev": placement.local_bytes,
        "predicted_t_memory_s": placement.t_memory,
        "predicted_slowdown_vs_all_hbm": placement.slowdown,
        "host_annotation": "inputs" if host_ok and not out_ok else (
            "inputs+outputs" if out_ok else "logical-only"),
        # the serving substrate's resolution of the same probe set
        "substrate_mode": capability.substrate_mode("auto"),
        "n_pool_tensors": sum(
            1 for v in placement.assignment.values() if v == "host"
        ),
    }
    return astate, new_bundle, info
