"""Configuration dataclasses shared by the whole framework.

`ModelConfig` is a single schema wide enough for every assigned architecture
family (dense / moe / ssm / hybrid / encdec / vlm / audio); family-specific
fields default to "off". `ShapeConfig` describes one (seq_len, global_batch)
workload cell; `MeshConfig` one device mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|encdec|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                       # dense-MLP hidden size (0 for pure-MoE/ssm)
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # per-expert hidden size
    moe_layer_period: int = 1       # every k-th layer is MoE (1 = all)
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4

    # --- hybrid (Jamba): attention every k-th layer, SSM otherwise ---
    attn_layer_period: int = 0      # 0 -> attention everywhere (if not ssm)

    # --- encoder-decoder ---
    num_encoder_layers: int = 0

    # --- multimodal frontend stubs ---
    frontend: str = ""              # "" | "vision_stub" | "audio_stub"
    num_prefix_tokens: int = 0      # patch/frame embeddings prepended

    # --- misc ---
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "swiglu"             # swiglu | gelu
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    param_dtype: str = "float32"    # master params
    # provenance (from the assignment table)
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived ----
    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        return (i % self.moe_layer_period) == (self.moe_layer_period - 1)

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.attn_layer_period:
            return (i % self.attn_layer_period) == (self.attn_layer_period - 1)
        return True

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: attention-free or mostly-SSM hybrid."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (matches models/ init within rounding)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        n_dec = self.num_layers
        for i in range(n_dec):
            total += self._layer_params(i)
        for _ in range(self.num_encoder_layers):
            total += self._enc_layer_params()
        return total

    def active_param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        for i in range(self.num_layers):
            total += self._layer_params(i, active_only=True)
        for _ in range(self.num_encoder_layers):
            total += self._enc_layer_params()
        return total

    def _attn_params(self) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        p = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.qkv_bias:
            p += h * hd + 2 * kv * hd
        return p

    def _mlp_params(self, ff: int) -> int:
        n = 3 if self.act in ("swiglu", "geglu") else 2
        return n * self.d_model * ff

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        # in_proj (x,z,B,C,dt heads), conv, A/D/dt bias, norm, out_proj
        nheads = self.ssm_heads
        proj_in = d * (2 * di + 2 * self.ssm_state + nheads)
        conv = self.conv_width * (di + 2 * self.ssm_state)
        extra = 2 * nheads + di
        return proj_in + conv + extra + di * d

    def _layer_params(self, i: int, active_only: bool = False) -> int:
        p = 2 * self.d_model  # norms
        if self.is_attn_layer(i):
            p += self._attn_params()
        elif self.family in ("ssm", "hybrid"):
            p += self._ssm_params()
        if self.is_moe_layer(i):
            n_exp = self.experts_per_token if active_only else self.num_experts
            p += n_exp * self._mlp_params(self.moe_d_ff)
            p += self.d_model * self.num_experts  # router
        elif self.d_ff:
            p += self._mlp_params(self.d_ff)
        return p

    def _enc_layer_params(self) -> int:
        return 2 * self.d_model + self._attn_params() + self._mlp_params(self.d_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatches: int = 1           # gradient accumulation steps
    remat: str = "block"            # none | block | full
    compress_grads: bool = False    # int8 cross-pod all-reduce
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    label_smoothing: float = 0.0


@dataclasses.dataclass(frozen=True)
class TierPolicyConfig:
    """How the paper's placement technique is applied to a run."""

    policy: str = "hotness"         # first_touch|hotness|balanced_bw|capacity|none
    pool_fraction: float = 0.5      # R_cap^remote of the emulated system
    offload_optimizer: bool = True  # moments eligible for pool tier
    offload_params: bool = True     # cold params eligible for pool tier
    prefetch_depth: int = 1         # layer-ahead prefetch of pooled tensors
