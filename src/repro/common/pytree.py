"""Pytree helpers: named flattening, byte accounting, tree maps with paths."""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import hw


def path_str(path) -> str:
    """Render a jax tree path as 'a/b/0/c'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def named_leaves(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p), v) for p, v in flat]


def map_with_path(fn: Callable[[str, Any], Any], tree):
    return jax.tree_util.tree_map_with_path(lambda p, v: fn(path_str(p), v), tree)


def leaf_bytes(leaf) -> int:
    shape = getattr(leaf, "shape", ())
    dtype = getattr(leaf, "dtype", jnp.float32)
    n = int(np.prod(shape)) if shape else 1
    return n * hw.dtype_size(dtype)


def tree_bytes(tree) -> int:
    return sum(leaf_bytes(v) for v in jax.tree_util.tree_leaves(tree))


def tree_num_params(tree) -> int:
    return sum(
        int(np.prod(getattr(v, "shape", ()) or (1,)))
        for v in jax.tree_util.tree_leaves(tree)
    )


def assert_finite(tree, where: str = "") -> None:
    for name, leaf in named_leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            if not bool(jnp.isfinite(leaf).all()):
                raise FloatingPointError(f"non-finite values in {where}:{name}")


def tree_select(tree, pred: Callable[[str], bool]):
    """Return {path: leaf} for leaves whose path satisfies pred."""
    return {n: v for n, v in named_leaves(tree) if pred(n)}
