"""Parallel context threaded through model apply functions.

Carries the mesh and the axis roles so blocks that need explicit collectives
(MoE expert-parallel dispatch) can shard_map themselves, while everything
else relies on pjit auto-sharding + constraints.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[jax.sharding.Mesh] = None
    dp_axes: tuple[str, ...] = ()       # batch axes, e.g. ("pod","data")
    fsdp_axis: Optional[str] = None     # param-shard axis (usually "data")
    tp_axis: Optional[str] = None       # tensor/expert-parallel axis ("model")
    shard_seq_moe: bool = True          # reshard seq over tp inside MoE
    remat: str = "block"                # none | block
    moe_fsdp_mode: str = "rowcol"       # rowcol | gather (see models/moe.py)

    @property
    def all_axes(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    def axis_size(self, name: Optional[str]) -> int:
        if self.mesh is None or name is None:
            return 1
        return self.mesh.shape[name]

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.axis_size(a)
        return n

    def batch_spec(self) -> P:
        return P(self.dp_axes if self.dp_axes else None)

    def constrain(self, x, spec: P):
        """Sharding constraint if a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )


SINGLE = ParallelCtx()  # no mesh: pure single-device semantics
