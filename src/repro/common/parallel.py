"""Parallel context threaded through model apply functions.

Carries the mesh and the axis roles so blocks that need explicit collectives
(MoE expert-parallel dispatch) can shard_map themselves, while everything
else relies on pjit auto-sharding + constraints.
"""

from __future__ import annotations

import dataclasses
import enum
import inspect
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------- jax compat
def _install_jax_compat() -> None:
    """Let call sites use the modern mesh spelling on older jax.

    jax >= 0.5 exposes `jax.sharding.AxisType` and `jax.make_mesh(...,
    axis_types=...)`; 0.4.x has neither (the internal enum is
    `jax._src.mesh.AxisTypes` and `Auto` is the implicit default). The repo
    standardizes on the modern spelling, so on old runtimes we publish an
    `AxisType` alias and wrap `make_mesh` to swallow the kwarg.
    """
    if not hasattr(jax.sharding, "AxisType"):
        try:
            from jax._src.mesh import AxisTypes as _axis_type
        except ImportError:
            class _axis_type(enum.Enum):
                Auto = enum.auto()
                Explicit = enum.auto()
                Manual = enum.auto()
        jax.sharding.AxisType = _axis_type

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _exp_shard_map

        def _shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                       check_vma=None, **kwargs):
            if check_vma is not None and "check_rep" not in kwargs:
                kwargs["check_rep"] = check_vma  # renamed in jax >= 0.6
            return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, **kwargs)

        jax.shard_map = _shard_map

    if not hasattr(jax, "make_mesh"):
        # pre-0.4.35 jax has no make_mesh at all
        def _make_mesh_from_scratch(axis_shapes, axis_names, *,
                                    devices=None, axis_types=None):
            del axis_types
            import numpy as _np
            devs = list(devices) if devices is not None else jax.devices()
            n = int(_np.prod(axis_shapes))
            grid = _np.array(devs[:n], dtype=object).reshape(axis_shapes)
            return jax.sharding.Mesh(grid, axis_names)

        jax.make_mesh = _make_mesh_from_scratch
    # signature() is checked on the current jax.make_mesh, so a re-import of
    # this module never double-wraps.
    elif "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        def _make_mesh(axis_shapes, axis_names, *args, axis_types=None,
                       **kwargs):
            del axis_types  # Auto is the only behavior old jax offers
            return _orig_make_mesh(axis_shapes, axis_names, *args, **kwargs)

        _make_mesh.__name__ = "make_mesh"
        _make_mesh.__doc__ = _orig_make_mesh.__doc__
        jax.make_mesh = _make_mesh


_install_jax_compat()


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[jax.sharding.Mesh] = None
    dp_axes: tuple[str, ...] = ()       # batch axes, e.g. ("pod","data")
    fsdp_axis: Optional[str] = None     # param-shard axis (usually "data")
    tp_axis: Optional[str] = None       # tensor/expert-parallel axis ("model")
    shard_seq_moe: bool = True          # reshard seq over tp inside MoE
    remat: str = "block"                # none | block
    moe_fsdp_mode: str = "rowcol"       # rowcol | gather (see models/moe.py)

    @property
    def all_axes(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    def axis_size(self, name: Optional[str]) -> int:
        if self.mesh is None or name is None:
            return 1
        return self.mesh.shape[name]

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.axis_size(a)
        return n

    def batch_spec(self) -> P:
        return P(self.dp_axes if self.dp_axes else None)

    def constrain(self, x, spec: P):
        """Sharding constraint if a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )


SINGLE = ParallelCtx()  # no mesh: pure single-device semantics
