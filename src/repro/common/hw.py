"""Hardware constants for the target platform (TPU v5e) and the host pool.

These numbers parameterize the roofline model (core/roofline.py), the tier
topology (core/tiers.py) and the interference link model (core/interference.py).
The container we *run* in is CPU-only; v5e is the *target* the dry-run and
roofline analysis are computed for.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """One accelerator chip."""

    name: str
    peak_flops_bf16: float      # flop/s
    hbm_bytes: float            # bytes of fast-tier memory per chip
    hbm_bw: float               # bytes/s fast-tier bandwidth per chip
    ici_link_bw: float          # bytes/s per ICI link (one direction)
    ici_num_links: int          # links per chip (2D torus on v5e -> 4)
    vmem_bytes: float           # on-chip vector memory (Pallas tile budget)
    mxu_dim: int                # systolic array native dim


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """The host that a group of chips shares — our 'rack-scale memory pool'.

    In the paper the pool is a CXL box shared by the nodes of a rack; here it
    is the host DRAM shared by `chips_per_host` TPU chips, reached over PCIe.
    """

    dram_bytes: float           # pool capacity per host
    pcie_bw: float              # bytes/s per chip to host (the 'remote link')
    pcie_shared_bw: float       # bytes/s total host<->chips (contention domain)
    chips_per_host: int
    dcn_bw: float               # bytes/s per host across pods


# TPU v5e (brief-specified constants: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI).
V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    ici_num_links=4,
    vmem_bytes=128 * 2**20,
    mxu_dim=128,
)

# v5e hosts carry 8 chips (4x2) with PCIe gen3 x16 per 2 chips in practice;
# we model a per-chip effective 16 GB/s and a shared 64 GB/s domain, which is
# deliberately *slower relative to HBM* than the paper's UPI (34 vs 73 GB/s):
# the TPU pool link ratio (~2%) is harsher than the paper's (~47%), which is
# why placement policy matters more here, not less.
V5E_HOST = HostSpec(
    dram_bytes=512 * 2**30,
    pcie_bw=16e9,
    pcie_shared_bw=64e9,
    chips_per_host=8,
    dcn_bw=25e9,
)

DTYPE_BYTES = {
    "float32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int8": 1,
    "uint8": 1,
    "int32": 4,
    "uint32": 4,
    "int64": 8,
    "bool": 1,
    "float64": 8,
}


def dtype_size(dtype) -> int:
    return DTYPE_BYTES[str(getattr(dtype, "name", dtype))]


def bidir_ici_bw(chip: ChipSpec = V5E) -> float:
    """Aggregate ICI bandwidth per chip (all links, one direction each)."""
    return chip.ici_link_bw * chip.ici_num_links
