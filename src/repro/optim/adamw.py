"""AdamW in pure JAX, structured so the moment tensors are first-class
tier-placement candidates: they are the coldest large state in training
(touched once per step, never by forward/backward), which makes them the
prime pool-tier residents under the paper's hotness ordering.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, lr, cfg: AdamWConfig):
    count = opt_state["count"] + 1
    c = count.astype(jnp.float32)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1**c
    bc2 = 1.0 - b2**c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * step).astype(p.dtype), m, v

    flat = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}


def global_norm(tree) -> jnp.ndarray:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm
