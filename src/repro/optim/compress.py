"""Int8 gradient compression with error feedback, for the cross-pod
all-reduce (a distributed-optimization trick from the 1000+-node checklist).

quantize -> psum(int-ish payload as int8-scaled f32 is pointless; we psum the
int8 *dequantized at 1/128 scale* only after casting, so the wire format in a
real DCN collective is int8) -> dequantize; the quantization residual is kept
per-leaf and added to the next step's gradient (error feedback), which keeps
SGD convergence unbiased in expectation.

On the HLO level the collective operand is int8, cutting cross-pod collective
bytes 4x vs fp32 — visible in the dry-run collective-bytes parser.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, error_state, axis_names):
    """psum each gradient leaf in int8 wire format with error feedback.

    Must run inside shard_map with `axis_names` bound. Returns
    (mean_grads, new_error_state).
    """
    n = 1
    for a in axis_names:
        n *= jax.lax.axis_size(a)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = quantize(g)
        new_e = g - dequantize(q, scale)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
        scale_sum = jax.lax.psum(scale, axis_names)  # conservative shared scale
        mean = summed.astype(jnp.float32) * (scale_sum / n) / n
        return mean, new_e

    pairs = jax.tree.map(one, grads, error_state)
    mean = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_e
