"""LR schedules."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    # warm from step 1 so the first optimizer step is never a no-op
    warm = peak_lr * (step + 1) / jnp.maximum(warmup_steps, 1)
    frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup_steps, warm, cos)
