"""Roofline models.

Two layers:

1. The brief-mandated 3-term *dry-run roofline* for a compiled step:
       compute    = HLO_flops   / peak_flops          (per chip)
       memory     = HLO_bytes   / hbm_bw              (per chip)
       collective = wire_bytes  / ici_bw              (per chip)
   All inputs are per-device quantities from profiler.hlo (the optimized
   SPMD program is per-device). The dominant term is the bottleneck; the
   attainable step time is ~max(terms) under perfect overlap and ~sum under
   none; we report both bounds.

2. The paper's §3.4/§5 *memory roofline* extended to multiple tiers:
   attainable bandwidth given an access split r_i over tiers with bandwidths
   B_i is  1 / max_i(r_i / B_i)  — maximized when r_i ∝ B_i (the paper's
   "balanced access" reference point R_bw).
"""

from __future__ import annotations

import dataclasses

from repro.common import hw
from repro.profiler.hlo import HloCostModel


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops: float                # per device
    hbm_bytes: float            # per device
    wire_bytes: float           # per device
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float          # 6*N*D useful flops (global)
    model_flops_per_device: float
    useful_ratio: float         # model_flops / hlo_flops (per device basis)
    bound_overlap: float        # max(terms)
    bound_serial: float         # sum(terms)
    roofline_fraction: float    # useful work time / attainable bound
    collective_by_kind: dict
    warnings: list

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_train(n_active_params: int, tokens: int) -> float:
    """6*N*D — the canonical useful-flops estimate for LM training."""
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: int, tokens: int) -> float:
    """2*N per generated token (forward only)."""
    return 2.0 * n_active_params * tokens


def report(
    arch: str,
    shape: str,
    mesh_name: str,
    cost: HloCostModel,
    n_devices: int,
    model_flops: float,
    chip: hw.ChipSpec = hw.V5E,
) -> RooflineReport:
    t_c = cost.flops / chip.peak_flops_bf16
    t_m = cost.hbm_bytes / chip.hbm_bw
    # wire bytes leave the chip over its ICI links (aggregate, one direction)
    t_x = cost.wire_bytes / hw.bidir_ici_bw(chip)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf_dev = model_flops / n_devices
    useful = mf_dev / cost.flops if cost.flops else 0.0
    bound_overlap = max(terms.values())
    bound_serial = sum(terms.values())
    # roofline fraction: time the useful flops NEED at peak vs the time the
    # compiled program NEEDS under perfect overlap. =1.0 iff compute-bound
    # with zero waste.
    t_useful = mf_dev / chip.peak_flops_bf16
    frac = t_useful / bound_overlap if bound_overlap else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name,
        flops=cost.flops, hbm_bytes=cost.hbm_bytes,
        wire_bytes=cost.wire_bytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        dominant=dominant,
        model_flops=model_flops, model_flops_per_device=mf_dev,
        useful_ratio=useful,
        bound_overlap=bound_overlap, bound_serial=bound_serial,
        roofline_fraction=frac,
        collective_by_kind=dict(cost.collective_by_kind),
        warnings=list(cost.warnings),
    )


# ------------------------------------------------- paper's memory roofline
def multi_tier_bandwidth(access_ratios, bandwidths) -> float:
    """Attainable aggregate bandwidth for an access split over tiers.

    time per byte = max_i r_i/B_i  (each tier streams its share in parallel);
    attainable BW = 1 / that. Balanced access (r_i = B_i/sum B) attains
    sum(B_i) — the paper's point that tiers ADD bandwidth when used in
    balance.
    """
    worst = max(
        (r / b) for r, b in zip(access_ratios, bandwidths) if b > 0
    )
    return 1.0 / worst if worst > 0 else 0.0


def attainable_flops(ai: float, access_ratios, bandwidths,
                     chip: hw.ChipSpec = hw.V5E) -> float:
    """Roofline P = min(F, AI * B_eff(r)) with the multi-tier B_eff."""
    beff = multi_tier_bandwidth(access_ratios, bandwidths)
    return min(chip.peak_flops_bf16, ai * beff)
