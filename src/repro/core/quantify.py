"""The paper's end-to-end quantitative workflow (Fig 4) as one API.

    analyze(arch, shape) ->
      level1: intrinsic — footprint, per-step traffic, arithmetic
              intensity, bandwidth-capacity curve
      level2: multi-tier — placement under a policy/pool_fraction,
              R_cap/R_access/R_bw corridor check, predicted memory time
      level3: pooling — sensitivity(LoI) table, interference coefficient

Byte counts come from the analytic access model (core.access) scaled
per-chip by the production sharding; compute time comes from the dry-run's
HLO flops when a dry-run record is supplied, else from the 6·N·D model at
peak. Everything here is deterministic and cheap — it is the tool an HPC
user would run before requesting a deployment configuration, which is the
paper's intent.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Optional

import jax

from repro import configs
from repro.common import hw
from repro.common.config import SHAPES, MeshConfig, SINGLE_POD_MESH
from repro.core import access as acc
from repro.core import interference as itf
from repro.core import placement as plc
from repro.core import roofline as rl
from repro.core import tiers as tr
from repro.models.module import shape_mode
from repro.runtime import serve as serve_rt
from repro.runtime import train as train_rt


@dataclasses.dataclass
class Analysis:
    arch: str
    shape: str
    level1: dict
    level2: dict
    level3: dict
    placement: plc.Placement
    profile: itf.InterferenceProfile


def _abstract_state(cfg, shape):
    if shape.kind == "train":
        state, _ = train_rt.abstract_train_state(cfg)
        return state
    params, _ = serve_rt.abstract_params(cfg)
    if shape.kind == "decode":
        caches = serve_rt.abstract_caches(
            cfg, shape.global_batch, shape.seq_len,
            enc_len=shape.seq_len if cfg.frontend == "audio_stub" else 0,
        )
        return {"params": params, "caches": caches}
    return {"params": params}


def _profile(cfg, shape, state, remat="block"):
    if shape.kind == "train":
        return acc.train_profile(state, cfg, shape, remat)
    return acc.serve_profile(
        state["params"], state.get("caches"), cfg, shape
    )


def t_compute_for(cfg, shape, n_chips: int,
                  dryrun_record: Optional[dict] = None) -> float:
    if dryrun_record and dryrun_record.get("status") == "ok":
        return dryrun_record["roofline"]["t_compute_s"]
    if shape.kind == "train":
        mf = rl.model_flops_train(cfg.active_param_count(), shape.tokens)
    elif shape.kind == "prefill":
        mf = rl.model_flops_decode(cfg.active_param_count(), shape.tokens)
    else:
        mf = rl.model_flops_decode(
            cfg.active_param_count(), shape.global_batch
        )
    return mf / n_chips / hw.V5E.peak_flops_bf16


def load_dryrun_record(arch: str, shape: str, mesh: str = "16x16",
                       outdir: str = "results/dryrun") -> Optional[dict]:
    p = os.path.join(outdir, f"{configs.canonical(arch)}_{shape}_{mesh}.json")
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


HBM_STATE_BUDGET = 0.6  # fraction of HBM available for resident state


def analyze(
    arch: str,
    shape_name: str,
    *,
    policy: str = "hotness",
    pool_fraction="auto",
    mesh_cfg: MeshConfig = SINGLE_POD_MESH,
    dryrun_record: Optional[dict] = None,
    use_dryrun: bool = True,
) -> Analysis:
    """pool_fraction: float = paper-style emulated R_cap stress test;
    "auto" = pool-by-necessity (whatever exceeds the per-chip HBM budget
    goes to the pool — the actual adoption scenario)."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    n_chips = mesh_cfg.num_devices
    if dryrun_record is None and use_dryrun:
        dryrun_record = load_dryrun_record(arch, shape_name)

    state = _abstract_state(cfg, shape)
    profile = _profile(cfg, shape, state)
    # per-chip scaling: state is sharded across the mesh
    profile = [
        dataclasses.replace(a, bytes=max(a.bytes // n_chips, 1))
        for a in profile
    ]

    total_bytes = sum(a.bytes for a in profile)
    if pool_fraction == "auto":
        budget = HBM_STATE_BUDGET * hw.V5E.hbm_bytes
        pool_fraction = max(0.0, min(0.95, 1.0 - budget / total_bytes))
        if pool_fraction == 0.0:
            policy = "all_local"
    total_traffic = sum(a.traffic for a in profile)
    t_comp = t_compute_for(cfg, shape, n_chips, dryrun_record)
    flops_per_chip = t_comp * hw.V5E.peak_flops_bf16
    ai = flops_per_chip / max(total_traffic, 1)
    xs, ys = acc.bandwidth_capacity_curve(profile)

    level1 = {
        "footprint_bytes_per_chip": total_bytes,
        "traffic_bytes_per_step_per_chip": total_traffic,
        "arithmetic_intensity": ai,
        "bwcap_curve": (xs.tolist(), ys.tolist()),
        "hot50": float(ys[min(range(len(xs)),
                              key=lambda i: abs(xs[i] - 0.5))]),
    }

    topo = tr.emulated(pool_fraction, total_bytes)
    placement = plc.place(profile, topo, policy, pool_fraction)
    level2 = {
        "policy": policy,
        "pool_fraction": pool_fraction,
        **plc.corridor_check(placement),
        "t_memory_s": placement.t_memory,
        "slowdown_vs_all_hbm": placement.slowdown,
        "multi_tier_bw": rl.multi_tier_bandwidth(
            [1 - placement.r_access_pool, placement.r_access_pool],
            [topo.local.bandwidth, topo.pool.bandwidth],
        ),
    }

    iprof = itf.profile_from_placement(arch, shape_name, placement, t_comp,
                                       topo)
    level3 = {
        "sensitivity": {
            f"loi_{int(100 * l)}": iprof.sensitivity(l)
            for l in (0.0, 0.1, 0.2, 0.3, 0.4, 0.5)
        },
        "interference_coefficient": iprof.interference_coefficient(),
        "injected_loi": iprof.injected_loi(),
    }
    return Analysis(arch, shape_name, level1, level2, level3, placement,
                    iprof)


@functools.lru_cache(maxsize=None)
def _profile_for_cached(arch, shape_name, policy, pool_fraction,
                        use_dryrun) -> itf.InterferenceProfile:
    return analyze(arch, shape_name, policy=policy,
                   pool_fraction=pool_fraction,
                   use_dryrun=use_dryrun).profile


def profile_for(arch: str, shape_name: str = "decode_32k", *,
                policy: str = "hotness", pool_fraction="auto",
                use_dryrun: bool = False) -> itf.InterferenceProfile:
    """Submission-time interference profile for a catalog workload.

    This is what the paper's §7.2 SLURM plugin would compute once per
    (arch, shape) when the job template is registered — cached (with
    arguments canonicalized here so kwarg spelling at call sites cannot
    split the cache) so a 10k-job trace costs O(|zoo|) analyses, not
    O(n_jobs).
    """
    return _profile_for_cached(arch, shape_name, policy, pool_fraction,
                               use_dryrun)
