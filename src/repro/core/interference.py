"""Level 3: memory interference on the pooled tier (paper §3.2, §6).

The pool link (PCIe to host DRAM here; UPI/CXL in the paper) is shared by
`chips_per_pool` chips. Co-running jobs inject traffic; the victim sees an
effective link bandwidth reduction plus queueing delay. We model the link as
an M/D/1-style server, the same queueing-theory approach as Tudor et al.
[45] that the paper builds on:

    utilization rho = (victim + background) demand / link capacity
    effective service time multiplier  ~ 1 + rho/(2(1-rho))  (capped)

`LoI` (level of interference) is the background traffic as a fraction of
peak link bandwidth, dialed by LBench's flops/element knob exactly as in the
paper. Sensitivity and the interference coefficient (IC) are derived from a
workload's tier access profile:

  * sensitivity(LoI): relative step time when the pool link carries LoI
    background traffic — HIGH pool access ratio + LOW arithmetic intensity
    -> sensitive (the paper's Hypre/NekRS quadrant);
  * IC: traffic the job itself injects relative to peak link bandwidth —
    what a scheduler needs for co-location decisions (paper §6.2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.placement import Placement
from repro.core.tiers import TierTopology, v5e_topology as tr_v5e
from repro.kernels.lbench import ref as lbench_ref


# ------------------------------------------------------------ link model
RHO_CAP = 0.95      # links time-slice: a victim is never fully starved
LOI_SHARE_FLOOR = 0.1


def queueing_slowdown(rho):
    """M/D/1 mean service multiplier at utilization rho (capped at the
    time-slicing limit — beyond ~95% the fabric arbiters round-robin).
    Broadcasts over numpy arrays; scalars come back as numpy scalars."""
    rho = np.clip(rho, 0.0, RHO_CAP)
    return 1.0 + rho / (2.0 * (1.0 - rho))


def mdl_knee(max_excess: float = 0.75) -> float:
    """Utilization rho* where the M/D/1 queueing excess reaches
    `max_excess`: solve 1 + rho/(2(1-rho)) = 1 + e  ->  rho = 2e/(1+2e).
    The default excess of 0.75 puts the knee at rho* = 0.6, the elbow of
    `queueing_slowdown` where delay departs the linear regime."""
    if max_excess <= 0.0:
        raise ValueError("max_excess must be positive")
    return 2.0 * max_excess / (1.0 + 2.0 * max_excess)


def corridor_budget(topo: Optional[TierTopology] = None,
                    max_excess: float = 0.75) -> float:
    """Aggregate injected-LoI budget of one pool link (the R_bw corridor).

    Derived, not hard-coded: the M/D/1 knee utilization of the shared link,
    discounted by the pool tier's share of the aggregate bandwidth diet
    (`TierTopology.r_bw_pool`) — that share of the link must stay clear for
    the residents' own foreground pool traffic, so only the remainder is
    available to absorb background injection before queueing explodes.
    """
    topo = topo or tr_v5e()
    return mdl_knee(max_excess) * (1.0 - topo.r_bw_pool)


def step_time_vec(t_pool, t_local, t_compute, loi, overlap: bool = True):
    """Victim-side step time under background LoI — the single source of
    truth for the contention model, broadcasting over any argument.

    The background stream occupies `loi` of the shared link; the victim's
    own transfers are pipelined (they never queue against themselves) but
    they lose bandwidth share and queue behind the background stream. The
    rack-scale simulator calls this with whole-pool arrays of per-job
    (t_pool, t_local, t_compute) against each job's background LoI.
    """
    loi = np.asarray(loi, dtype=np.float64)
    t_pool_eff = (
        t_pool * queueing_slowdown(loi)
        / np.maximum(1.0 - loi, LOI_SHARE_FLOOR)
    )
    if overlap:
        return np.maximum(np.maximum(t_compute, t_local), t_pool_eff)
    return t_compute + t_local + t_pool_eff


def background_lois(injected) -> np.ndarray:
    """Per-victim background LoI inside one shared-link contention domain:
    the sum of everyone ELSE's injected traffic, capped at saturation."""
    injected = np.asarray(injected, dtype=np.float64)
    return np.minimum(1.0, injected.sum() - injected)


def progress_rates(t_pool, t_local, t_compute, bg_loi) -> np.ndarray:
    """Per-job progress rate (fraction of isolated speed, in (0, 1]) at the
    given background LoI. Vectorized over co-resident jobs."""
    base = np.maximum(np.maximum(t_compute, t_local), t_pool)
    base = np.maximum(base, 1e-12)
    return base / step_time_vec(t_pool, t_local, t_compute, bg_loi)


def lbench_loi(nflop: int, n_elements: int, topo: TierTopology,
               t_compute_floor: float = 0.0) -> float:
    """LoI produced by an LBench instance with `nflop` flops/element.

    LBench streams its array over the pool link; its achievable traffic is
    min(link bw, flops_capability-limited rate). Low nflop -> link-saturating
    (LoI -> 100%); high nflop -> compute-bound, lower LoI. Mirrors paper
    Fig 11-left (linear in configured intensity until saturation).
    """
    bytes_per_elem = 8.0  # f32 read + write
    flops_per_elem = max(nflop, 1)
    # time per element on the link vs in compute (1 core-ish probe)
    t_link = bytes_per_elem / topo.pool.bandwidth
    t_comp = flops_per_elem * 2e-10 + t_compute_floor
    achieved_bw = bytes_per_elem / max(t_link, t_comp)
    return min(1.0, achieved_bw / topo.pool.bandwidth)


# --------------------------------------------------------- app metrics
@dataclasses.dataclass
class InterferenceProfile:
    arch: str
    shape: str
    pool_traffic: float          # bytes per step per chip on the pool link
    local_traffic: float         # bytes per step per chip in HBM
    t_compute: float             # seconds of pure compute per step
    topo: TierTopology

    @property
    def t_pool(self) -> float:
        return self.pool_traffic / self.topo.pool.bandwidth

    @property
    def t_local(self) -> float:
        return self.local_traffic / self.topo.local.bandwidth

    def step_time(self, loi: float = 0.0, overlap: bool = True) -> float:
        """Predicted step time at background interference level `loi`
        (scalar entry point into `step_time_vec`)."""
        return float(
            step_time_vec(self.t_pool, self.t_local, self.t_compute, loi,
                          overlap)
        )

    def step_time_no_pool(self) -> float:
        return max(self.t_compute, self.t_local)

    def sensitivity(self, loi: float) -> float:
        """Relative performance at LoI vs LoI=0 (paper Fig 10; 1.0 = no
        degradation)."""
        return self.step_time(0.0) / self.step_time(loi)

    def sensitivity_vec(self, lois) -> np.ndarray:
        """`sensitivity` broadcast over an array of LoI values."""
        return self.step_time(0.0) / step_time_vec(
            self.t_pool, self.t_local, self.t_compute, lois
        )

    def _raw_base(self) -> float:
        return max(self.t_compute, self.t_local, self.t_pool, 1e-12)

    def interference_coefficient(self) -> float:
        """IC: the slowdown this job inflicts on a 1-thread LBench probe
        (paper §3.2) — driven by the job's pool-link utilization."""
        util = self.t_pool / self._raw_base()
        return float(queueing_slowdown(util))

    def injected_loi(self) -> float:
        return min(1.0, self.t_pool / self._raw_base())


def profile_from_placement(arch: str, shape: str, placement: Placement,
                           t_compute: float, topo: TierTopology
                           ) -> InterferenceProfile:
    return InterferenceProfile(
        arch=arch,
        shape=shape,
        pool_traffic=placement.pool_traffic,
        local_traffic=placement.local_traffic,
        t_compute=t_compute,
        topo=topo,
    )


# ------------------------------------------------------ LBench validation
def lbench_intensity_sweep(topo: TierTopology, nflops=(1, 2, 4, 8, 16, 32,
                                                       64, 128)):
    """Paper Fig 11-middle: measured traffic saturates at link bw while
    contention (IC) keeps rising below 8 flops/element."""
    rows = []
    for nf in nflops:
        loi = lbench_loi(nf, 1 << 20, topo)
        raw_bw = min(
            topo.pool.bandwidth,
            loi * topo.pool.bandwidth,
        )
        ic = float(queueing_slowdown(loi))
        rows.append({
            "nflop": nf,
            "loi": loi,
            "pcm_bw": raw_bw,          # what raw counters would show
            "ic": ic,                  # what LBench can still distinguish
        })
    return rows
