"""Level 1: per-tensor access profiles and bandwidth-capacity scaling curves.

The paper measures page-grain access counts with PEBS; XLA host offload is
tensor-grain, so the unit of placement here is the named tensor of the
train/serve state. `touches_per_step` is derived from training/serving
semantics (how many times each byte moves per step) — exact for this
framework because the step program is fixed:

  train:  param fwd read + bwd read (+1 reread under block remat)
          grad write+read, moment read+write (x2), param write
  serve:  param read per step; expert weights scaled by the expected
          fraction of experts activated by the step's tokens
          (1 - (1 - k/E)^T — the Fig 6 skew for MoE);
          KV cache read per decode step, 1/S write share.

The bandwidth-capacity curve (paper Fig 6) is the CDF of traffic over
footprint with tensors sorted by traffic density.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.common.config import ModelConfig, ShapeConfig
from repro.common.pytree import leaf_bytes, named_leaves


@dataclasses.dataclass
class TensorAccess:
    name: str
    bytes: int                 # global bytes
    touches: float             # byte-touches per step / bytes (density)
    category: str              # param|expert|moment|embed|cache|other

    @property
    def traffic(self) -> float:
        return self.bytes * self.touches


def _category(name: str) -> str:
    if re.search(r"moe/(w_gate|w_up|w_down)", name):
        return "expert"
    if "/opt/" in name or name.startswith("opt/"):
        return "moment"
    if "embedding" in name or "lm_head" in name:
        return "embed"
    if re.search(r"(^|/)(k|v|cross_k|cross_v|state|tail_)", name):
        return "cache"
    return "param"


def expected_expert_fraction(cfg: ModelConfig, tokens: int) -> float:
    """Expected fraction of experts activated by `tokens` routed tokens."""
    if not cfg.num_experts:
        return 1.0
    p_miss = (1.0 - cfg.experts_per_token / cfg.num_experts) ** max(tokens, 1)
    return 1.0 - p_miss


ZIPF_ALPHA = 1.0  # expert-popularity skew (observed MoE routing is Zipf-ish)

# --- per-step decode traffic over the KV cache (paper Fig 10 spread) ---
# A decode step does NOT stream the whole KV prefix at full rate: attention
# mass concentrates on the most recent tokens, and paged/blocked decode
# kernels fetch the cold prefix at a reduced effective rate (sparse /
# compressed / skipped blocks). Modeling the cache as hot-tail + cold-prefix
# is what moves catalog decode cells off the silent/link-saturating extremes
# and populates the intermediate LoI band of the paper's Fig 10.
DECODE_HOT_WINDOW = 4096   # tokens of KV tail read at full rate each step
DECODE_COLD_TOUCH = 0.05   # effective per-step touch of the cold prefix


# --- paged-pool payload dtypes (mirrors models.blocks.POOL_DTYPES) ---
# int8 pools carry one float32 (scale, zero) pair per (page, KV head) per
# K and per V — the "k_sz"/"v_sz" leaves — amortized over the page's
# tokens in the bytes-per-token accounting.
POOL_PAYLOAD_BYTES = {"bf16": 2, "int8": 1}
POOL_SZ_BYTES = 8               # float32 (scale, zero)


def kv_pool_token_bytes(n_attn_layers: int, kv_heads: int, head_dim: int,
                        page_tokens: int, pool_dtype: str,
                        fp_bytes: int = 4,
                        sz_granularity: str = "page") -> float:
    """Self-attention K/V bytes per cached token under a paged pool of
    `pool_dtype` — the closed-form twin of the serving engine's
    cache-tree walk (`serving.engine._kv_bytes_per_token`):

        2 (K and V) * kv_heads * head_dim * payload_bytes * n_layers
        [+ 2 * kv_heads * 8 / page_tokens * n_layers   when int8]

    `fp_bytes` is the compute dtype's itemsize (the "fp" safety-net pool
    stores it unchanged). This is what makes the pager, `phys_tiers()`
    and the admission corridor see the real ~4x pool-byte cut of int8
    pools instead of pricing fp bytes that never cross the link.

    `sz_granularity="token"` prices the speculative-decoding per-token
    sub-scale layout (`kernels.quant.quantize_tokens`): one (scale,
    zero) pair per token row instead of per page, so the int8 sz term
    loses its /page_tokens amortization."""
    payload = POOL_PAYLOAD_BYTES.get(pool_dtype, fp_bytes)
    per_tok = 2.0 * kv_heads * head_dim * payload * n_attn_layers
    if pool_dtype == "int8":
        sz = 2.0 * kv_heads * POOL_SZ_BYTES * n_attn_layers
        if sz_granularity != "token":
            sz /= page_tokens
        per_tok += sz
    return per_tok


def kv_dedup_token_bytes(n_tokens: int, shared_tokens: int,
                         n_sharers: int, token_bytes: float) -> float:
    """Deduplicated pool bytes per cached token when `n_sharers` slots of
    `n_tokens` each share a `shared_tokens`-long prefix (the serving
    prefix cache, `serving.prefix_cache`): the shared prefix is stored
    ONCE, every private suffix once each —

        (n_sharers * (n_tokens - shared_tokens) + shared_tokens)
            * token_bytes / (n_sharers * n_tokens)

    The closed-form twin of `KVPager.phys_tiers()` under sharing: at
    shared_tokens = 0 it degenerates to `token_bytes`; as the shared
    prefix dominates, footprint per token tends to token_bytes /
    n_sharers — the memory over-provisioning the paper quantifies,
    reclaimed by refcounted pages instead of extra capacity."""
    if n_sharers < 1:
        raise ValueError("n_sharers must be >= 1")
    if not 0 <= shared_tokens <= n_tokens:
        raise ValueError("need 0 <= shared_tokens <= n_tokens")
    if n_tokens == 0:
        return 0.0
    stored = n_sharers * (n_tokens - shared_tokens) + shared_tokens
    return stored * token_bytes / (n_sharers * n_tokens)


def decode_cache_split(seq_len: int) -> list[tuple[str, float, float]]:
    """(suffix, byte_fraction, touches) portions of a seq-indexed KV leaf
    for one decode step under the hot-tail/cold-prefix traffic model."""
    hot_frac = min(1.0, DECODE_HOT_WINDOW / max(seq_len, 1))
    if hot_frac >= 1.0:
        return [("", 1.0, 1.0)]
    return [
        ("[hot]", hot_frac, 1.0),
        ("[cold]", 1.0 - hot_frac, DECODE_COLD_TOUCH),
    ]


def expert_activation_probs(cfg: ModelConfig, tokens: int) -> np.ndarray:
    """Per-expert probability of being activated by a step's tokens under a
    Zipf(ZIPF_ALPHA) routing popularity. This is the MoE realization of the
    paper's Fig 6 skew: a minority of experts receives most traffic, so the
    cold tail is pool-eligible at serving time."""
    E, k = cfg.num_experts, cfg.experts_per_token
    ranks = np.arange(1, E + 1, dtype=np.float64)
    pop = ranks ** -ZIPF_ALPHA
    pop /= pop.sum()
    p_tok = np.minimum(1.0, k * pop)          # P(one token routes to e)
    return 1.0 - (1.0 - p_tok) ** max(tokens, 1)


def train_profile(state, cfg: ModelConfig, shape: ShapeConfig,
                  remat: str = "block") -> list[TensorAccess]:
    """Access profile for one optimizer step."""
    out = []
    fwd_reads = 2.0 if remat == "block" else 1.0  # fwd + recompute
    tokens = shape.tokens
    emb_frac = min(1.0, tokens / cfg.vocab_size)
    for name, leaf in named_leaves(state):
        b = leaf_bytes(leaf)
        if b == 0 or name == "step" or name.endswith("count"):
            continue
        cat = _category(name)
        if cat == "moment":
            touches = 2.0                      # read + write in opt phase
        elif cat == "embed" and "embedding" in name:
            # gather rows fwd + scatter-add grads; unembed matmul reads all
            touches = fwd_reads * emb_frac + 1.0 + 3.0
        elif cat == "expert":
            # all experts receive grads in train; dense traffic
            touches = fwd_reads + 1.0 + 3.0   # fwd(+remat), bwd read, opt
        else:
            touches = fwd_reads + 1.0 + 3.0
        out.append(TensorAccess(name, b, touches, cat))
    return out


def serve_profile(params, caches, cfg: ModelConfig, shape: ShapeConfig,
                  expert_grain: bool = True) -> list[TensorAccess]:
    """Access profile for one decode step (or prefill if caches is None).

    With `expert_grain`, the stacked expert tensors are profiled per expert
    (the analysis analogue of the paper's page-grain PEBS sampling): each
    expert's activation probability follows the Zipf routing model, which is
    what produces the Fig 6-style skewed bandwidth-capacity curve for MoE
    archs at serving time.
    """
    out = []
    tokens = shape.global_batch if shape.kind == "decode" else shape.tokens
    emb_frac = min(1.0, tokens / cfg.vocab_size)
    p_act = (
        expert_activation_probs(cfg, tokens) if cfg.num_experts else None
    )
    for name, leaf in named_leaves(params):
        b = leaf_bytes(leaf)
        if b == 0:
            continue
        cat = _category(name)
        if cat == "expert":
            if expert_grain and cfg.num_experts:
                be = b // cfg.num_experts
                for e in range(cfg.num_experts):
                    out.append(TensorAccess(
                        f"{name}[e{e}]", be, float(p_act[e]), "expert"
                    ))
                continue
            touches = expected_expert_fraction(cfg, tokens)
        elif cat == "embed" and "embedding" in name:
            touches = emb_frac + 1.0          # gather + unembed matmul
        else:
            touches = 1.0
        out.append(TensorAccess(name, b, touches, cat))
    if caches is not None:
        for name, leaf in named_leaves(caches):
            b = leaf_bytes(leaf)
            if b == 0:
                continue
            # seq-indexed self-attention K/V (and an int8 pool's per-page
            # scale arrays, which ride with their pages): hot tail at full
            # rate, cold prefix at the reduced paged-decode rate (Fig 10
            # spread); SSM state / conv tails / cross-KV are read whole
            # every step.
            if shape.kind == "decode" and re.search(
                    r"(^|/)(k|v)(_sz)?$", name):
                for sfx, frac, touches in decode_cache_split(shape.seq_len):
                    out.append(TensorAccess(
                        f"cache/{name}{sfx}", int(b * frac), touches, "cache"
                    ))
                continue
            out.append(TensorAccess("cache/" + name, b, 1.0, "cache"))
    return out


def with_prefetch_excess(profile: list[TensorAccess], excess_bytes: float,
                         name: str = "prefetch/excess"
                         ) -> list[TensorAccess]:
    """Fold a prefetcher's fetched-but-unused bytes back into an access
    profile (paper §4.2: SuperLU's speculative HW prefetcher adds 37%
    excess traffic). The excess is real pool-link traffic per step — it
    inflates the profile's pool time, injected LoI, and interference
    coefficient exactly like useful traffic does, which is how a
    low-accuracy prefetcher turns itself into an interference injector.
    `excess_bytes` comes from `prefetch.PrefetchReport.excess_bytes` (per
    trace; divide by steps for per-step) or the pager's
    `prefetch_excess_bytes` counter."""
    if excess_bytes <= 0:
        return list(profile)
    return list(profile) + [
        TensorAccess(name, int(excess_bytes), 1.0, "other")
    ]


# ------------------------------------------------- Fig 6 scaling curve
def bandwidth_capacity_curve(profile: list[TensorAccess]):
    """Returns (footprint_fraction, traffic_fraction) arrays — the CDF of
    accesses vs footprint with tensors sorted by traffic density (hot
    first). The paper's Fig 6, tensor-grain."""
    items = sorted(profile, key=lambda a: a.touches, reverse=True)
    total_b = sum(a.bytes for a in items) or 1
    total_t = sum(a.traffic for a in items) or 1
    xs, ys = [0.0], [0.0]
    cb = ct = 0.0
    for a in items:
        cb += a.bytes
        ct += a.traffic
        xs.append(cb / total_b)
        ys.append(ct / total_t)
    return np.array(xs), np.array(ys)


def arithmetic_intensity(flops: float, profile: list[TensorAccess],
                         activation_bytes: float = 0.0) -> float:
    traffic = sum(a.traffic for a in profile) + activation_bytes
    return flops / traffic if traffic else 0.0
