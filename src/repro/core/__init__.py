"""The paper's contribution: three-level quantitative memory methodology.

  Level 1: core.access    — intrinsic characterization (bandwidth-capacity
                            scaling curves, arithmetic intensity)
  Level 2: core.tiers +
           core.placement — multi-tier capacity/bandwidth/access ratios and
                            placement policies
           core.roofline  — standard + multi-tier memory roofline
  Level 3: core.interference — LoI / IC / sensitivity on the pooled tier
"""
