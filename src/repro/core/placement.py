"""Level 2: placement policies and the R_cap <= R_access <= R_bw corridor.

Policies (all return a Placement):
  all_local   — everything in HBM (must fit; the smollm control case)
  first_touch — allocation order fills HBM then spills (Linux default the
                paper starts from; our baseline)
  hotness     — sort by traffic density, hottest into HBM (the paper's BFS
                case-study fix, §7.1)
  balanced_bw — hotness order, but stop filling HBM once the *pool's share
                of traffic* would drop below R_BW = B_pool/(B_hbm+B_pool):
                uses both tiers' bandwidth concurrently (paper §5's point
                that tiers ADD bandwidth when accesses are balanced)
  capacity    — fill so pool access share ~= pool capacity share (the
                paper's *lower* reference point; included as the anti-goal)

The placement quality metric is the predicted memory-phase time from the
multi-tier roofline: t = max(local_traffic/B_hbm, pool_traffic/B_link).
"""

from __future__ import annotations

import dataclasses

from repro.core.access import TensorAccess
from repro.core.tiers import TierTopology


@dataclasses.dataclass
class Placement:
    assignment: dict           # name -> "hbm" | "host"
    policy: str
    pool_fraction_target: float
    # metrics
    r_cap_pool: float          # pool share of placed bytes
    r_access_pool: float       # pool share of traffic (paper's R_access)
    r_bw_pool: float           # reference point
    local_bytes: float
    pool_bytes: float
    local_traffic: float
    pool_traffic: float
    t_memory: float            # predicted memory-phase seconds (per step)
    t_memory_all_local: float  # lower bound if everything were in HBM

    @property
    def slowdown(self) -> float:
        return (
            self.t_memory / self.t_memory_all_local
            if self.t_memory_all_local
            else 1.0
        )

    def tier_of(self, name: str) -> str:
        return self.assignment.get(name, "hbm")


def _finalize(assignment, profile, topo: TierTopology, policy: str,
              pool_fraction: float, scale: float = 1.0) -> Placement:
    """scale: global->per-chip byte scale (1/n_shards average)."""
    local_b = pool_b = local_t = pool_t = 0.0
    for a in profile:
        if assignment.get(a.name, "hbm") == "hbm":
            local_b += a.bytes
            local_t += a.traffic
        else:
            pool_b += a.bytes
            pool_t += a.traffic
    total_b = local_b + pool_b or 1.0
    total_t = local_t + pool_t or 1.0
    t_local = scale * local_t / topo.local.bandwidth
    t_pool = scale * pool_t / topo.pool.bandwidth
    t_all = scale * total_t / topo.local.bandwidth
    return Placement(
        assignment=assignment,
        policy=policy,
        pool_fraction_target=pool_fraction,
        r_cap_pool=pool_b / total_b,
        r_access_pool=pool_t / total_t,
        r_bw_pool=topo.r_bw_pool,
        local_bytes=local_b,
        pool_bytes=pool_b,
        local_traffic=local_t,
        pool_traffic=pool_t,
        t_memory=max(t_local, t_pool),
        t_memory_all_local=t_all,
    )


def place(profile: list[TensorAccess], topo: TierTopology, policy: str,
          pool_fraction: float = 0.5, per_chip_scale: float = 1.0
          ) -> Placement:
    total = sum(a.bytes for a in profile)
    local_cap_global = (1.0 - pool_fraction) * total

    if policy == "all_local":
        assignment = {a.name: "hbm" for a in profile}
        return _finalize(assignment, profile, topo, policy, 0.0,
                         per_chip_scale)

    if policy == "first_touch":
        order = list(profile)                 # allocation (tree) order
    elif policy in ("hotness", "balanced_bw", "capacity"):
        order = sorted(profile, key=lambda a: a.touches, reverse=True)
    else:
        raise ValueError(f"unknown policy {policy}")

    assignment = {}
    used = 0.0
    if policy == "balanced_bw":
        # fill HBM hot-first but keep pool traffic share >= R_BW so the pool
        # link contributes bandwidth instead of idling
        total_t = sum(a.traffic for a in profile) or 1.0
        r_bw = topo.r_bw_pool
        pool_t = total_t
        for a in order:
            would_pool_t = pool_t - a.traffic
            if used + a.bytes <= local_cap_global and (
                would_pool_t / total_t
            ) >= r_bw:
                assignment[a.name] = "hbm"
                used += a.bytes
                pool_t = would_pool_t
            else:
                assignment[a.name] = "host"
    elif policy == "capacity":
        # target pool access share ~= pool capacity share (reference only)
        total_t = sum(a.traffic for a in profile) or 1.0
        pool_t = total_t
        for a in order:
            if used + a.bytes <= local_cap_global and (
                pool_t - a.traffic
            ) / total_t >= pool_fraction:
                assignment[a.name] = "hbm"
                used += a.bytes
                pool_t -= a.traffic
            else:
                assignment[a.name] = "host"
    else:
        for a in order:
            if used + a.bytes <= local_cap_global:
                assignment[a.name] = "hbm"
                used += a.bytes
            else:
                assignment[a.name] = "host"

    return _finalize(assignment, profile, topo, policy, pool_fraction,
                     per_chip_scale)


def corridor_check(p: Placement) -> dict:
    """The paper's §5 tuning corridor: R_cap <= R_access <= R_bw."""
    return {
        "r_cap_pool": p.r_cap_pool,
        "r_access_pool": p.r_access_pool,
        "r_bw_pool": p.r_bw_pool,
        "below_capacity_ref": p.r_access_pool < p.r_cap_pool,
        "above_bandwidth_ref": p.r_access_pool > p.r_bw_pool,
        "in_corridor": p.r_cap_pool <= p.r_access_pool <= p.r_bw_pool,
    }
