"""Tier topology — Level 2 of the paper's methodology.

A `TierTopology` describes the per-chip memory system: the fast HBM tier and
the pooled host tier behind the PCIe link (the paper's rack-scale pool behind
CXL). `emulated(pool_fraction, working_set)` mirrors the paper's evaluation
method: rather than changing hardware, the *available* fast-tier capacity is
restricted so that the pool holds `pool_fraction` of the working set
(R_cap^remote = 25/50/75%).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.common import hw


@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str                 # "hbm" | "host"
    capacity: float           # bytes per chip
    bandwidth: float          # bytes/s per chip (stream)
    latency: float            # seconds
    # jax memory kind ("device" / "pinned_host"); the serving substrate
    # (repro.serving.substrate) places the physical pool twin with the
    # pool tier's kind, so analytical pricing and placement stay one model
    memory_kind: Optional[str]


@dataclasses.dataclass(frozen=True)
class TierTopology:
    tiers: tuple
    shared_link_bw: float     # host<->chips contention domain (bytes/s)
    chips_per_pool: int

    @property
    def local(self) -> TierSpec:
        return self.tiers[0]

    @property
    def pool(self) -> TierSpec:
        return self.tiers[1]

    @property
    def r_bw_pool(self) -> float:
        """The paper's R_BW reference: pool share of aggregate bandwidth."""
        total = sum(t.bandwidth for t in self.tiers)
        return self.pool.bandwidth / total

    def r_cap_pool(self) -> float:
        total = sum(t.capacity for t in self.tiers)
        return self.pool.capacity / total


def v5e_topology(chip: hw.ChipSpec = hw.V5E,
                 host: hw.HostSpec = hw.V5E_HOST) -> TierTopology:
    return TierTopology(
        tiers=(
            TierSpec("hbm", chip.hbm_bytes, chip.hbm_bw, 1e-7, "device"),
            TierSpec(
                "host",
                host.dram_bytes / host.chips_per_host,
                host.pcie_bw,
                2e-6,
                "pinned_host",
            ),
        ),
        shared_link_bw=host.pcie_shared_bw,
        chips_per_pool=host.chips_per_host,
    )


def emulated(pool_fraction: float, working_set: float,
             base: Optional[TierTopology] = None) -> TierTopology:
    """Paper-style emulation: restrict local capacity so the pool must hold
    `pool_fraction` of the working set (per chip)."""
    base = base or v5e_topology()
    local_cap = min(base.local.capacity, (1.0 - pool_fraction) * working_set)
    pool_cap = max(base.pool.capacity, pool_fraction * working_set)
    return TierTopology(
        tiers=(
            dataclasses.replace(base.local, capacity=local_cap),
            dataclasses.replace(base.pool, capacity=pool_cap),
        ),
        shared_link_bw=base.shared_link_bw,
        chips_per_pool=base.chips_per_pool,
    )
