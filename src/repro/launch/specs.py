"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

`input_specs(arch, shape)` returns the abstract inputs the dry-run lowers
with — weak-type-correct, shardable, zero allocation. Frontend stubs supply
precomputed frame/patch embedding SDS per the brief.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import configs
from repro.common.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.frontends import frontend_embed_shape

SDS = jax.ShapeDtypeStruct


def _frontend_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    out = {}
    shape = frontend_embed_shape(cfg, batch, seq)
    if cfg.frontend == "vision_stub":
        out["patches"] = SDS(shape, jnp.dtype(cfg.dtype))
    elif cfg.frontend == "audio_stub":
        out["frames"] = SDS(shape, jnp.dtype(cfg.dtype))
    return out


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    n_text = S - cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else S
    batch = {"tokens": SDS((B, n_text + 1), jnp.int32)}
    batch.update(_frontend_specs(cfg, B, S))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_stub":
        # encoder consumes S frames; decoder prompt is a BOS token
        return {
            "tokens": SDS((B, 1), jnp.int32),
            **_frontend_specs(cfg, B, S),
        }
    n_text = S - cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else S
    batch = {"tokens": SDS((B, n_text), jnp.int32)}
    batch.update(_frontend_specs(cfg, B, S))
    return batch


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    return SDS((shape.global_batch,), jnp.int32)


def input_specs(arch: str, shape_name: str) -> dict:
    """Abstract inputs for the cell's step function (see launch.dryrun)."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    return {
        "token": decode_token_specs(cfg, shape),
        "t": SDS((), jnp.int32),
    }
