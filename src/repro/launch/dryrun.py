import os, sys  # noqa: E401  (brief: set XLA_FLAGS before ANY other import)
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=" + os.environ.get("REPRO_DEVICES", "512" if "--multi-pod" in sys.argv else "256")).strip()  # noqa: E501

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, print memory/cost analysis, derive the 3-term roofline.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod]
  REPRO_DEVICES=16 python -m repro.launch.dryrun ... --mesh 4x4   (dev only)

Writes one JSON per cell under results/dryrun/.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.common.config import SHAPES, TrainConfig
from repro.core import roofline
from repro.launch import specs as S
from repro.launch.mesh import ctx_for_mesh, make_production_mesh
from repro.profiler.hlo import analyze_hlo
from repro.runtime import serve as serve_rt
from repro.runtime import sharding as shd
from repro.runtime import train as train_rt
from repro.runtime.tiering import apply_tier_shardings  # noqa: E402


# Grad-accumulation factors tuned so every train_4k cell's per-device temp
# fits v5e HBM (16 GiB) — measured from the v1 baseline sweep temps.
TRAIN_MICROBATCHES = {
    "smollm_360m": 2,
    "granite_moe_1b_a400m": 1,
    "granite_3_2b": 8,
    "paligemma_3b": 4,
    "mamba2_780m": 8,
    "mistral_nemo_12b": 8,
    "qwen2_5_32b": 16,
    "kimi_k2_1t_a32b": 8,
    "jamba_1_5_large_398b": 16,
    "seamless_m4t_large_v2": 32,
}


def build_mesh(args):
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        return jax.make_mesh(
            (d, m), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
        )
    return make_production_mesh(multi_pod=args.multi_pod)


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def lower_cell(arch: str, shape_name: str, mesh, args):
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ctx = ctx_for_mesh(mesh, fsdp=not args.no_fsdp, remat=args.remat)
    rules = shd.ShardingRules.for_training(
        fsdp_axis=ctx.fsdp_axis, tp_axis=ctx.tp_axis
    )
    ins = S.input_specs(arch, shape_name)

    tier_info = None
    if shape.kind == "train":
        mb = args.microbatches or TRAIN_MICROBATCHES.get(
            configs.canonical(arch), 8
        )
        tcfg = TrainConfig(microbatches=mb)
        bundle = train_rt.make_bundle(
            cfg, ctx, tcfg, rules, mesh, ins["batch"]
        )
        astate = bundle.abstract_state
        if args.tier_policy != "none":
            astate, bundle, tier_info = apply_tier_shardings(
                cfg, ctx, tcfg, rules, mesh, ins["batch"], bundle, shape,
                policy=args.tier_policy, pool_fraction=args.pool_fraction,
            )
        lowered = bundle.step_fn.lower(astate, ins["batch"])
        tokens = shape.tokens
        mf = roofline.model_flops_train(cfg.active_param_count(), tokens)
    elif shape.kind == "prefill":
        rules = shd.ShardingRules.for_serving(
            data_axis=ctx.fsdp_axis, tp_axis=ctx.tp_axis
        )
        sb = serve_rt.make_bundle(
            cfg, ctx, rules, mesh,
            batch=shape.global_batch, max_seq=shape.seq_len,
            enc_len=shape.seq_len if cfg.frontend == "audio_stub" else 0,
        )
        lowered = sb.prefill_fn.lower(sb.abstract_params, ins["batch"])
        mf = roofline.model_flops_decode(
            cfg.active_param_count(), shape.tokens
        )
    else:  # decode
        rules = shd.ShardingRules.for_serving(
            data_axis=ctx.fsdp_axis, tp_axis=ctx.tp_axis
        )
        enc_len = shape.seq_len if cfg.frontend == "audio_stub" else 0
        sb = serve_rt.make_bundle(
            cfg, ctx, rules, mesh,
            batch=shape.global_batch, max_seq=shape.seq_len, enc_len=enc_len,
        )
        lowered = sb.decode_fn.lower(
            sb.abstract_params, ins["token"], sb.abstract_caches, ins["t"]
        )
        mf = roofline.model_flops_decode(
            cfg.active_param_count(), shape.global_batch
        )
    return lowered, mf, tier_info


def run_cell(arch: str, shape_name: str, mesh, args, outdir: str):
    t0 = time.time()
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name(mesh),
        "tier_policy": args.tier_policy, "status": "ok",
    }
    try:
        lowered, model_flops, tier_info = lower_cell(
            arch, shape_name, mesh, args
        )
        if tier_info is not None:
            record["tier"] = tier_info
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        print(ma)                               # proves it fits
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax < 0.5: one dict per program
            ca = ca[0] if ca else {}
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        cost = analyze_hlo(compiled.as_text())
        rep = roofline.report(
            arch, shape_name, mesh_name(mesh), cost,
            n_devices=mesh.size, model_flops=model_flops,
        )
        record.update(
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory_analysis={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "host_argument_bytes": ma.host_argument_size_in_bytes,
                "generated_code_bytes": ma.generated_code_size_in_bytes,
            },
            xla_cost={"flops": ca.get("flops"),
                      "bytes_accessed": ca.get("bytes accessed")},
            hlo_cost={
                "flops_per_device": rep.flops,
                "hbm_bytes_per_device": rep.hbm_bytes,
                "wire_bytes_per_device": rep.wire_bytes,
                "collectives": rep.collective_by_kind,
                "warnings": rep.warnings[:10],
            },
            roofline={
                "t_compute_s": rep.t_compute,
                "t_memory_s": rep.t_memory,
                "t_collective_s": rep.t_collective,
                "dominant": rep.dominant,
                "model_flops": rep.model_flops,
                "useful_ratio": rep.useful_ratio,
                "bound_overlap_s": rep.bound_overlap,
                "bound_serial_s": rep.bound_serial,
                "roofline_fraction": rep.roofline_fraction,
            },
        )
        print(
            f"[{arch} x {shape_name} @ {record['mesh']}] "
            f"compute={rep.t_compute:.4f}s memory={rep.t_memory:.4f}s "
            f"collective={rep.t_collective:.4f}s -> {rep.dominant}-bound, "
            f"roofline_fraction={rep.roofline_fraction:.3f}"
        )
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        traceback.print_exc()
    record["wall_s"] = round(time.time() - t0, 1)
    os.makedirs(outdir, exist_ok=True)
    suffix = "" if args.tier_policy == "none" else f"_{args.tier_policy}"
    fn = os.path.join(
        outdir,
        f"{arch}_{shape_name}_{record['mesh']}{suffix}.json",
    )
    with open(fn, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None, help="dev override, e.g. 4x4")
    ap.add_argument("--remat", default="block", choices=["none", "block"])
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = per-arch auto (fits HBM)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tier-policy", default="none",
                    choices=["none", "first_touch", "hotness", "balanced_bw",
                             "capacity"])
    ap.add_argument("--pool-fraction", type=float, default=0.5)
    ap.add_argument("--outdir", default="results/dryrun")
    args = ap.parse_args(argv)

    mesh = build_mesh(args)
    cells = (
        configs.all_cells()
        if args.all
        else [(configs.canonical(args.arch), args.shape)]
    )
    results = []
    for arch, shape in cells:
        results.append(run_cell(arch, shape, mesh, args, args.outdir))
    n_ok = sum(1 for r in results if r["status"] == "ok")
    print(f"\n{n_ok}/{len(results)} cells OK on mesh {mesh_name(mesh)}")
    if n_ok < len(results):
        for r in results:
            if r["status"] != "ok":
                print(" FAIL", r["arch"], r["shape"], r["error"])
        sys.exit(1)


if __name__ == "__main__":
    main()
