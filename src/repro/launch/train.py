"""Training launcher: end-to-end loop with checkpoint/restart, straggler
watchdog, prefetching data pipeline, and the paper's tier placement applied
to the training state.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 200 --batch 8 --seq 128

Full-size archs need the production mesh (TPU pod); --reduced runs the
same code path on CPU (the smoke/integration config).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.common.config import ShapeConfig, TrainConfig
from repro.data import PrefetchPipeline
from repro.data.synthetic import make_batch_for
from repro.launch.mesh import ctx_for_mesh, make_smoke_mesh
from repro.runtime import sharding as shd
from repro.runtime import train as train_rt
from repro.runtime.fault import StragglerWatchdog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(
        args.arch
    )
    mesh = make_smoke_mesh()
    ctx = ctx_for_mesh(mesh, fsdp=False, remat="block")
    rules = shd.ShardingRules.for_training(fsdp_axis=None,
                                           tp_axis=ctx.tp_axis)
    tcfg = TrainConfig(
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        microbatches=args.microbatches,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        seed=args.seed,
    )
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    example = make_batch_for(cfg, args.seq, args.batch, 0, args.seed)
    bundle = train_rt.make_bundle(cfg, ctx, tcfg, rules, mesh, example,
                                  donate=True)

    ckpt = CheckpointManager(args.ckpt_dir)
    start_step = 0
    state, _ = train_rt.init_train_state(cfg, jax.random.PRNGKey(args.seed))
    if args.resume and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        state = ckpt.restore(start_step, state)
        print(f"resumed from step {start_step}")

    pipeline = PrefetchPipeline(
        lambda s: make_batch_for(cfg, args.seq, args.batch, s, args.seed),
        start_step=start_step,
    )
    watchdog = StragglerWatchdog(
        on_straggler=lambda r: print(
            f"[straggler] step {r.step}: {r.step_time:.3f}s "
            f"({r.ratio:.1f}x ewma)"
        )
    )

    losses = []
    t_start = time.time()
    try:
        for step in range(start_step, args.steps):
            ds_step, batch = pipeline.get()
            assert ds_step == step, (ds_step, step)
            watchdog.start_step()
            state, metrics = bundle.step_fn(state, batch)
            loss = float(metrics["loss"])
            watchdog.end_step(step)
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"step {step:5d} loss {loss:8.4f} "
                    f"acc {float(metrics['accuracy']):.3f} "
                    f"gnorm {float(metrics['grad_norm']):.2f}"
                )
            if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
                ckpt.save(step + 1, state)
    finally:
        pipeline.close()
        ckpt.wait()

    wall = time.time() - t_start
    print(
        f"done: {args.steps - start_step} steps in {wall:.1f}s, "
        f"loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
        f"{len(watchdog.flagged)} straggler events"
    )
    assert np.isfinite(losses[-1])
    return losses


if __name__ == "__main__":
    main()
