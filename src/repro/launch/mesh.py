"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. The production target is a TPU v5e pod of 16x16=256 chips;
multi-pod doubles it with a leading "pod" axis over DCN.
"""

from __future__ import annotations

import jax

from repro.common.parallel import ParallelCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for CPU smoke tests (1 device)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def ctx_for_mesh(mesh, *, fsdp: bool = True, remat: str = "block",
                 shard_seq_moe: bool = True) -> ParallelCtx:
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    return ParallelCtx(
        mesh=mesh,
        dp_axes=dp,
        fsdp_axis="data" if (fsdp and "data" in names
                             and mesh.shape["data"] > 1) else None,
        tp_axis="model" if "model" in names else None,
        shard_seq_moe=shard_seq_moe,
        remat=remat,
    )
