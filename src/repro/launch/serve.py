"""Serving launcher: continuous-batching engine over tier-aware KV paging.

    # scenario mode (the engine's native shape)
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --scenario chat --requests 16 --slots 4

    # classic one-shot batch (kept for parity with the old launcher):
    # `--batch` requests of the same prompt length arrive at t=0
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --batch 4 --prompt-len 32 --gen 16

Both modes run `repro.serving.ServingEngine`: fixed-shape jitted cells
(bucketed prefill, slot-batched greedy decode with per-slot positions),
the page-grain tier-aware KV pager, and M/D/1-knee admission control.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro import configs
from repro.launch.mesh import ctx_for_mesh, make_smoke_mesh
from repro.serving import (
    EngineConfig,
    Request,
    ServingEngine,
    fleet,
    make_scenario,
)


def burst_requests(n: int, prompt_len: int, gen: int, vocab: int,
                   seed: int) -> list:
    """The old launcher's shape: n identical-length prompts at t=0."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            request_id=i,
            tokens=rng.integers(0, vocab, size=prompt_len).astype(np.int32),
            max_new_tokens=gen,
            arrival=0.0,
        )
        for i in range(n)
    ]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # classic one-shot batch
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    # scenario mode
    ap.add_argument("--scenario", default=None,
                    choices=["chat", "long_context", "bursty",
                             "shared_prefix", "multi_tenant"])
    ap.add_argument("--requests", type=int, default=16)
    # fleet mode
    ap.add_argument("--fleet", type=int, default=0,
                    help="run N engines behind the FleetRouter "
                    "(0 = single-engine path)")
    ap.add_argument("--policy", default="round_robin",
                    choices=list(fleet.POLICIES),
                    help="fleet placement policy")
    ap.add_argument("--roles", action="store_true",
                    help="disaggregated prefill/decode roles (fleet "
                    "mode; needs --prefill-chunk)")
    ap.add_argument("--autoscale-min", type=int, default=0,
                    help="fleet autoscaling: start/min engine count "
                    "(0 = autoscaling off; max is --fleet)")
    # engine knobs
    ap.add_argument("--slots", type=int, default=0,
                    help="0 = match --batch (one-shot) / 4 (scenario)")
    ap.add_argument("--pager", default="hotness",
                    choices=["hotness", "static", "none"])
    ap.add_argument("--contiguous", action="store_true",
                    help="per-slot contiguous caches instead of the "
                    "paged physical page pool (the pre-PR-4 layout)")
    ap.add_argument("--pool-dtype", default=None,
                    choices=["int8", "fp"],
                    help="paged pool payload (default: engine default, "
                    "int8; --contiguous forces fp)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="interleave prompt chunks of this many tokens "
                    "with decode steps (paged, attention-only archs; "
                    "0 = serialized whole-prompt prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix radix cache in every engine "
                    "(paged, attention-only archs)")
    ap.add_argument("--local-budget", type=float, default=0.5,
                    help="local-tier budget as a fraction of peak KV bytes")
    ap.add_argument("--admission", default="loi",
                    choices=["loi", "greedy"])
    args = ap.parse_args(argv)

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(
        args.arch
    )
    mesh = make_smoke_mesh()
    ctx = ctx_for_mesh(mesh, fsdp=False, remat="none")

    if args.scenario:
        n_slots = args.slots or 4
        buckets = {
            "long_context": (128,),
            "shared_prefix": (32,),
            "multi_tenant": (16, 32, 64),
        }.get(args.scenario, (16, 32))
        max_seq = max(buckets) + 64
        # arrival processes scaled to the virtual clock (µs-scale steps on
        # reduced models) so requests actually overlap in flight
        scenario_kw = {
            "chat": dict(prompt_buckets=buckets, arrival_rate=2e4),
            "long_context": dict(prompt_bucket=buckets[0],
                                 arrival_rate=5e3),
            "bursty": dict(prompt_buckets=buckets, burst_size=n_slots + 2,
                           burst_gap=1e-4),
            "shared_prefix": dict(prompt_buckets=buckets,
                                  system_tokens=16, n_systems=2,
                                  arrival_rate=2e4),
            "multi_tenant": dict(interactive_buckets=buckets[:2],
                                 batch_bucket=buckets[-1],
                                 arrival_rate=2e4, batch_gap=1e-4),
        }[args.scenario]
        reqs = make_scenario(
            args.scenario, args.requests, cfg.vocab_size, seed=args.seed,
            **scenario_kw,
        )
    else:
        n_slots = args.slots or args.batch
        buckets = (args.prompt_len,)
        max_seq = args.prompt_len + args.gen
        reqs = burst_requests(
            args.batch, args.prompt_len, args.gen, cfg.vocab_size,
            args.seed,
        )

    page_tokens = max(8, max_seq // 16)
    if args.prefill_chunk and args.contiguous:
        ap.error("--prefill-chunk needs the paged layout; drop "
                 "--contiguous")
    if args.prefill_chunk:
        # chunks scatter whole pages through the block table: pin the
        # page grain to 8 (every bucket is a multiple) and round the
        # chunk up to whole pages
        page_tokens = 8
        args.prefill_chunk = -(-args.prefill_chunk // page_tokens) \
            * page_tokens
        bad = [b for b in buckets if b % args.prefill_chunk]
        if bad:
            ap.error(
                f"--prefill-chunk {args.prefill_chunk} (page-rounded) "
                f"must divide every prompt bucket {tuple(buckets)}; "
                f"try one of "
                f"{sorted({c for c in (8, 16, 32, 64) if not any(b % c for b in buckets)})}"
            )
    ecfg = EngineConfig(
        n_slots=n_slots,
        max_seq=max_seq,
        prefill_buckets=buckets,
        paged=not args.contiguous,
        # contiguous caches have no pool to quantize: pin the fp net
        pool_dtype="fp" if args.contiguous else (args.pool_dtype
                                                 or EngineConfig.pool_dtype),
        prefill_chunk=args.prefill_chunk or None,
        page_tokens=page_tokens,
        local_budget_frac=args.local_budget,
        pager_policy=args.pager,
        hot_window=max(16, max_seq // 4),
        admission=args.admission,
        catalog_arch=args.arch if args.admission == "loi" else None,
        prefix_cache=args.prefix_cache,
    )

    if args.fleet:
        if args.roles and not args.prefill_chunk:
            ap.error("--roles needs --prefill-chunk (the prefill-role "
                     "engine runs chunked prefill)")
        scale = None
        if args.autoscale_min:
            scale = fleet.AutoscaleConfig(
                min_engines=args.autoscale_min, max_engines=args.fleet)
        fcfg = fleet.FleetConfig(
            n_engines=args.fleet, policy=args.policy, roles=args.roles,
            autoscale=scale,
        )
        router = fleet.FleetRouter.build(
            cfg, ctx, ecfg, fcfg, mesh=mesh, seed=args.seed)
        fstats = router.run(reqs)
        s = fstats.summary()
        print(
            f"fleet[{args.fleet} x {args.policy}"
            f"{' roles' if args.roles else ''}]: served {s['requests']} "
            f"requests / {s['tokens']} tokens "
            f"({s['tok_per_s_virtual']:.1f} tok/s virtual) "
            f"routed={s['routed']}"
        )
        print(
            f"latency: ttft_p50={s['ttft_p50']:.2e}s "
            f"ttft_p95={s['ttft_p95']:.2e}s ttft_p99={s['ttft_p99']:.2e}s "
            f"tpot_p50={s['tpot_p50']:.2e}s"
        )
        print(
            f"prefix_hit_rate={s['prefix_hit_rate']:.3f} "
            f"transfers={s['transfers']} "
            f"transfer_bytes={s['transfer_bytes']:.0f} "
            f"cancelled={s['cancelled']} scale_events={s['scale_events']}"
        )
        done = [r for r in reqs if r.output]
        print("sample:", done[0].output[:12] if done else "(no requests)")
        return fstats

    engine = ServingEngine.build(
        cfg, ctx, ecfg, mesh=mesh, seed=args.seed
    )
    stats = engine.run(reqs)
    s = stats.summary()
    print(
        f"served {s['n_requests']} requests / {s['tokens']} tokens in "
        f"{stats.steps} steps ({s['tok_per_s_wall']:.1f} tok/s wall, "
        f"{s['tok_per_s_virtual']:.1f} tok/s virtual)"
    )
    print(
        f"latency: ttft_p50={s['ttft_p50_s']:.2e}s "
        f"tpot_p50={s['tpot_p50_s']:.2e}s tpot_p99={s['tpot_p99_s']:.2e}s "
        f"stall_p95={s['stall_p95_s']:.2e}s"
    )
    print(
        f"tiering[{args.pager}]: remote_share={s['remote_share']:.3f} "
        f"evictions={engine.pager.evictions} "
        f"promotions={engine.pager.promotions} "
        f"admission_blocks={s['admission_blocks']} "
        f"max_concurrency={s['max_concurrency']}"
    )
    print("compile counts (must stay flat at steady state):",
          engine.compile_counts())
    done = [r for r in reqs if r.output]
    print("sample:", done[0].output[:12] if done else "(no requests)")
    return stats


if __name__ == "__main__":
    main()
