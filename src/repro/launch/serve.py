"""Serving launcher: batched prefill + decode loop with tier-aware KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.common.config import ShapeConfig
from repro.data.synthetic import make_batch_for
from repro.launch.mesh import ctx_for_mesh, make_smoke_mesh
from repro.models import model as M
from repro.runtime import serve as serve_rt
from repro.runtime import sharding as shd


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(
        args.arch
    )
    mesh = make_smoke_mesh()
    ctx = ctx_for_mesh(mesh, fsdp=False, remat="none")
    max_seq = args.prompt_len + args.gen

    params, _ = M.init_model(cfg, jax.random.PRNGKey(args.seed))
    batch = make_batch_for(cfg, args.prompt_len, args.batch, 0, args.seed)
    prompt = {k: (v[:, :args.prompt_len] if k == "tokens" else v)
              for k, v in batch.items()}

    t0 = time.time()
    caches, logits = M.prefill(params, prompt, cfg, ctx, max_seq=max_seq)
    tok = jnp.argmax(logits, axis=-1)
    t_prefill = time.time() - t0

    npfx = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        t = args.prompt_len + npfx + i
        logits, caches = M.decode_step(params, tok, caches, t, cfg, ctx)
        tok = jnp.argmax(logits, axis=-1)
        generated.append(tok)
    t_decode = time.time() - t0

    out = jnp.stack(generated, axis=1)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.3f}s")
    print(
        f"decode: {args.gen - 1} steps in {t_decode:.3f}s "
        f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)"
    )
    print("sample:", out[0, :12].tolist())
    return out


if __name__ == "__main__":
    main()
