"""Predictive prefetch subsystem — the paper's §4.2 finding (prefetch
traffic dominates tiered-memory profiles; its accuracy/coverage/excess
decide whether a pooled tier helps or hurts) promoted from the one
statically-schedulable case the repo modeled to a subsystem for DYNAMIC
access streams.

Three-level mapping (each level one module, composable across sources):

  1. capture  (`trace.py`, `workloads.py`, `static.py`) — demand
     page-touch streams as `AccessTrace`: the serving KV pager's
     hot-tail/cold-prefix stream (`kv_pager_trace` or a live
     `TraceRecorder` on a `KVPager`), the rack simulator's co-resident
     pool traffic (`sched_pool_trace`), the BFS-on-CSR frontier
     expansion over a pool-resident adjacency array (`bfs_trace`, with
     application hints), and the static layer stream
     (`layer_stream_trace` — the subsumed `runtime/prefetch.py` case).
  2. predict  (`predictors.py`) — one protocol
     (observe/start_step/predict), seven predictors: next_line, stride,
     stream, markov, ghb (second-order delta-correlation history),
     static (accuracy=1 schedule), and the application-directed frontier
     predictor.
  3. score    (`engine.py`) — the shared `PrefetchEngine` replays any
     trace under any predictor against a local page budget and a
     matched pool link, charges issued pool->local copies, and reports
     the paper's Fig 7/8 metrics (accuracy, coverage, timeliness,
     excess) plus remote stalls; fetched-but-unused bytes feed back
     into `core.access` profiles via `with_prefetch_excess`.

Serving integration: `serving.kv_pager.PagerConfig(prefetch=<name>)`
switches the pager's cold-prefix page-in from demand paging to
prediction-driven staging (discrete touch schedule, demand vs prefetched
pool bytes split), and `kernels/decode_attention/paged.py` makes the
pager's page grain real at the kernel level (block-index-map gather over
non-contiguous KV pages).
"""

from repro.prefetch.engine import (
    AdaptiveSwitcher,
    PrefetchConfig,
    PrefetchEngine,
    PrefetchReport,
    evaluate_zoo,
    remote_reduction,
)
from repro.prefetch.predictors import (
    FrontierPredictor,
    GHBPredictor,
    MarkovPredictor,
    NextLinePredictor,
    Predictor,
    StaticSchedulePredictor,
    StreamPredictor,
    StridePredictor,
    make_predictor,
    zoo_names,
)
from repro.prefetch.trace import (
    AccessTrace,
    TraceRecorder,
    kv_pager_trace,
    sched_pool_trace,
)
from repro.prefetch.workloads import BFSTrace, bfs_levels, bfs_trace, \
    random_csr

__all__ = [
    "AccessTrace",
    "AdaptiveSwitcher",
    "BFSTrace",
    "FrontierPredictor",
    "GHBPredictor",
    "MarkovPredictor",
    "NextLinePredictor",
    "Predictor",
    "PrefetchConfig",
    "PrefetchEngine",
    "PrefetchReport",
    "StaticSchedulePredictor",
    "StreamPredictor",
    "StridePredictor",
    "TraceRecorder",
    "bfs_levels",
    "bfs_trace",
    "evaluate_zoo",
    "kv_pager_trace",
    "make_predictor",
    "random_csr",
    "remote_reduction",
    "sched_pool_trace",
    "zoo_names",
]
