"""The shared `PrefetchEngine`: replay an `AccessTrace` against a local
page cache + pool link, let one predictor issue pool->local page copies,
and score it with the paper's Fig 7/8 metrics.

Tier model (one engine step = one unit of workload compute):

* the local tier holds `local_pages` pages (LRU, touched-this-step pages
  are never victims);
* the pool link moves at most `bw_pages_per_step` pages per step —
  demand fetches have priority, prefetches get the leftover (matched
  pool bandwidth: every predictor, including the demand baseline, sees
  the same link);
* a prefetch issued at step i arrives at step i + `latency_steps`. At
  the default latency of 1 every correct prediction is in time (one
  step of compute hides the transfer — the layer-ahead regime of
  `prefetch/static.py`); with a slower pool (`latency_steps >= 2`) a
  correct-but-shallow prediction is LATE: the touch still stalls, the
  transfer is not re-issued, and only predictors that run far enough
  ahead (deep stride/stream depth, multi-step schedules) keep their
  coverage — timeliness is a first-class metric, not an accuracy
  footnote;
* step time = t_compute + stalls * t_fetch; demand misses and late
  prefetches stall, in-time prefetched copies overlap compute.

Metrics (paper Fig 7/8 vocabulary):

  accuracy   — (useful + late) / issued: was the prediction right?
  coverage   — useful / (useful + late + demand): misses removed.
  timeliness — useful / (useful + late): right AND on time.
  excess     — never-used issued transfers / issued: wasted pool-link
               bytes, fed back into `core.access` profiles via
               `with_prefetch_excess` (a speculative prefetcher is an
               interference injector — the paper's SuperLU 37% case).

`remote_accesses` (demand + late stalls) is the §7.1 acceptance number:
frontier-directed prefetch must cut it >= 40% vs the demand baseline.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.prefetch.predictors import Predictor, make_predictor
from repro.prefetch.trace import AccessTrace


@dataclasses.dataclass(frozen=True)
class PrefetchConfig:
    local_pages: int                 # local-tier page budget
    bw_pages_per_step: int           # pool-link pages/step (matched)
    degree: int = 8                  # max prefetches issued per step
    t_compute: float = 1.0           # seconds of compute per step
    t_fetch: float = 0.05            # stall per demand/late page
    latency_steps: int = 1           # steps before an issued page lands

    def __post_init__(self):
        if self.local_pages < 1 or self.bw_pages_per_step < 1:
            raise ValueError("local_pages and bw_pages_per_step must be >=1")
        if self.latency_steps < 1:
            raise ValueError("latency_steps must be >= 1")


@dataclasses.dataclass
class PrefetchReport:
    predictor: str
    trace: str
    source: str
    page_bytes: float
    steps: int
    touches: int
    local_hits: int
    demand_misses: int
    issued: int
    useful: int                      # prefetched, arrived in time, touched
    late: int                        # prefetched, touched while in flight
    total_time: float

    @property
    def accuracy(self) -> float:
        return (self.useful + self.late) / self.issued if self.issued else 0.0

    @property
    def coverage(self) -> float:
        misses = self.useful + self.late + self.demand_misses
        return self.useful / misses if misses else 0.0

    @property
    def timeliness(self) -> float:
        right = self.useful + self.late
        return self.useful / right if right else 0.0

    @property
    def excess(self) -> float:
        return ((self.issued - self.useful - self.late) / self.issued
                if self.issued else 0.0)

    @property
    def excess_bytes(self) -> float:
        return (self.issued - self.useful - self.late) * self.page_bytes

    @property
    def remote_accesses(self) -> int:
        """Accesses that stall on the pool tier (the §7.1 number)."""
        return self.demand_misses + self.late

    @property
    def remote_bytes(self) -> float:
        return self.remote_accesses * self.page_bytes

    def summary(self) -> Dict[str, float]:
        return {
            "predictor": self.predictor,
            "trace": self.trace,
            "source": self.source,
            "accuracy": self.accuracy,
            "coverage": self.coverage,
            "timeliness": self.timeliness,
            "excess": self.excess,
            "remote_accesses": self.remote_accesses,
            "issued": self.issued,
            "total_time": self.total_time,
        }


class PrefetchEngine:
    """Deterministic replay of one trace under one predictor."""

    def __init__(self, cfg: PrefetchConfig):
        self.cfg = cfg

    def run(self, trace: AccessTrace, predictor: Predictor
            ) -> PrefetchReport:
        cfg = self.cfg
        local: "collections.OrderedDict[int, bool]" = collections.OrderedDict()
        # page -> arrival step; issued-but-not-yet-arrived transfers
        inflight: Dict[int, int] = {}
        # issued-by-prefetch pages not yet touched (accuracy bookkeeping)
        pending: set = set()

        hits = demand = issued = useful = late = 0
        total_time = 0.0

        def touch_lru(p: int) -> None:
            local.pop(p, None)
            local[p] = True                      # most-recent position

        def evict(protect: set) -> None:
            while len(local) > cfg.local_pages:
                for cand in local:               # oldest first
                    if cand not in protect:
                        local.pop(cand)
                        pending.discard(cand)
                        break
                else:
                    break                        # everything is protected

        for i, step_pages in enumerate(trace.steps):
            # arrivals from the previous step's issues
            for p in [p for p, t in inflight.items() if t <= i]:
                del inflight[p]
                local[p] = True
            hint = trace.hints[i] if trace.hints is not None else None
            predictor.start_step(hint)

            bw = cfg.bw_pages_per_step
            stalls = 0
            protect = set(step_pages)
            for p in step_pages:
                if p in local:
                    hits += 1
                    if p in pending:
                        pending.discard(p)
                        useful += 1
                elif p in inflight:
                    late += 1                    # right page, too late
                    stalls += 1
                    del inflight[p]
                    pending.discard(p)
                    local[p] = True
                else:
                    demand += 1
                    stalls += 1
                    bw -= 1                      # demand takes link share
                    local[p] = True
                touch_lru(p)
                predictor.observe(p)
            evict(protect)

            # leftover link bandwidth goes to prediction
            for p in predictor.predict(cfg.degree):
                if bw <= 0:
                    break
                if 0 <= p < trace.n_pages and p not in local \
                        and p not in inflight:
                    inflight[p] = i + cfg.latency_steps
                    pending.add(p)
                    issued += 1
                    bw -= 1
            total_time += cfg.t_compute + stalls * cfg.t_fetch

        return PrefetchReport(
            predictor=predictor.name,
            trace=trace.name,
            source=trace.source,
            page_bytes=trace.page_bytes,
            steps=trace.n_steps,
            touches=trace.touches,
            local_hits=hits,
            demand_misses=demand,
            issued=issued,
            useful=useful,
            late=late,
            total_time=total_time,
        )


class AdaptiveSwitcher(Predictor):
    """Accuracy-tracked per-phase predictor switching.

    No single stream predictor wins every phase of a real trace — a
    sequential prefill phase wants `next_line`, a strided re-read wants
    `stride`, interleaved slots want `stream`. The switcher runs every
    candidate in SHADOW: all of them observe the full demand stream and
    predict every step, but only the active candidate's predictions are
    returned (and thus charged against the pool link). Each candidate's
    shadow predictions are scored against the touches that follow — a
    prediction that is touched within `ttl` steps counts as a hit, one
    that expires counts as a miss — into a rolling window of the last
    `window` outcomes. Every `phase_steps` steps the switcher moves the
    active role to the candidate with the best windowed accuracy (ties
    keep the incumbent, so a phase of equals never thrashes).

    Shadow scoring is free by construction: predictions are lists of
    page ids, only the ACTIVE list turns into transfers, so the
    switcher's excess-traffic profile is exactly its active history.
    """

    name = "adaptive"

    #: default candidate set: the stream-learnable zoo (no schedules or
    #: hints required — same constraint the KV pager puts on predictors)
    CANDIDATES = ("next_line", "stride", "stream", "markov", "ghb")

    def __init__(self, candidates: Optional[List[Predictor]] = None,
                 window: int = 64, ttl: int = 4, phase_steps: int = 16):
        if candidates is None:
            candidates = [make_predictor(n) for n in self.CANDIDATES]
        if not candidates:
            raise ValueError("adaptive switcher needs >= 1 candidate")
        if window < 1 or ttl < 1 or phase_steps < 1:
            raise ValueError("window, ttl and phase_steps must be >= 1")
        self.candidates = list(candidates)
        self.window = int(window)
        self.ttl = int(ttl)
        self.phase_steps = int(phase_steps)
        self.active = 0
        self.switches = 0
        self._step = 0
        # per-candidate shadow state: page -> expiry step / outcome window
        self._outstanding: List[Dict[int, int]] = [
            {} for _ in self.candidates]
        self._scores = [collections.deque(maxlen=self.window)
                        for _ in self.candidates]

    def _accuracy(self, i: int) -> float:
        s = self._scores[i]
        # unscored candidates rank below any scored one: a predictor
        # that never commits (empty predictions) must not hold the
        # active role against one with a real record
        return sum(s) / len(s) if s else -1.0

    def accuracies(self) -> List[float]:
        """Windowed shadow accuracy per candidate (diagnostics)."""
        return [self._accuracy(i) for i in range(len(self.candidates))]

    def start_step(self, hint: Optional[Sequence[int]] = None) -> None:
        self._step += 1
        for i, out in enumerate(self._outstanding):
            for p in [p for p, t in out.items() if t <= self._step]:
                del out[p]
                self._scores[i].append(0)        # expired unused: miss
        if self._step % self.phase_steps == 0:
            best = max(
                range(len(self.candidates)),
                key=lambda i: (self._accuracy(i), i == self.active),
            )
            if best != self.active:
                self.active = best
                self.switches += 1
        for c in self.candidates:
            c.start_step(hint)

    def observe(self, page: int) -> None:
        for i, (c, out) in enumerate(
                zip(self.candidates, self._outstanding)):
            if page in out:
                del out[page]
                self._scores[i].append(1)        # touched in time: hit
            c.observe(page)

    def predict(self, degree: int) -> List[int]:
        chosen: List[int] = []
        for i, c in enumerate(self.candidates):
            preds = c.predict(degree)
            shadow = self._outstanding[i]
            for p in preds:
                if p not in shadow:
                    shadow[p] = self._step + self.ttl
            if i == self.active:
                chosen = preds
        return chosen


def evaluate_zoo(trace: AccessTrace, cfg: PrefetchConfig,
                 predictors: Optional[List[str]] = None
                 ) -> List[PrefetchReport]:
    """Score the predictor zoo (plus the demand baseline first) on one
    trace under one matched-bandwidth engine config. `static` is built
    with the trace's own schedule (the accuracy=1 upper bound);
    `frontier` only moves when the trace carries hints."""
    names = predictors or ["demand", "next_line", "stride", "stream",
                           "markov", "ghb", "static", "frontier"]
    out = []
    for name in names:
        if name == "static":
            p = make_predictor("static", schedule=trace.steps)
        elif name == "stream":
            # size regions to the trace's address space so distinct
            # streams (slots/jobs) land in distinct table entries
            p = make_predictor(
                "stream", region_pages=max(16, trace.n_pages // 8)
            )
        else:
            p = make_predictor(name)
        out.append(PrefetchEngine(cfg).run(trace, p))
    return out


def remote_reduction(reports: List[PrefetchReport],
                     predictor: str) -> float:
    """Remote-access reduction of `predictor` vs the demand baseline in
    the same report set (1.0 = all remote stalls eliminated)."""
    base = next(r for r in reports if r.predictor == "demand")
    pred = next(r for r in reports if r.predictor == predictor)
    if base.remote_accesses == 0:
        return 0.0
    return 1.0 - pred.remote_accesses / base.remote_accesses
