"""BFS over a pool-resident CSR graph — the paper's §7.1 case study.

The adjacency array (`indices` of the CSR) lives on the pool tier; BFS
frontier expansion reads the adjacency lists of the current frontier's
vertices. Because frontier vertices are scattered, the page-touch stream
is irregular — the access pattern HW prefetchers fail on — but the
*application* knows the next frontier exactly (it just computed it), so
it can direct prefetch of the next chunk's adjacency pages. The paper
measures this cutting remote accesses by ~50% for a 13% speedup; the
`frontier` predictor + `PrefetchEngine` reproduce the mechanism and
`benchmarks/bench_bfs_case.py` the headline number.

`bfs_trace` chunks each BFS level into engine steps of `chunk` vertices;
`hints[i]` carries step i+1's adjacency pages (the app-directed forecast
— within a level the remaining frontier is known, and the first chunk of
level L+1 is known once level L's expansion completes).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.prefetch.trace import AccessTrace


def random_csr(n_vertices: int, avg_degree: int,
               seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Uniform random digraph in CSR form (indptr, indices). Degrees are
    Poisson-ish around `avg_degree`; endpoints uniform — adjacency pages
    of any frontier are scattered over the whole `indices` array."""
    rng = np.random.default_rng(seed)
    degrees = rng.poisson(avg_degree, n_vertices).astype(np.int64)
    degrees = np.maximum(degrees, 1)
    indptr = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(degrees, out=indptr[1:])
    indices = rng.integers(0, n_vertices, indptr[-1]).astype(np.int64)
    return indptr, indices


def bfs_levels(indptr: np.ndarray, indices: np.ndarray,
               src: int = 0) -> List[np.ndarray]:
    """Top-down BFS; returns the frontier per level in DISCOVERY order
    (the natural queue order — sorting it would turn the adjacency walk
    into a near-sequential CSR sweep and hand HW prefetchers an easy
    pattern the real workload does not have)."""
    n = len(indptr) - 1
    visited = np.zeros(n, dtype=bool)
    visited[src] = True
    frontier = np.array([src], dtype=np.int64)
    levels = [frontier]
    while len(frontier):
        neigh = np.concatenate(
            [indices[indptr[v]:indptr[v + 1]] for v in frontier]
        )
        fresh = ~visited[neigh]
        # first-seen dedup in discovery order
        first = np.zeros(len(neigh), dtype=bool)
        seen_at = {}
        for i in np.nonzero(fresh)[0]:
            u = int(neigh[i])
            if u not in seen_at:
                seen_at[u] = i
                first[i] = True
        nxt = neigh[first]
        visited[nxt] = True
        if not len(nxt):
            break
        levels.append(nxt)
        frontier = nxt
    return levels


@dataclasses.dataclass
class BFSTrace:
    trace: AccessTrace
    levels: List[np.ndarray]
    n_vertices: int
    n_edges: int


def _adjacency_pages(indptr, vertices, edges_per_page) -> List[int]:
    """Distinct pages of the CSR `indices` array covering the adjacency
    lists of `vertices`, in traversal order."""
    pages: List[int] = []
    seen = set()
    for v in vertices:
        lo, hi = indptr[v], indptr[v + 1]
        for p in range(lo // edges_per_page, max(hi - 1, lo) //
                       edges_per_page + 1):
            if p not in seen:
                seen.add(p)
                pages.append(int(p))
    return pages


def bfs_trace(n_vertices: int = 4096, avg_degree: int = 16,
              page_bytes: float = 1024.0, bytes_per_edge: int = 4,
              chunk: int = 32, src: int = 0, seed: int = 0) -> BFSTrace:
    """Build the BFS page-touch trace with application-directed hints.

    Step i touches the adjacency pages of `chunk` frontier vertices;
    `hints[i]` is step i+1's page list (the software pipeline: expand
    chunk j while prefetching chunk j+1's lists)."""
    indptr, indices = random_csr(n_vertices, avg_degree, seed)
    edges_per_page = max(1, int(page_bytes) // bytes_per_edge)
    n_pages = -(-len(indices) // edges_per_page)
    levels = bfs_levels(indptr, indices, src)

    chunks: List[np.ndarray] = []
    for frontier in levels:
        for i in range(0, len(frontier), chunk):
            chunks.append(frontier[i:i + chunk])
    steps = [_adjacency_pages(indptr, c, edges_per_page) for c in chunks]
    hints = steps[1:] + [[]]
    trace = AccessTrace(
        f"bfs_v{n_vertices}_d{avg_degree}", "bfs", page_bytes, n_pages,
        steps, hints=hints,
    ).validate()
    return BFSTrace(trace, levels, n_vertices, int(len(indices)))
