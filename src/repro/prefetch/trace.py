"""Demand-trace capture: page-touch streams from the three dynamic
sources the paper profiles (§4.2) — the serving KV pager, the rack
simulator's pool traffic, and the BFS graph workload
(`prefetch/workloads.py`).

An `AccessTrace` is the common currency of the subsystem: per engine step,
the ordered list of (global) page ids demanded, plus the optional
application-directed hint stream (`hints[i]` = pages the app forecasts
for step i+1 — only the BFS workload fills it). The static layer stream
of `prefetch/static.py` emits the same shape, so one `PrefetchEngine`
scores every source against one predictor protocol.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence


@dataclasses.dataclass
class AccessTrace:
    """A page-touch stream: `steps[i]` is the demand-ordered page ids
    touched at step i over an `n_pages` address space of `page_bytes`
    pages. `hints[i]`, when present, is the application's forecast of
    step i+1's touches (consumed by the `frontier` predictor)."""

    name: str
    source: str                      # serving | sched | bfs | layer
    page_bytes: float
    n_pages: int
    steps: List[List[int]]
    hints: Optional[List[List[int]]] = None

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    @property
    def touches(self) -> int:
        return sum(len(s) for s in self.steps)

    def validate(self) -> "AccessTrace":
        for s in self.steps:
            for p in s:
                if not 0 <= p < self.n_pages:
                    raise ValueError(f"page {p} outside [0, {self.n_pages})")
        if self.hints is not None and len(self.hints) != len(self.steps):
            raise ValueError("hints must be per-step (same length as steps)")
        return self


class TraceRecorder:
    """Capture hook: `KVPager` (and anything else) calls `record(pages)`
    once per step; `to_trace` freezes the stream."""

    def __init__(self):
        self.steps: List[List[int]] = []

    def record(self, pages: Sequence[int]) -> None:
        self.steps.append([int(p) for p in pages])

    def to_trace(self, name: str, source: str, page_bytes: float,
                 n_pages: int) -> AccessTrace:
        return AccessTrace(name, source, page_bytes, n_pages,
                           [list(s) for s in self.steps]).validate()


# ------------------------------------------------- serving (KV pager)
def kv_pager_trace(n_slots: int = 2, max_seq: int = 256,
                   page_tokens: int = 8, hot_window: int = 32,
                   cold_touch: float = 0.1, prompt_len: int = 192,
                   steps: int = 96, bytes_per_token: float = 256.0,
                   budget_frac: float = 0.4) -> AccessTrace:
    """Record the page-touch stream of a long-context decode under the
    tier-aware KV pager (pure numpy — the pager is a logical manager).
    Global page ids are slot-major (`slot * n_pages + page`), so the
    stream interleaves one hot-tail run plus one cold round-robin per
    active slot — the serving shape a stream predictor must untangle."""
    import numpy as np

    from repro.serving.kv_pager import KVPager, PagerConfig

    page_bytes = bytes_per_token * page_tokens
    n_pages = -(-max_seq // page_tokens)
    budget = budget_frac * n_slots * n_pages * page_bytes
    pager = KVPager(
        n_slots, max_seq, bytes_per_token, 0.0,
        PagerConfig(page_tokens=page_tokens, local_budget_bytes=budget,
                    policy="hotness", hot_window=hot_window,
                    cold_touch=cold_touch),
    )
    rec = TraceRecorder()
    pager.recorder = rec
    for s in range(n_slots):
        pager.admit(s, prompt_len)
    active = np.ones(n_slots, dtype=bool)
    for _ in range(steps):
        pager.step(active)
    return rec.to_trace(
        f"kv_pager_s{n_slots}x{max_seq}", "serving", page_bytes,
        n_slots * n_pages,
    )


# --------------------------------------------- sched (pool traffic)
def sched_pool_trace(n_jobs: int = 4, steps: int = 200,
                     pages_per_job: int = 512, page_bytes: float = 4096.0,
                     seed: int = 0) -> AccessTrace:
    """Pool-link traffic of co-resident simulator jobs as a page stream:
    each job streams sequentially through its own pool-resident region at
    a rate proportional to its injected LoI (`sched.workload` synthetic
    profiles), wrapping at the region end. The interleaving of per-job
    sequential scans is the multi-tenant pattern the stream predictor's
    region table exists for."""
    import numpy as np

    from repro.sched.workload import synthetic_stream

    jobs = synthetic_stream(n_jobs, seed=seed)
    rates = [max(1, int(round(1 + 4 * j.injected_loi))) for j in jobs]
    cursors = [0] * n_jobs
    out: List[List[int]] = []
    rng = np.random.default_rng(seed + 1)
    for _ in range(steps):
        step: List[int] = []
        order = rng.permutation(n_jobs)            # arrival interleaving
        for j in order:
            base = j * pages_per_job
            for _ in range(rates[j]):
                step.append(base + cursors[j])
                cursors[j] = (cursors[j] + 1) % pages_per_job
        out.append(step)
    return AccessTrace(
        f"sched_pool_{n_jobs}j", "sched", page_bytes,
        n_jobs * pages_per_job, out,
    ).validate()
