"""The statically-schedulable end of the prefetch spectrum (formerly
`runtime/prefetch.py`, now the `static` corner of the predictor zoo).

`scan_with_prefetch` runs a lax.scan over stacked layer params where the
pool-resident leaves are streamed host->device one layer AHEAD of use
(double buffer in the scan carry): XLA emits async copy-start/copy-done
pairs whose transfer overlaps the previous layer's compute, exactly like a
HW prefetcher hides CXL latency. Accuracy is structurally 100% (the layer
schedule is static); coverage is min(1, t_layer_compute / t_layer_transfer).

`layer_stream_trace` emits the same schedule as an `AccessTrace`, so the
`StaticSchedulePredictor` scores 1.0 accuracy/coverage through the SAME
`PrefetchEngine` that scores the dynamic predictors — the subsystem's
three-level mapping (trace -> predictor -> engine) covers the static case
as its trivially-predictable corner instead of special-casing it.

On backends without internal memory-kind transfers (XLA:CPU — see
runtime/capability.py) the transfer is an identity and the scan reduces to
a plain lax.scan, so the same code path runs everywhere.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.prefetch.trace import AccessTrace
from repro.runtime import capability


def to_device(x):
    if capability.supports_internal_transfer():
        return jax.device_put(x, jax.memory.TransferToMemoryKind("device"))
    return x


def scan_with_prefetch(
    body: Callable,
    carry,
    stacked_params,
    pool_mask,
    n_layers: int,
):
    """lax.scan over layers with layer-ahead prefetch of pooled leaves.

    body(carry, layer_params) -> (carry, out)
    pool_mask: pytree of bools matching stacked_params — True leaves are
    pool-resident and get the double-buffer treatment.
    """

    def slice_layer(i):
        return jax.tree.map(
            lambda p: jax.lax.dynamic_index_in_dim(p, i, 0, keepdims=False),
            stacked_params,
        )

    def fetch(layer, i):
        # transfer pooled leaves of layer i+? to device
        return jax.tree.map(
            lambda leaf, pooled: to_device(leaf) if pooled else leaf,
            layer, pool_mask,
        )

    first = fetch(slice_layer(0), 0)

    def step(state, i):
        carry, buf = state
        # kick off the NEXT layer's transfer before computing this one —
        # XLA schedules the copy concurrently with body()'s compute
        nxt = jnp.minimum(i + 1, n_layers - 1)
        next_buf = fetch(slice_layer(nxt), nxt)
        carry, out = body(carry, buf)
        return (carry, next_buf), out

    (carry, _), outs = jax.lax.scan(
        step, (carry, first), jnp.arange(n_layers)
    )
    return carry, outs


def layer_stream_trace(n_layers: int = 24, pages_per_layer: int = 8,
                       epochs: int = 4,
                       page_bytes: float = 1 << 20) -> AccessTrace:
    """The lax.scan layer stream as an `AccessTrace`: step i touches all
    pages of layer (i mod n_layers). Fully schedulable — the `static`
    predictor's home turf, and the structural-accuracy-1 lane of
    `benchmarks/bench_prefetch.py`."""
    steps = [
        [(i % n_layers) * pages_per_layer + p
         for p in range(pages_per_layer)]
        for i in range(n_layers * epochs)
    ]
    return AccessTrace(
        f"layer_stream_L{n_layers}", "layer", page_bytes,
        n_layers * pages_per_layer, steps,
    ).validate()
