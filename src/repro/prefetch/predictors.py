"""The predictor zoo — one protocol, seven predictors (paper §4.2, Fig 7/8).

Every predictor sees the same thing a hardware or runtime prefetcher sees:
the demand page-touch stream, one page id at a time (`observe`), plus an
optional per-step application hint (`start_step`). `predict(degree)`
returns the pages to fetch ahead, best-first; the shared `PrefetchEngine`
charges the issued transfers against the pool-link budget and scores the
outcome with the paper's metrics (accuracy / coverage / timeliness /
excess traffic).

The zoo spans the paper's taxonomy:

  next_line — fetch the next `degree` sequential pages after the last
              touch (the L2 adjacent-line prefetcher).
  stride    — confirm a constant stride over the last touches, then run
              it ahead (the classic IP-stride HW prefetcher).
  stream    — a table of concurrent region streams (direction + last
              page per region), round-robin ahead of each confirmed
              stream (the LLC streamer; survives interleaved slots/jobs).
  markov    — first-order page-transition history, walk the most
              frequent successors (correlation prefetcher).
  ghb       — Global History Buffer, delta-correlation: SECOND-order
              history keyed on the last two touch DELTAS, so it learns
              repeating delta patterns (+1,+3,+1,+3 ...) that defeat
              both the single-stride confirmer and first-order page
              correlation (Nesbit & Smith's GHB/DC organization).
  static    — the full access SCHEDULE is known (the subsumed
              `runtime/prefetch.py` layer stream: accuracy is
              structurally 1); predicts exactly the next step's pages.
  frontier  — application-directed: the workload hands the next
              frontier's pages via `start_step` (the paper's BFS §7.1
              fix — software knows the future that hardware cannot).

`demand` (the null predictor) is the no-prefetch baseline every report is
normalized against.
"""

from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence


class Predictor:
    """Base: a demand-paging null predictor (never prefetches)."""

    name = "demand"

    def start_step(self, hint: Optional[Sequence[int]] = None) -> None:
        """Called once per engine step, before that step's touches.
        `hint` is the application-directed forecast of upcoming touches
        (only the `frontier` predictor uses it)."""

    def observe(self, page: int) -> None:
        """One demand touch of `page` (in demand order)."""

    def predict(self, degree: int) -> List[int]:
        """Up to `degree` pages to fetch ahead, best-first."""
        return []


class NextLinePredictor(Predictor):
    name = "next_line"

    def __init__(self):
        self.last: Optional[int] = None

    def observe(self, page: int) -> None:
        self.last = page

    def predict(self, degree: int) -> List[int]:
        if self.last is None:
            return []
        return [self.last + i for i in range(1, degree + 1)]


class StridePredictor(Predictor):
    """Confirm a constant stride twice before running ahead."""

    name = "stride"

    def __init__(self):
        self.last: Optional[int] = None
        self.stride = 0
        self.confidence = 0

    def observe(self, page: int) -> None:
        if self.last is not None:
            s = page - self.last
            if s != 0:
                if s == self.stride:
                    self.confidence = min(self.confidence + 1, 4)
                else:
                    self.stride = s
                    self.confidence = 1
        self.last = page

    def predict(self, degree: int) -> List[int]:
        if self.last is None or self.confidence < 2 or self.stride == 0:
            return []
        return [self.last + self.stride * i for i in range(1, degree + 1)]


class StreamPredictor(Predictor):
    """Per-region stream table: tolerates interleaved sequential streams
    (multiple serving slots / co-resident jobs sharing one trace)."""

    name = "stream"

    def __init__(self, region_pages: int = 256, max_streams: int = 16):
        self.region_pages = region_pages
        self.max_streams = max_streams
        # region -> [last_page, stride, confidence]; insertion order = LRU
        self.table: Dict[int, list] = collections.OrderedDict()

    def observe(self, page: int) -> None:
        region = page // self.region_pages
        ent = self.table.pop(region, None)
        if ent is None:
            ent = [page, 0, 0]
        else:
            s = page - ent[0]
            if s != 0:
                if s == ent[1]:
                    ent[2] = min(ent[2] + 1, 4)
                else:
                    ent[1], ent[2] = s, 1
            ent[0] = page
        self.table[region] = ent
        while len(self.table) > self.max_streams:
            self.table.popitem(last=False)

    def predict(self, degree: int) -> List[int]:
        live = [e for e in reversed(self.table.values()) if e[2] >= 2]
        out: List[int] = []
        depth = 1
        while live and len(out) < degree:
            for last, stride, _ in live:          # round-robin the streams
                out.append(last + stride * depth)
                if len(out) >= degree:
                    break
            depth += 1
        return out


class MarkovPredictor(Predictor):
    """First-order page-transition table; prediction walks the chain of
    most-frequent successors from the current page."""

    name = "markov"

    def __init__(self, max_pages: int = 1 << 16):
        self.table: Dict[int, collections.Counter] = {}
        self.last: Optional[int] = None
        self.max_pages = max_pages

    def observe(self, page: int) -> None:
        if self.last is not None and len(self.table) < self.max_pages:
            self.table.setdefault(self.last, collections.Counter())[page] += 1
        self.last = page

    def predict(self, degree: int) -> List[int]:
        out: List[int] = []
        seen = set()
        cur = self.last
        while cur is not None and len(out) < degree:
            succ = self.table.get(cur)
            if not succ:
                break
            ranked = [p for p, _ in succ.most_common(degree)
                      if p not in seen]
            if not ranked:
                break
            for p in ranked[: degree - len(out)]:
                out.append(p)
                seen.add(p)
            cur = ranked[0]                        # walk the likeliest chain
        return out


class GHBPredictor(Predictor):
    """Global History Buffer, delta-correlation (second-order history).

    The index is the pair of the last two non-zero touch deltas; the
    table records which delta followed that pair. Prediction replays the
    likeliest delta chain from the current context — so a repeating
    delta pattern of any period <= 2 (strides, alternating strides,
    interleaved +a/+b walks) is learned exactly, where `stride` needs a
    single confirmed constant and `markov` must see every absolute page
    twice. Pages are unbounded; the table is capacity-capped like a
    hardware GHB."""

    name = "ghb"

    def __init__(self, max_entries: int = 1 << 12):
        self.last: Optional[int] = None
        self.key = (None, None)            # last two deltas
        self.table: Dict[tuple, collections.Counter] = {}
        self.max_entries = max_entries

    def observe(self, page: int) -> None:
        if self.last is None:
            self.last = page
            return
        d = page - self.last
        self.last = page
        if d == 0:
            return
        a, b = self.key
        if a is not None and (
                self.key in self.table or len(self.table) < self.max_entries):
            self.table.setdefault(self.key, collections.Counter())[d] += 1
        self.key = (b, d)

    def predict(self, degree: int) -> List[int]:
        a, _ = self.key
        if self.last is None or a is None:
            return []
        out: List[int] = []
        key, page = self.key, self.last
        for _ in range(degree):
            succ = self.table.get(key)
            if not succ:
                break
            d = succ.most_common(1)[0][0]
            page = page + d
            out.append(page)
            key = (key[1], d)
        return out


class StaticSchedulePredictor(Predictor):
    """The access schedule is fully known ahead of time — the subsumed
    `runtime/prefetch.py` case (a lax.scan over stacked layers has a
    static layer stream), generalized to any recorded schedule. Accuracy
    is structurally 1: everything predicted IS the next step's touch set.
    """

    name = "static"

    def __init__(self, schedule: Sequence[Sequence[int]]):
        self.schedule = [list(s) for s in schedule]
        self.step = -1

    def start_step(self, hint: Optional[Sequence[int]] = None) -> None:
        self.step += 1

    def predict(self, degree: int) -> List[int]:
        nxt = self.step + 1
        if nxt >= len(self.schedule):
            return []
        return list(self.schedule[nxt])[:degree]


class FrontierPredictor(Predictor):
    """Application-directed (paper §7.1 BFS case study): the workload
    computes its next frontier and hands the adjacency pages via
    `start_step(hint)`; prediction is exactly that hint."""

    name = "frontier"

    def __init__(self):
        self.hint: List[int] = []

    def start_step(self, hint: Optional[Sequence[int]] = None) -> None:
        self.hint = list(hint) if hint else []

    def predict(self, degree: int) -> List[int]:
        return self.hint[:degree]


_ZOO = {
    "demand": Predictor,
    "next_line": NextLinePredictor,
    "stride": StridePredictor,
    "stream": StreamPredictor,
    "markov": MarkovPredictor,
    "ghb": GHBPredictor,
    "frontier": FrontierPredictor,
}


def make_predictor(name: str, **kwargs) -> Predictor:
    """Factory over the zoo. `static` needs the schedule:
    `make_predictor("static", schedule=trace.steps)`."""
    if name == "static":
        return StaticSchedulePredictor(kwargs.pop("schedule"))
    if name == "adaptive":
        # lazy: AdaptiveSwitcher lives with the engine (it composes zoo
        # members), and engine.py imports this module at the top
        from repro.prefetch.engine import AdaptiveSwitcher
        return AdaptiveSwitcher(**kwargs)
    try:
        cls = _ZOO[name]
    except KeyError:
        raise ValueError(
            f"unknown predictor {name!r} "
            f"(know {sorted(_ZOO)} + 'static' + 'adaptive')"
        ) from None
    return cls(**kwargs)


def zoo_names(include_static: bool = True) -> List[str]:
    names = [n for n in _ZOO if n != "demand"]
    return names + ["static"] if include_static else names
