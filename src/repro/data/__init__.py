from repro.data.synthetic import SyntheticLM, make_batch_for  # noqa
from repro.data.pipeline import PrefetchPipeline  # noqa
