"""Deterministic synthetic LM data.

Determinism is keyed on (seed, step) so a restarted job replays the exact
same stream from its restored step — the data side of checkpoint/restart
fault tolerance. The token stream is a mixture of a Markov chain and repeated
n-grams so models achieve non-trivial loss reduction (pure uniform noise
cannot be learned and makes convergence tests vacuous).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models.frontends import synthetic_frontend_embeds


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        """Batch for a given step: tokens (B, S+1)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        B, S = self.global_batch, self.seq_len + 1
        # base markov-ish stream: next = (prev * a + noise) % V
        start = jax.random.randint(k1, (B, 1), 0, self.vocab_size)
        noise = jax.random.randint(k2, (B, S), 0, 7)

        def step_fn(prev, n):
            nxt = (prev * 31 + n + 1) % self.vocab_size
            return nxt, nxt

        _, toks = jax.lax.scan(
            step_fn, start[:, 0], noise.T
        )
        toks = toks.T
        # splice in a repeated n-gram at a random offset (learnable structure)
        gram = jax.random.randint(k3, (B, 8), 0, self.vocab_size)
        toks = jax.lax.dynamic_update_slice(toks, gram, (0, 4))
        toks = jax.lax.dynamic_update_slice(toks, gram, (0, 16))
        return {"tokens": toks.astype(jnp.int32)}


def make_batch_for(cfg: ModelConfig, seq_len: int, global_batch: int,
                   step: int = 0, seed: int = 0) -> dict:
    """Full input batch for an arch (adds stub frontend embeddings)."""
    ds = SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed)
    batch = ds.batch_at(step)
    if cfg.frontend == "vision_stub":
        batch["patches"] = synthetic_frontend_embeds(
            cfg, global_batch, seq_len, jax.random.fold_in(
                jax.random.PRNGKey(seed + 1), step)
        )
    if cfg.frontend == "audio_stub":
        batch["frames"] = synthetic_frontend_embeds(
            cfg, global_batch, seq_len, jax.random.fold_in(
                jax.random.PRNGKey(seed + 2), step)
        )
    return batch
