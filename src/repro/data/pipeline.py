"""Host-side prefetching data pipeline.

The paper's §4.2 finding — prefetching is *necessary* for HPC workloads on
tiered memory — shows up twice in this framework: (a) layer-ahead prefetch of
pool-tier params (prefetch/static.py) and (b) this input pipeline, which
keeps `depth` batches in flight on a background thread so host->device
transfer overlaps the previous step's compute.

Also the straggler-mitigation hook: `skip_to(step)` lets a restarted /
rejoining worker jump the stream forward without replaying work.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Optional


class PrefetchPipeline:
    def __init__(self, batch_fn: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2, transfer: Optional[Callable] = None):
        self._batch_fn = batch_fn
        self._transfer = transfer or (lambda x: x)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        while not self._stop.is_set():
            with self._lock:
                step = self._next
                self._next += 1
            try:
                item = (step, self._transfer(self._batch_fn(step)))
            except Exception as e:  # surface in consumer
                item = (step, e)
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self) -> tuple[int, dict]:
        step, item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return step, item

    def skip_to(self, step: int):
        """Fast-forward (drain queue + reset producer) — straggler catch-up."""
        with self._lock:
            self._next = step
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
