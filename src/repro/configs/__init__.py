"""Architecture config registry.

Every assigned architecture is a module exposing `CONFIG` (the full,
paper-exact config) and `reduced()` (a tiny same-family config for CPU smoke
tests). `get(name)` / `list_archs()` are the public API; `shapes_for(name)`
returns the shape cells that are *runnable* for that arch (sub-quadratic
gating for long_500k per DESIGN.md §4).
"""

from __future__ import annotations

import importlib

from repro.common.config import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = [
    "granite_moe_1b_a400m",
    "kimi_k2_1t_a32b",
    "mamba2_780m",
    "jamba_1_5_large_398b",
    "mistral_nemo_12b",
    "qwen2_5_32b",
    "smollm_360m",
    "granite_3_2b",
    "seamless_m4t_large_v2",
    "paligemma_3b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def canonical(name: str) -> str:
    name = name.replace(".", "_")
    return _ALIASES.get(name, name.replace("-", "_"))


def _module(name: str):
    return importlib.import_module(f"repro.configs.{canonical(name)}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def reduced(name: str) -> ModelConfig:
    return _module(name).reduced()


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def shapes_for(name: str) -> list[ShapeConfig]:
    cfg = get(name)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # pure full-attention arch: documented skip
        out.append(s)
    return out


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) pair — the dry-run matrix."""
    return [(a, s.name) for a in ARCH_IDS for s in shapes_for(a)]
