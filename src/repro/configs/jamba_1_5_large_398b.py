"""jamba-1.5-large-398b — 72L d=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
MoE 16 experts top-2, Mamba+attention 1:7 interleave.  [arXiv:2403.19887; hf]

Layer pattern: attention on every 8th layer (1:7 attn:mamba), MoE on every
2nd layer (Jamba places MoE at period 2); remaining MLPs are dense.
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    moe_layer_period=2,
    attn_layer_period=8,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    source="arXiv:2403.19887",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-reduced",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=128,
        num_experts=4,
        experts_per_token=2,
        moe_d_ff=128,
        moe_layer_period=2,
        attn_layer_period=2,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        conv_width=4,
    )
