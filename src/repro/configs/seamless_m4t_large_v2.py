"""seamless-m4t-large-v2 — enc-dec 24L(+24L enc) d=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — multimodal (audio).  [arXiv:2308.11596; hf]

The speech frontend is a STUB per the brief: `input_specs()` provides
precomputed frame embeddings of shape (batch, frames, d_model) which feed the
text/unit encoder; the decoder cross-attends to encoder output.
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    act="gelu",
    frontend="audio_stub",
    num_prefix_tokens=0,  # encoder consumes the frames; no decoder prefix
    source="arXiv:2308.11596",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-reduced",
        family="audio",
        num_layers=2,
        num_encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        act="gelu",
        frontend="audio_stub",
    )
