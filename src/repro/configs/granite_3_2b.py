"""granite-3-2b — 40L d=2048 32H (GQA kv=8) d_ff=8192 vocab=49155 — GQA.
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-3-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        tie_embeddings=True,
    )
