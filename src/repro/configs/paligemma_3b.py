"""paligemma-3b — 18L d=2048 8H (GQA kv=1) d_ff=16384 vocab=257216 — SigLIP +
gemma.  [arXiv:2407.07726; hf]

The SigLIP vision tower is a STUB per the brief: `input_specs()` provides 256
precomputed patch embeddings (already projected to d_model) prepended to the
text tokens; the gemma decoder is built in full.
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    act="geglu",
    tie_embeddings=True,
    frontend="vision_stub",
    num_prefix_tokens=256,
    source="arXiv:2407.07726",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="paligemma-reduced",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        act="geglu",
        tie_embeddings=True,
        frontend="vision_stub",
        num_prefix_tokens=8,
    )
