"""smollm-360m — 32L d=960 15H (GQA kv=5) d_ff=2560 vocab=49152 — llama-arch
small.  [hf:HuggingFaceTB/SmolLM-135M; hf]

Control arch for the tiering technique: the whole training state fits HBM,
so a correct placement policy must choose all-HBM (pool fraction -> 0).
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-reduced",
        family="dense",
        num_layers=2,
        d_model=60,
        num_heads=3,
        num_kv_heads=1,
        head_dim=20,
        d_ff=128,
        vocab_size=128,
        tie_embeddings=True,
    )
