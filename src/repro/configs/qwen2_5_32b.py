"""qwen2.5-32b — 64L d=5120 40H (GQA kv=8) d_ff=27648 vocab=152064 — GQA,
QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        qkv_bias=True,
    )
