"""mamba2-780m — 48L d=1536, attention-free SSM, ssm_state=128, vocab=50280.
SSD (state-space duality).  [arXiv:2405.21060; unverified]
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-reduced",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        head_dim=1,
        d_ff=0,
        vocab_size=128,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        conv_width=4,
        tie_embeddings=True,
    )
