"""granite-moe-1b-a400m — 24L d=1024 16H (GQA kv=8) expert-ff=512 vocab=49155,
MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=0,
    moe_d_ff=512,
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    moe_layer_period=1,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-reduced",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=0,
        moe_d_ff=32,
        vocab_size=128,
        num_experts=4,
        experts_per_token=2,
        moe_layer_period=1,
        tie_embeddings=True,
    )
