"""kimi-k2-1t-a32b — 61L d=7168 64H (GQA kv=8) expert-ff=2048 vocab=163840,
MoE 384 experts top-8 — trillion-param MoE.  [arXiv:2501.kimi2; unverified]

This is the showcase arch for the paper's technique: total params (1.03T)
vs active params (~32B) is exactly the skewed bandwidth-capacity curve of
paper Fig 6 (BFS/XSBench): a small fraction of the footprint receives nearly
all accesses, so the cold expert majority is pool-tier eligible.
"""

from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=0,
    moe_d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    moe_layer_period=1,
    source="arXiv:2501.kimi2",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-reduced",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        head_dim=16,
        d_ff=0,
        moe_d_ff=64,
        vocab_size=256,
        num_experts=8,
        experts_per_token=2,
        moe_layer_period=1,
    )
