"""Checkpoint/restart with async save and elastic (re-shard) restore.

Design (1000+-node posture):
  * Each save writes one npz per flattened leaf group + a JSON manifest with
    step, tree structure, shapes, dtypes and a content checksum — a torn or
    partial write is detected at restore and the previous step is used.
  * Saves run on a background thread off the step's critical path; the train
    loop only blocks if a previous save is still in flight (double-buffer).
  * Restore is *elastic*: arrays are saved unsharded (gathered per leaf), so
    a checkpoint written on one mesh restores onto any other mesh/sharding —
    the restore path re-shards with device_put per the new sharding tree.
  * `keep` rotation bounds disk usage; `latest_step()` drives restart logic.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.common.pytree import named_leaves


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------ save
    def save(self, step: int, state, blocking: bool = False):
        """Snapshot to host then write asynchronously."""
        self.wait()  # at most one save in flight
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self._pending = threading.Thread(
            target=self._write, args=(step, host_state), daemon=True
        )
        self._pending.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_state):
        tmp = os.path.join(self.directory, f".tmp_step_{step}_{time.time_ns()}")
        os.makedirs(tmp, exist_ok=True)
        leaves = named_leaves(host_state)
        manifest = {"step": step, "leaves": []}
        arrays = {}
        for i, (name, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            key = f"a{i}"
            arrays[key] = arr
            manifest["leaves"].append(
                {
                    "name": name,
                    "key": key,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
                }
            )
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(self.directory, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._rotate()

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                if os.path.exists(
                    os.path.join(self.directory, d, "manifest.json")
                ):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target, shardings=None,
                verify: bool = True):
        """Restore into the structure of `target` (values or SDS tree).

        `shardings`: optional matching tree of NamedSharding for elastic
        re-shard onto the current mesh.
        """
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        by_name = {}
        for entry in manifest["leaves"]:
            arr = data[entry["key"]]
            if verify:
                sha = hashlib.sha1(arr.tobytes()).hexdigest()
                if sha != entry["sha1"]:
                    raise IOError(
                        f"checksum mismatch in {entry['name']} at step {step}"
                    )
            by_name[entry["name"]] = arr

    # build result tree in target structure
        names = [n for n, _ in named_leaves(target)]
        missing = [n for n in names if n not in by_name]
        if missing:
            raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
        flat_target, treedef = jax.tree_util.tree_flatten(target)
        arrays = [by_name[n] for n in names]
        if shardings is not None:
            flat_sh = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: x is None or hasattr(x, "spec")
            )
            out = [
                jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                for a, s in zip(arrays, flat_sh)
            ]
        else:
            out = [jax.numpy.asarray(a) for a in arrays]
        return jax.tree_util.tree_unflatten(treedef, out)
