"""Request queue and arrival-process generators for the serving engine.

A `Request` is one user call: a prompt (already tokenized; its length must
be one of the engine's prefill buckets — serving systems quantize prompt
lengths so the fixed-shape prefill cells never recompile) plus a decode
budget, a PRIORITY CLASS (0 = most urgent; ties broken by arrival, so a
single-class trace is plain FIFO — bit-identical to the pre-priority
queue) and an optional tenant tag. A request can be CANCELLED: either
eagerly (`cancel()`) or at a virtual-time deadline (`cancel_at`, which
makes cancellation deterministic in replayed traces). The queue drops
cancelled requests at pop time; the engine sweeps cancelled in-flight
requests out of their slots, releasing their KV pages back through the
pager (`ServingEngine.sweep_cancelled`).

`RequestQueue` orders by (priority, arrival): `pop(now)` only releases
arrived requests, and among the arrived set the lowest priority class
goes first, FIFO within a class — so open-loop traces replay
deterministically.

Scenario generators mirror the benchmark matrix of the brief:

* `chat_stream`      — short prompts, short generations, steady Poisson
                       arrivals (the latency-sensitive interactive lane);
* `long_context_stream` — few requests, long prompts (the 32k-class lane
                       whose KV cache spills the local tier — the cell the
                       tier-aware pager exists for);
* `bursty_stream`    — mixed prompt lengths arriving in bursts separated
                       by idle gaps (slot churn + admission stress);
* `shared_prefix_stream` — chat traffic behind fixed system prompts
                       (the prefix-cache dedup lane: every request opens
                       with one of `n_systems` shared prefixes);
* `multi_tenant_stream` — an interactive tenant (short prompts, priority
                       0, steady Poisson) interleaved with a batch tenant
                       (long prompts, priority 1, bursty) — the fleet
                       router's priority-class stress lane.

All generators are deterministic in `seed`.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request plus its per-request accounting."""

    request_id: int
    tokens: np.ndarray            # (prompt_len,) int32 prompt
    max_new_tokens: int
    arrival: float = 0.0          # seconds since trace start
    priority: int = 0             # class: 0 most urgent; FIFO within class
    tenant: str = "default"       # multi-tenant stream tag (accounting)
    cancel_at: Optional[float] = None   # virtual-time cancellation
    # deadline — deterministic in replayed traces (None = never)
    cancelled: bool = False       # eager cancellation flag (router.cancel)
    # --- filled in by the engine ---
    admitted: float = float("nan")
    finished: float = float("nan")
    output: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens

    def cancel(self) -> None:
        self.cancelled = True

    def is_cancelled(self, now: float) -> bool:
        return self.cancelled or (
            self.cancel_at is not None and now >= self.cancel_at
        )


class RequestQueue:
    """Priority queue over (priority class, arrival). `pop(now)` only
    releases arrived requests; among the arrived set the lowest priority
    class pops first, FIFO (arrival-stable) within a class — with a
    single class this is exactly the old FIFO. Cancelled requests are
    dropped at peek/pop (never handed to the engine); `drop_cancelled`
    counts them."""

    def __init__(self, requests: Sequence[Request] = ()):
        # arrival-sorted feed list (stable for ties) + a ready-heap of
        # arrived requests keyed (priority, ORIGINAL arrival, absorb
        # order). Keying on the original arrival — not absorb time —
        # makes REQUEUED requests (fault recovery re-routing a dead
        # engine's queue) re-admit in the same deterministic
        # (priority, original_arrival) order the fault-free run used;
        # the absorb-order seq only breaks exact (priority, arrival)
        # ties, so a single-class trace stays plain FIFO.
        self._items: List[Request] = sorted(requests, key=lambda r: r.arrival)
        self._head = 0
        self._ready: List[tuple] = []
        self._seq = 0
        self.drop_cancelled = 0

    def push(self, req: Request) -> None:
        # insert into the *unconsumed* suffix only — re-sorting the whole
        # list would shuffle already-popped items back past _head
        pos = bisect.bisect(
            [r.arrival for r in self._items[self._head:]], req.arrival
        )
        self._items.insert(self._head + pos, req)

    def __len__(self) -> int:
        return len(self._items) - self._head + len(self._ready)

    def _absorb(self, now: float) -> None:
        """Move arrived feed items into the ready heap (dropping the
        already-cancelled) and purge cancelled heap entries."""
        while (self._head < len(self._items)
               and self._items[self._head].arrival <= now):
            r = self._items[self._head]
            self._head += 1
            if r.is_cancelled(now):
                self.drop_cancelled += 1
                continue
            heapq.heappush(self._ready,
                           (r.priority, r.arrival, self._seq, r))
            self._seq += 1
        while self._ready and self._ready[0][-1].is_cancelled(now):
            heapq.heappop(self._ready)
            self.drop_cancelled += 1

    def peek(self, now: float) -> Optional[Request]:
        self._absorb(now)
        return self._ready[0][-1] if self._ready else None

    def pop(self, now: float) -> Optional[Request]:
        r = self.peek(now)
        if r is not None:
            heapq.heappop(self._ready)
        return r

    def drain(self) -> List[Request]:
        """Remove and return EVERY remaining request — ready ones in
        (priority, original arrival) order, then the not-yet-arrived
        feed in arrival order. The fault-recovery path: a dead engine's
        queue drains back through the fleet placement policies, and the
        original-arrival heap key on the destination makes re-admission
        order-stable."""
        out = [item[-1] for item in sorted(self._ready)]
        out += self._items[self._head:]
        self._items, self._head, self._ready = [], 0, []
        return out

    def next_arrival(self) -> float:
        """Earliest event time among queued requests: ready requests have
        already arrived (their arrival), otherwise the feed head's arrival
        (inf when drained)."""
        if self._ready:
            return min(item[-1].arrival for item in self._ready)
        if self._head < len(self._items):
            return self._items[self._head].arrival
        return float("inf")


# ------------------------------------------------------------- scenarios
def _mk_requests(rng, vocab: int, prompt_lens, gens, arrivals) -> list:
    out = []
    for i, (pl, g, at) in enumerate(zip(prompt_lens, gens, arrivals)):
        toks = rng.integers(0, vocab, size=int(pl)).astype(np.int32)
        out.append(Request(
            request_id=i, tokens=toks, max_new_tokens=int(g),
            arrival=float(at),
        ))
    return out


def chat_stream(n: int, vocab: int, *, seed: int = 0,
                prompt_buckets: Sequence[int] = (16, 32),
                gen_range: tuple = (8, 24),
                arrival_rate: float = 2.0) -> List[Request]:
    """Short-prompt interactive chat: Poisson arrivals, bucketed prompts."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    lens = rng.choice(list(prompt_buckets), size=n)
    gens = rng.integers(gen_range[0], gen_range[1] + 1, size=n)
    return _mk_requests(rng, vocab, lens, gens, arrivals)


def long_context_stream(n: int, vocab: int, *, seed: int = 0,
                        prompt_bucket: int = 256,
                        gen_range: tuple = (16, 48),
                        arrival_rate: float = 0.5) -> List[Request]:
    """Long-context lane: every prompt at the largest bucket, so per-slot
    KV exceeds the local-tier budget and the pager must evict."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    lens = np.full(n, prompt_bucket)
    gens = rng.integers(gen_range[0], gen_range[1] + 1, size=n)
    return _mk_requests(rng, vocab, lens, gens, arrivals)


def bursty_stream(n: int, vocab: int, *, seed: int = 0,
                  prompt_buckets: Sequence[int] = (16, 32, 64),
                  gen_range: tuple = (8, 32),
                  burst_size: int = 6,
                  burst_gap: float = 4.0) -> List[Request]:
    """Mixed bursty arrivals: `burst_size` requests land together, then the
    line goes quiet for ~`burst_gap` seconds (slot churn + admission
    throttle stress)."""
    rng = np.random.default_rng(seed)
    arrivals = []
    t = 0.0
    jitter = 0.01 * burst_gap     # in-burst spread << the idle gap
    while len(arrivals) < n:
        k = min(burst_size, n - len(arrivals))
        arrivals.extend([t + float(rng.uniform(0, jitter))
                         for _ in range(k)])
        t += float(rng.exponential(burst_gap))
    arrivals = np.sort(np.asarray(arrivals))
    lens = rng.choice(list(prompt_buckets), size=n)
    gens = rng.integers(gen_range[0], gen_range[1] + 1, size=n)
    return _mk_requests(rng, vocab, lens, gens, arrivals)


def shared_prefix_stream(n: int, vocab: int, *, seed: int = 0,
                         system_tokens: int = 24,
                         prompt_buckets: Sequence[int] = (32,),
                         gen_range: tuple = (8, 24),
                         arrival_rate: float = 2.0,
                         n_systems: int = 1) -> List[Request]:
    """Chat traffic behind `n_systems` fixed system prompts: every request
    opens with one of the shared `system_tokens`-long prefixes and fills
    the rest of its bucket with a random user tail — the workload the
    prefix radix cache (`serving.prefix_cache`) deduplicates. Same
    Poisson arrival process as `chat_stream`; deterministic in `seed`
    (the system prefixes themselves derive from `seed`, so two streams
    with the same seed share byte-identical prefixes)."""
    if any(b <= system_tokens for b in prompt_buckets):
        raise ValueError(
            f"prompt_buckets {tuple(prompt_buckets)} must exceed "
            f"system_tokens {system_tokens} (requests need a user tail)"
        )
    if n_systems < 1:
        raise ValueError("n_systems must be >= 1")
    rng = np.random.default_rng(seed)
    systems = [rng.integers(0, vocab, size=system_tokens).astype(np.int32)
               for _ in range(n_systems)]
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    lens = rng.choice(list(prompt_buckets), size=n)
    gens = rng.integers(gen_range[0], gen_range[1] + 1, size=n)
    which = rng.integers(0, n_systems, size=n)
    out = []
    for i in range(n):
        tail = rng.integers(
            0, vocab, size=int(lens[i]) - system_tokens
        ).astype(np.int32)
        out.append(Request(
            request_id=i,
            tokens=np.concatenate([systems[int(which[i])], tail]),
            max_new_tokens=int(gens[i]),
            arrival=float(arrivals[i]),
        ))
    return out


def multi_tenant_stream(n: int, vocab: int, *, seed: int = 0,
                        interactive_buckets: Sequence[int] = (16, 32),
                        batch_bucket: int = 64,
                        batch_fraction: float = 0.4,
                        gen_interactive: tuple = (8, 16),
                        gen_batch: tuple = (16, 32),
                        arrival_rate: float = 2.0,
                        batch_burst: int = 4,
                        batch_gap: float = 6.0) -> List[Request]:
    """Two tenants sharing one fleet: an `interactive` tenant (short
    prompts, priority 0, steady Poisson arrivals) and a `batch` tenant
    (long prompts, priority 1, arriving in bursts) — the priority-class
    lane: under contention the queue must serve interactive requests
    ahead of co-arrived batch work. Deterministic in `seed`."""
    if not 0.0 <= batch_fraction <= 1.0:
        raise ValueError("batch_fraction must be in [0, 1]")
    rng = np.random.default_rng(seed)
    n_batch = int(round(n * batch_fraction))
    n_inter = n - n_batch
    inter = _mk_requests(
        rng, vocab,
        rng.choice(list(interactive_buckets), size=n_inter),
        rng.integers(gen_interactive[0], gen_interactive[1] + 1,
                     size=n_inter),
        np.cumsum(rng.exponential(1.0 / arrival_rate, size=n_inter)),
    )
    for r in inter:
        r.tenant = "interactive"
        r.priority = 0
    arrivals, t = [], 0.0
    while len(arrivals) < n_batch:
        k = min(batch_burst, n_batch - len(arrivals))
        arrivals.extend(t + rng.uniform(0, 0.01 * batch_gap, size=k))
        t += float(rng.exponential(batch_gap))
    batch = _mk_requests(
        rng, vocab,
        np.full(n_batch, batch_bucket),
        rng.integers(gen_batch[0], gen_batch[1] + 1, size=n_batch),
        np.sort(np.asarray(arrivals)),
    )
    for i, r in enumerate(batch):
        r.request_id = n_inter + i      # unique across tenants
        r.tenant = "batch"
        r.priority = 1
    return sorted(inter + batch, key=lambda r: (r.arrival, r.request_id))


SCENARIOS = {
    "chat": chat_stream,
    "long_context": long_context_stream,
    "bursty": bursty_stream,
    "shared_prefix": shared_prefix_stream,
    "multi_tenant": multi_tenant_stream,
}


def make_scenario(name: str, n: int, vocab: int, *, seed: int = 0,
                  **kwargs) -> List[Request]:
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; one of "
                         f"{sorted(SCENARIOS)}")
    return SCENARIOS[name](n, vocab, seed=seed, **kwargs)
