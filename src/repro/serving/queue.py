"""Request queue and arrival-process generators for the serving engine.

A `Request` is one user call: a prompt (already tokenized; its length must
be one of the engine's prefill buckets — serving systems quantize prompt
lengths so the fixed-shape prefill cells never recompile) plus a decode
budget. `RequestQueue` is a FIFO ordered by arrival time: the engine only
sees requests whose arrival is <= its clock, so open-loop traces replay
deterministically.

Three scenario generators mirror the benchmark matrix of the brief:

* `chat_stream`      — short prompts, short generations, steady Poisson
                       arrivals (the latency-sensitive interactive lane);
* `long_context_stream` — few requests, long prompts (the 32k-class lane
                       whose KV cache spills the local tier — the cell the
                       tier-aware pager exists for);
* `bursty_stream`    — mixed prompt lengths arriving in bursts separated
                       by idle gaps (slot churn + admission stress);
* `shared_prefix_stream` — chat traffic behind fixed system prompts
                       (the prefix-cache dedup lane: every request opens
                       with one of `n_systems` shared prefixes).

All generators are deterministic in `seed`.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request plus its per-request accounting."""

    request_id: int
    tokens: np.ndarray            # (prompt_len,) int32 prompt
    max_new_tokens: int
    arrival: float = 0.0          # seconds since trace start
    # --- filled in by the engine ---
    admitted: float = float("nan")
    finished: float = float("nan")
    output: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


class RequestQueue:
    """FIFO over arrival time. `pop(now)` only releases arrived requests."""

    def __init__(self, requests: Sequence[Request] = ()):
        self._items: List[Request] = sorted(requests, key=lambda r: r.arrival)
        self._head = 0

    def push(self, req: Request) -> None:
        # insert into the *unconsumed* suffix only — re-sorting the whole
        # list would shuffle already-popped items back past _head
        pos = bisect.bisect(
            [r.arrival for r in self._items[self._head:]], req.arrival
        )
        self._items.insert(self._head + pos, req)

    def __len__(self) -> int:
        return len(self._items) - self._head

    def peek(self, now: float) -> Optional[Request]:
        if self._head < len(self._items):
            r = self._items[self._head]
            if r.arrival <= now:
                return r
        return None

    def pop(self, now: float) -> Optional[Request]:
        r = self.peek(now)
        if r is not None:
            self._head += 1
        return r

    def next_arrival(self) -> float:
        """Arrival time of the next queued request (inf when drained)."""
        if self._head < len(self._items):
            return self._items[self._head].arrival
        return float("inf")


# ------------------------------------------------------------- scenarios
def _mk_requests(rng, vocab: int, prompt_lens, gens, arrivals) -> list:
    out = []
    for i, (pl, g, at) in enumerate(zip(prompt_lens, gens, arrivals)):
        toks = rng.integers(0, vocab, size=int(pl)).astype(np.int32)
        out.append(Request(
            request_id=i, tokens=toks, max_new_tokens=int(g),
            arrival=float(at),
        ))
    return out


def chat_stream(n: int, vocab: int, *, seed: int = 0,
                prompt_buckets: Sequence[int] = (16, 32),
                gen_range: tuple = (8, 24),
                arrival_rate: float = 2.0) -> List[Request]:
    """Short-prompt interactive chat: Poisson arrivals, bucketed prompts."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    lens = rng.choice(list(prompt_buckets), size=n)
    gens = rng.integers(gen_range[0], gen_range[1] + 1, size=n)
    return _mk_requests(rng, vocab, lens, gens, arrivals)


def long_context_stream(n: int, vocab: int, *, seed: int = 0,
                        prompt_bucket: int = 256,
                        gen_range: tuple = (16, 48),
                        arrival_rate: float = 0.5) -> List[Request]:
    """Long-context lane: every prompt at the largest bucket, so per-slot
    KV exceeds the local-tier budget and the pager must evict."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    lens = np.full(n, prompt_bucket)
    gens = rng.integers(gen_range[0], gen_range[1] + 1, size=n)
    return _mk_requests(rng, vocab, lens, gens, arrivals)


def bursty_stream(n: int, vocab: int, *, seed: int = 0,
                  prompt_buckets: Sequence[int] = (16, 32, 64),
                  gen_range: tuple = (8, 32),
                  burst_size: int = 6,
                  burst_gap: float = 4.0) -> List[Request]:
    """Mixed bursty arrivals: `burst_size` requests land together, then the
    line goes quiet for ~`burst_gap` seconds (slot churn + admission
    throttle stress)."""
    rng = np.random.default_rng(seed)
    arrivals = []
    t = 0.0
    jitter = 0.01 * burst_gap     # in-burst spread << the idle gap
    while len(arrivals) < n:
        k = min(burst_size, n - len(arrivals))
        arrivals.extend([t + float(rng.uniform(0, jitter))
                         for _ in range(k)])
        t += float(rng.exponential(burst_gap))
    arrivals = np.sort(np.asarray(arrivals))
    lens = rng.choice(list(prompt_buckets), size=n)
    gens = rng.integers(gen_range[0], gen_range[1] + 1, size=n)
    return _mk_requests(rng, vocab, lens, gens, arrivals)


def shared_prefix_stream(n: int, vocab: int, *, seed: int = 0,
                         system_tokens: int = 24,
                         prompt_buckets: Sequence[int] = (32,),
                         gen_range: tuple = (8, 24),
                         arrival_rate: float = 2.0,
                         n_systems: int = 1) -> List[Request]:
    """Chat traffic behind `n_systems` fixed system prompts: every request
    opens with one of the shared `system_tokens`-long prefixes and fills
    the rest of its bucket with a random user tail — the workload the
    prefix radix cache (`serving.prefix_cache`) deduplicates. Same
    Poisson arrival process as `chat_stream`; deterministic in `seed`
    (the system prefixes themselves derive from `seed`, so two streams
    with the same seed share byte-identical prefixes)."""
    if any(b <= system_tokens for b in prompt_buckets):
        raise ValueError(
            f"prompt_buckets {tuple(prompt_buckets)} must exceed "
            f"system_tokens {system_tokens} (requests need a user tail)"
        )
    if n_systems < 1:
        raise ValueError("n_systems must be >= 1")
    rng = np.random.default_rng(seed)
    systems = [rng.integers(0, vocab, size=system_tokens).astype(np.int32)
               for _ in range(n_systems)]
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    lens = rng.choice(list(prompt_buckets), size=n)
    gens = rng.integers(gen_range[0], gen_range[1] + 1, size=n)
    which = rng.integers(0, n_systems, size=n)
    out = []
    for i in range(n):
        tail = rng.integers(
            0, vocab, size=int(lens[i]) - system_tokens
        ).astype(np.int32)
        out.append(Request(
            request_id=i,
            tokens=np.concatenate([systems[int(which[i])], tail]),
            max_new_tokens=int(gens[i]),
            arrival=float(arrivals[i]),
        ))
    return out


SCENARIOS = {
    "chat": chat_stream,
    "long_context": long_context_stream,
    "bursty": bursty_stream,
    "shared_prefix": shared_prefix_stream,
}


def make_scenario(name: str, n: int, vocab: int, *, seed: int = 0,
                  **kwargs) -> List[Request]:
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; one of "
                         f"{sorted(SCENARIOS)}")
    return SCENARIOS[name](n, vocab, seed=seed, **kwargs)
