"""Speculative-decoding proposers + the greedy acceptance rule.

Greedy decode emits one token per sweep of the slot batch's pool-resident
KV pages — the lowest-arithmetic-intensity loop in the serving stack, and
under the paper's corridor the loop whose bytes-per-token sets the decode
roofline. Speculative decoding amortizes that sweep: a PROPOSER guesses
`k - 1` draft tokens per slot, the verify cell
(`runtime.serve.build_decode_verify_paged`) scores all k candidates in
ONE paged-decode call, and `accept_greedy` keeps the longest candidate
prefix that matches what greedy decode would have produced. The token
stream is BIT-IDENTICAL to plain greedy decode by construction (on fp
pools; int8 pools inherit the same bounded quantization drift either
way) — proposers only change how many tokens each sweep yields, never
which tokens.

Two proposers, matching the two classic regimes:

* `ngram_propose` — SELF-speculative: match the slot's own trailing
  n-gram against its earlier history (prompt + generated tokens) and
  replay what followed the most recent earlier occurrence. Zero extra
  parameters, zero device work, stateless — the proposal is a pure
  function of the request's token history, so a slot can migrate across
  engines (fleet handoff) mid-request and the proposer cannot tell.
  Pays off on repetitive streams (code, templated text, the degenerate
  loops tiny models fall into); costs nothing when it misses.
* the DRAFT proposer (driven by `ServingEngine._propose_draft` over
  `runtime.serve.build_decode_draft`) — a small draft model decodes
  `k - 1` tokens ahead against its own contiguous caches, catch-up
  refed from the committed history so rejected speculation never
  poisons it. The draft weights live on the shared `EngineCells`
  (deterministic `PRNGKey(0)` init), so a fleet of engines shares one
  draft tree the same way it shares the target params.

The acceptance rule is the standard greedy-verification ladder: with
candidates `cand[0..k-1]` (cand[0] = the slot's last emitted token) and
verify outputs `greedy[0..k-1]` (greedy[j] = the model's pick FOR the
position after cand[j]), token cand[j+1] is only kept if it equals
greedy[j] — i.e. if greedy decode WOULD have produced it — and the step
emits `greedy[0..a]` where `a` is the first mismatch (or k-1). At least
one token (greedy[0]) always lands, so a cold proposer degrades to plain
greedy decode plus the (k-1)-row verify overhead, never below it.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def ngram_propose(history: np.ndarray, n_draft: int,
                  max_ngram: int = 4) -> np.ndarray:
    """Propose `n_draft` continuation tokens for `history` by suffix
    n-gram matching: find the LONGEST trailing n-gram (n down from
    `max_ngram`) with an earlier occurrence in `history`, prefer the
    MOST RECENT earlier occurrence, and replay the tokens that followed
    it. Deterministic, stateless, O(max_ngram * len(history)) with
    vectorized scans. Falls back to repeating the last token (a bet on
    degenerate loops) when no n-gram recurs."""
    hist = np.asarray(history, dtype=np.int64).ravel()
    L = int(hist.size)
    out = np.zeros(n_draft, dtype=np.int32)
    if L == 0 or n_draft <= 0:
        return out
    for n in range(min(max_ngram, L - 1), 0, -1):
        sfx = hist[L - n:]
        # candidate start positions of an EARLIER occurrence (must end
        # before the suffix itself starts)
        starts = np.arange(0, L - n)
        ok = np.ones(starts.size, dtype=bool)
        for j in range(n):
            ok &= hist[starts + j] == sfx[j]
        if not ok.any():
            continue
        i = int(starts[ok][-1])            # most recent earlier match
        cont = hist[i + n:i + n + n_draft]
        if cont.size == 0:
            continue
        out[:cont.size] = cont
        out[cont.size:] = cont[-1]         # pad by repeating the tail
        return out
    out[:] = hist[-1]
    return out


def accept_greedy(cand: Sequence[int],
                  greedy: Sequence[int]) -> Tuple[int, list]:
    """Greedy-verification acceptance for ONE slot: `cand[0..k-1]` the
    scored candidates (cand[0] = last emitted token), `greedy[0..k-1]`
    the verify cell's argmax row. Returns `(n_accepted_drafts, emit)`
    where `emit = greedy[0..a]` is the token burst to commit
    (`1 + n_accepted_drafts` tokens) — exactly the tokens `a + 1`
    successive greedy decode steps would have emitted."""
    cand = np.asarray(cand)
    greedy = np.asarray(greedy)
    k = int(cand.size)
    a = 0
    while a < k - 1 and int(cand[a + 1]) == int(greedy[a]):
        a += 1
    return a, [int(t) for t in greedy[:a + 1]]
