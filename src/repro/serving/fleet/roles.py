"""Disaggregated prefill/decode engine roles: the pool-transfer ledger.

The disaggregated-memory thesis applied to token serving: a
prefill-role engine runs chunked prefill and *produces* KV pages into
the shared pool; a decode-role engine *consumes* them.  Mechanically a
handoff is three existing primitives composed across two engines:

1. the prefill engine completes the last chunk, emits the first token,
   guard-**pins** the slot's prompt pages and parks the slot in the
   ``handoff`` phase (`ServingEngine._prefill_tick`), queueing a
   :class:`~repro.serving.engine.HandoffRecord`;
2. :func:`execute_handoff` admits the request into a decode-engine
   slot, allocates destination pages through the decode engine's pager
   (`KVPager.admit`), and copies the page *payload* — every paged cache
   leaf (`k`/`v` + int8 `k_sz`/`v_sz` scale planes) along the physical
   page axis — pricing the transfer at pool bandwidth on the virtual
   clock (`advance_to(t_emit + pages*page_bytes/BW)`);
3. the prefill engine drops the guard pin and **releases** the source
   slot (`complete_handoff` -> `KVPager.release`), returning its pages
   to the producer's free list.

The :class:`TransferLedger` is the router's accounting of every page
movement — pages, bytes, and per-transfer latency — so bench lanes can
report the pool traffic the role split generates.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.serving.engine import _PAGED_KEYS, HandoffRecord, ServingEngine

__all__ = ["TransferLedger", "copy_pages", "can_accept_handoff",
           "execute_handoff"]


@dataclasses.dataclass
class TransferRecord:
    request_id: int
    src_engine: int
    dst_engine: int
    n_pages: int
    bytes: float
    t_emit: float                 # prefill clock at first-token emission
    t_ready: float                # decode clock when pages landed
    retries: int = 0              # failed copy attempts (fault injection)


class TransferLedger:
    """Append-only log of prefill->decode page transfers."""

    def __init__(self) -> None:
        self.records: List[TransferRecord] = []

    def record(self, rec: TransferRecord) -> None:
        self.records.append(rec)

    def counters(self) -> dict:
        n = len(self.records)
        return {
            "transfers": n,
            "pages": sum(r.n_pages for r in self.records),
            "bytes": sum(r.bytes for r in self.records),
            "mean_latency_s": (
                sum(r.t_ready - r.t_emit for r in self.records) / n
                if n else 0.0
            ),
            # fault-recovery accounting: failed attempts re-crossed the
            # link, so their bytes are real interference even though no
            # page ever landed from them
            "retries": sum(r.retries for r in self.records),
            "retry_bytes": sum(r.retries * r.bytes for r in self.records),
        }


def copy_pages(src_caches, dst_caches, src_pages, dst_pages):
    """Copy the payload of `src_pages` (physical ids in the source pool)
    onto `dst_pages` of the destination pool, for every paged leaf —
    k/v and, for int8 pools, the per-page (scale, zero) planes ride
    along, so quantized pages transfer bit-exactly. Leaves index pages
    on axis 1 (layer-stacked axis 0)."""
    src_ids = np.asarray(src_pages, dtype=np.int32)
    dst_ids = np.asarray(dst_pages, dtype=np.int32)
    if src_ids.size != dst_ids.size:
        raise ValueError("src/dst page counts differ")
    out = {}
    for pos, c in dst_caches.items():
        nc = dict(c)
        src_c = src_caches[pos]
        for key in _PAGED_KEYS:
            if key in nc:
                nc[key] = nc[key].at[:, dst_ids].set(src_c[key][:, src_ids])
        out[pos] = nc
    return out


def can_accept_handoff(dst: ServingEngine, rec: HandoffRecord) -> bool:
    """Room for the transfer right now: a free slot and enough free
    physical pages to own the prompt."""
    return (dst.batcher.n_free > 0
            and dst.pager.counters()["free_pages"] >= len(rec.pages))


def execute_handoff(rec: HandoffRecord, src: ServingEngine,
                    dst: ServingEngine, *, src_id: int, dst_id: int,
                    ledger: TransferLedger, faults=None) -> float:
    """Move `rec`'s request from the prefill engine `src` into a decode
    slot on `dst`. Returns the decode-side ready time (virtual s).

    `faults` (a `serving.faults.FaultInjector`) flakes the copy at the
    "handoff" site: each failed attempt re-prices the full payload over
    the link plus exponential backoff, bounded by `plan.max_retries`
    before the fault surfaces as fatal. The payload lands exactly once
    — only the t_ready bill and the ledger's retry counters change."""
    if not can_accept_handoff(dst, rec):
        raise RuntimeError(
            f"decode engine {dst_id} cannot accept handoff for request "
            f"{rec.request.request_id} (free slots {dst.batcher.n_free}, "
            f"free pages {dst.pager.counters()['free_pages']})"
        )
    req = rec.request
    n_pages = len(rec.pages)
    slot = dst.batcher.admit(req, start_pos=rec.n_tokens)
    dst.pager.admit(slot.index, rec.n_tokens)
    dst_pages = [int(p) for p in dst.pager.phys[slot.index, :n_pages]]
    dst.caches = copy_pages(src.caches, dst.caches, rec.pages, dst_pages)
    dst.tokens[slot.index] = rec.first_token
    # the transfer serializes after first-token emission and prices the
    # page payload over the pool link — the decode engine cannot start
    # this slot before the pages land. With the physical substrate on,
    # page bytes come MEASURED from the pool twin's array nbytes (and
    # the copy lands as a completion-tracked handoff stream in the
    # source engine's ledger); the pager's derived page_bytes is the
    # substrate-off fallback — the two agree to float rounding, so
    # fleet baselines are mode-invariant.
    if src.substrate is not None:
        page_b = src.substrate.page_bytes
        src.substrate.record_handoff(n_pages, step=src.steps)
    else:
        page_b = src.pager.page_bytes
    t_xfer = n_pages * page_b / src.topo.pool.bandwidth
    retries, t_backoff = 0, 0.0
    if faults is not None:
        while faults.transfer_fails("handoff"):
            retries += 1
            t_backoff += faults.backoff_s(retries)
            if retries >= faults.plan.max_retries:
                raise RuntimeError(
                    f"handoff for request {req.request_id} failed "
                    f"{retries} consecutive attempts — link unreachable")
    t_ready = rec.t_emit + (1 + retries) * t_xfer + t_backoff
    dst.advance_to(t_ready)
    src.complete_handoff(rec)
    ledger.record(TransferRecord(
        request_id=req.request_id, src_engine=src_id, dst_engine=dst_id,
        n_pages=n_pages, bytes=n_pages * page_b,
        t_emit=rec.t_emit, t_ready=t_ready, retries=retries,
    ))
    return t_ready
