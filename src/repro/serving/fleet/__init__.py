"""Fleet layer: N serving engines behind a placement-policy router.

Public surface:

* :class:`FleetRouter` / :class:`FleetConfig` / :class:`FleetStats` —
  the router and its run loop (`router.py`);
* :class:`EngineView`, :func:`make_policy`, the three placement
  policies (`placement.py`);
* :class:`Autoscaler` / :class:`AutoscaleConfig` — queue-depth
  hysteresis scaling (`autoscale.py`);
* :class:`TransferLedger`, :func:`execute_handoff` — the disaggregated
  prefill/decode pool-transfer machinery (`roles.py`).
"""
from repro.serving.fleet.autoscale import AutoscaleConfig, Autoscaler
from repro.serving.fleet.placement import (
    POLICIES, EngineView, KVLoadAwarePlacement, PlacementPolicy,
    PrefixAwarePlacement, RoundRobinPlacement, kv_load_score, make_policy)
from repro.serving.fleet.roles import (
    TransferLedger, can_accept_handoff, copy_pages, execute_handoff)
from repro.serving.fleet.router import (
    EngineHandle, FleetConfig, FleetRouter, FleetStats)

__all__ = [
    "AutoscaleConfig", "Autoscaler",
    "POLICIES", "EngineView", "KVLoadAwarePlacement", "PlacementPolicy",
    "PrefixAwarePlacement", "RoundRobinPlacement", "kv_load_score",
    "make_policy",
    "TransferLedger", "can_accept_handoff", "copy_pages",
    "execute_handoff",
    "EngineHandle", "FleetConfig", "FleetRouter", "FleetStats",
]
