"""Queue-depth-driven autoscaling of the fleet's engine count.

The controller is a pure hysteresis loop over one observable — mean
queue depth per accepting engine (router backlog + per-engine queued +
busy slots, over slot capacity).  It recommends +1 / -1 / 0; the router
owns the mechanism (activating a parked engine, draining one for
removal).  Keeping the decision side effect free makes the hysteresis
behaviour directly unit-testable: feed a synthetic load series, assert
the scale events.

A scale-down drains the chosen engine *immediately* through the fault
layer's migration path (`FleetRouter._evacuate_handle`): queued work
re-routes with original arrivals, in-flight slots migrate to the
survivors by teacher-forced refill, and the engine parks with its page
pool verified fully free — rather than lingering half-occupied until
its slowest slot finishes.

Hysteresis has three guards against flapping:

* watermarks — scale up only above ``high_watermark`` occupancy,
  down only below ``low_watermark``;
* patience — the watermark must hold for ``up_patience`` /
  ``down_patience`` *consecutive* observations;
* cooldown — after any scale event, ``cooldown`` observations must
  pass before the next one.
"""
from __future__ import annotations

import dataclasses

__all__ = ["AutoscaleConfig", "Autoscaler"]


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    min_engines: int = 1
    max_engines: int = 4
    high_watermark: float = 1.5   # queue depth per slot: scale up above
    low_watermark: float = 0.25   # scale down below
    up_patience: int = 2          # consecutive high observations needed
    down_patience: int = 4        # consecutive low observations needed
    cooldown: int = 3             # observations to sit out after an event

    def __post_init__(self) -> None:
        if not 1 <= self.min_engines <= self.max_engines:
            raise ValueError("need 1 <= min_engines <= max_engines")
        if self.low_watermark >= self.high_watermark:
            raise ValueError("low_watermark must be < high_watermark")


class Autoscaler:
    """Feed ``observe(occupancy, n_engines)`` once per router epoch;
    it returns the recommended delta in {-1, 0, +1}."""

    def __init__(self, cfg: AutoscaleConfig) -> None:
        self.cfg = cfg
        self._high_streak = 0
        self._low_streak = 0
        self._cooldown = 0
        self.ups = 0
        self.downs = 0

    def observe(self, occupancy: float, n_engines: int) -> int:
        c = self.cfg
        if occupancy >= c.high_watermark:
            self._high_streak += 1
            self._low_streak = 0
        elif occupancy <= c.low_watermark:
            self._low_streak += 1
            self._high_streak = 0
        else:
            self._high_streak = 0
            self._low_streak = 0
        if self._cooldown > 0:
            self._cooldown -= 1
            return 0
        if (self._high_streak >= c.up_patience
                and n_engines < c.max_engines):
            self._high_streak = 0
            self._cooldown = c.cooldown
            self.ups += 1
            return +1
        if (self._low_streak >= c.down_patience
                and n_engines > c.min_engines):
            self._low_streak = 0
            self._cooldown = c.cooldown
            self.downs += 1
            return -1
        return 0
