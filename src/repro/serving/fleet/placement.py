"""Pluggable request-placement policies for the fleet router.

One protocol, three policies:

* ``RoundRobinPlacement`` — the baseline: a counter modulo the eligible
  engine set.  With greedy decoding (tokens depend only on the prompt)
  it replays single-engine token streams bit-for-bit, which is what the
  CI fleet-parity lane asserts.
* ``KVLoadAwarePlacement`` — scores each engine by outstanding-token
  load (queued prompt+gen tokens plus the remaining tokens of busy
  slots, per slot of capacity; plain queue depth when the view carries
  no costs) plus pool pressure (fraction of physical pages in use),
  picking the minimum with engine-id tie-break.  Everything it reads
  is in the router-built :class:`EngineView` snapshot, so scoring is
  deterministic and unit-testable without engines.
* ``PrefixAwarePlacement`` — a router-side radix index over
  page-granular token blocks: each placed prompt registers its full
  pages against the chosen engine, and a later prompt sharing a block
  prefix is steered to the engine whose ``prefix_cache`` already holds
  those pages.  Falls back to KV-load-aware scoring on a cold miss.

Policies see only :class:`EngineView` snapshots (never live engines),
so a placement decision is a pure function of (views, request,
policy-internal state) — the property the determinism tests pin down.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

__all__ = [
    "EngineView",
    "PlacementPolicy",
    "RoundRobinPlacement",
    "KVLoadAwarePlacement",
    "PrefixAwarePlacement",
    "make_policy",
    "POLICIES",
]


@dataclasses.dataclass(frozen=True)
class EngineView:
    """Immutable snapshot of one engine's load, built by the router per
    placement decision. `queued` counts requests already routed to the
    engine but not yet admitted; `busy` counts occupied slots."""
    engine_id: int
    n_slots: int
    busy: int
    queued: int
    free_pages: int
    total_pages: int
    role: str = "unified"          # "unified" | "prefill" | "decode"
    accepting: bool = True         # False while draining for scale-down
    # outstanding-token costs (None = not supplied; scoring falls back
    # to plain queue depth): queued = prompt+gen tokens of routed-but-
    # unadmitted requests, busy = remaining prefill+gen of live slots
    queued_cost: Optional[float] = None
    busy_cost: Optional[float] = None

    @property
    def queue_depth(self) -> int:
        return self.queued + self.busy

    @property
    def load_cost(self) -> float:
        """Outstanding tokens when the router supplied costs, else the
        request/slot count — either way, 'how much work is ahead of a
        request placed here'."""
        if self.queued_cost is None or self.busy_cost is None:
            return float(self.queue_depth)
        return self.queued_cost + self.busy_cost

    @property
    def free_frac(self) -> float:
        return self.free_pages / self.total_pages if self.total_pages else 0.0


class PlacementPolicy(Protocol):
    """A policy maps (eligible engine views, prompt tokens) -> engine_id.

    ``place`` must return the ``engine_id`` of one of the supplied
    views; the router filters views to eligible engines (accepting,
    prefill-capable for the request) before calling. ``record`` is
    invoked by the router after the decision is final so stateful
    policies (round-robin counter, prefix index) advance exactly once
    per placed request.
    """

    name: str

    def place(self, views: Sequence[EngineView],
              tokens: Sequence[int]) -> int: ...

    def record(self, engine_id: int, tokens: Sequence[int]) -> None: ...


class RoundRobinPlacement:
    """Counter mod the eligible set — order-stable, load-blind."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def place(self, views: Sequence[EngineView],
              tokens: Sequence[int]) -> int:
        if not views:
            raise ValueError("no eligible engines")
        return views[self._next % len(views)].engine_id

    def record(self, engine_id: int, tokens: Sequence[int]) -> None:
        self._next += 1


def kv_load_score(view: EngineView) -> float:
    """Lower is better: outstanding load + half-weighted pool pressure.
    Load is normalised by slot capacity so heterogeneous fleets compare
    fairly; pool pressure is (1 - free page fraction) — it decides
    between equally loaded engines (an empty fleet places on the engine
    with the most free pages)."""
    lp = view.load_cost / view.n_slots if view.n_slots else float("inf")
    return lp + 0.5 * (1.0 - view.free_frac)


class KVLoadAwarePlacement:
    """Pick the engine with the lowest :func:`kv_load_score`; ties break
    on the lowest engine id, so the decision is a deterministic function
    of the views alone."""

    name = "kv_aware"

    def place(self, views: Sequence[EngineView],
              tokens: Sequence[int]) -> int:
        if not views:
            raise ValueError("no eligible engines")
        return min(views, key=lambda v: (kv_load_score(v), v.engine_id)
                   ).engine_id

    def record(self, engine_id: int, tokens: Sequence[int]) -> None:
        pass


class PrefixAwarePlacement:
    """Router-side radix index over page-granular token blocks.

    The index maps a tuple of full-page token blocks (the same
    granularity as each engine's ``PrefixCache``) to the engine that
    last served a prompt with that block path.  ``place`` walks the
    longest indexed prefix of the request's blocks; if the owning
    engine is still eligible, the request is steered there — its radix
    trie holds those exact pages, so admission turns into
    ``map_shared`` hits instead of cold prefill.  Cold prompts (or an
    owner that is draining/full) fall back to KV-load-aware scoring.
    """

    name = "prefix_aware"

    def __init__(self, page_tokens: int) -> None:
        if page_tokens <= 0:
            raise ValueError("page_tokens must be positive")
        self.page_tokens = page_tokens
        self._index: Dict[Tuple[Tuple[int, ...], ...], int] = {}
        self._fallback = KVLoadAwarePlacement()
        self.steered = 0
        self.cold = 0

    def _blocks(self, tokens: Sequence[int]) -> Tuple[Tuple[int, ...], ...]:
        p = self.page_tokens
        toks = tuple(int(t) for t in tokens)
        return tuple(toks[i:i + p] for i in range(0, len(toks) - p + 1, p))

    def lookup(self, tokens: Sequence[int]) -> Tuple[Optional[int], int]:
        """(owning engine_id, matched block count) for the longest
        indexed block prefix, or (None, 0) on a cold miss."""
        blocks = self._blocks(tokens)
        for k in range(len(blocks), 0, -1):
            eng = self._index.get(blocks[:k])
            if eng is not None:
                return eng, k
        return None, 0

    def place(self, views: Sequence[EngineView],
              tokens: Sequence[int]) -> int:
        if not views:
            raise ValueError("no eligible engines")
        eng, matched = self.lookup(tokens)
        if eng is not None and any(v.engine_id == eng for v in views):
            self.steered += 1
            return eng
        self.cold += 1
        return self._fallback.place(views, tokens)

    def record(self, engine_id: int, tokens: Sequence[int]) -> None:
        blocks = self._blocks(tokens)
        for k in range(1, len(blocks) + 1):
            self._index[blocks[:k]] = engine_id


POLICIES = ("round_robin", "kv_aware", "prefix_aware")


def make_policy(name: str, *, page_tokens: int = 16) -> PlacementPolicy:
    """Factory used by the launcher / benchmarks (`--policy NAME`)."""
    if name == "round_robin":
        return RoundRobinPlacement()
    if name == "kv_aware":
        return KVLoadAwarePlacement()
    if name == "prefix_aware":
        return PrefixAwarePlacement(page_tokens)
    raise ValueError(f"unknown placement policy {name!r}; "
                     f"choose from {', '.join(POLICIES)}")
