"""FleetRouter: N `ServingEngine`s behind one placement policy.

The router is pure-Python orchestration over the engines' re-entrant
tick primitives (`pump` / `advance_to` / `begin_capture` /
`capture_stats`): no new jitted cells, which is why the whole fleet
layer runs on CPU CI in interpret mode.  All engines share one set of
compiled cells and one parameter tree (`FleetRouter.build` compiles
once), so an N-engine fleet costs N cache pools, not N compilations.

Event loop (deterministic for a fixed trace + policy):

1. *route* — every request whose arrival is <= the router clock is
   placed once, via the policy, over the eligible engine views
   (accepting + prefill-capable under role split); routed requests sit
   in per-engine `RequestQueue`s (priority + cancellation semantics
   included);
2. *transfer* — pending prefill->decode handoffs are drained to the
   least-loaded decode engine with capacity (`roles.execute_handoff`);
3. *tick* — the ready engine with the smallest virtual clock pumps one
   engine-loop iteration; the router clock is the min over ready
   engines' next-event times, else the next unrouted arrival.

Each engine keeps its own virtual clock; fleet makespan is the max
engine clock at drain.  With one engine this loop replays
`ServingEngine.run` bit-for-bit (same pump/advance sequence), and with
greedy decoding the *token streams* are placement-invariant — the
property the CI fleet-parity lane pins down.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import ServeStats, ServingEngine
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.queue import Request, RequestQueue
from repro.serving.fleet.autoscale import AutoscaleConfig, Autoscaler
from repro.serving.fleet.placement import (
    EngineView, PlacementPolicy, make_policy)
from repro.serving.fleet.roles import (
    TransferLedger, can_accept_handoff, execute_handoff)

__all__ = ["FleetConfig", "FleetStats", "EngineHandle", "FleetRouter"]


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_engines: int = 2
    policy: str = "round_robin"
    roles: bool = False          # True: engine 0 prefill-role, rest decode
    autoscale: Optional[AutoscaleConfig] = None
    # --- fault injection + recovery (serving.faults) ---
    faults: Optional[FaultPlan] = None   # chaos schedule; None/no-op plan
    # leaves every engine on the byte-identical fault-free path
    watchdog_s: float = 5e-3     # virtual seconds an engine may fail to
    # make progress before the router declares it dead and recovers its
    # queued + in-flight requests onto the survivors

    def __post_init__(self) -> None:
        if self.n_engines < 1:
            raise ValueError("n_engines must be >= 1")
        if self.roles and self.n_engines < 2:
            raise ValueError("role split needs >= 2 engines")
        if self.roles and self.autoscale is not None:
            raise ValueError("autoscale is unified-role only")
        if self.autoscale is not None \
                and self.autoscale.max_engines > self.n_engines:
            raise ValueError("autoscale.max_engines exceeds built engines")
        if self.watchdog_s <= 0:
            raise ValueError("watchdog_s must be > 0")
        if self.roles and self.faults is not None and (
                self.faults.kill_engine is not None
                or self.faults.stall_engine is not None):
            raise ValueError(
                "engine kill/stall under a role split is unsupported: "
                "recovery migrates by teacher-forced refill through the "
                "bucketed prefill cell, which chunked prefill-role "
                "engines do not expose (transfer flaking is fine)")


class EngineHandle:
    """One engine + its router-side state (queue, role, accepting)."""

    def __init__(self, engine_id: int, engine: ServingEngine,
                 role: str = "unified", accepting: bool = True):
        self.engine_id = engine_id
        self.engine = engine
        self.role = role
        self.accepting = accepting
        self.queue = RequestQueue()
        self.routed: List[Request] = []
        self.stalled = False      # pump made no progress on arrived work;
        # cleared when routing/handoff/clock events change its inputs
        self.dead = False         # watchdog-recovered: permanently fenced
        self.recover_at: Optional[float] = None   # suspect since pump
        # returned "dead"/an over-watchdog stall; recovery fires when the
        # router clock reaches this

    def view(self) -> EngineView:
        eng = self.engine
        # outstanding-token costs: what the kv-aware score actually
        # weighs — a queued 64-token batch prompt with a 32-token budget
        # is far more load than a queued 16-token chat turn
        queued_cost = sum(
            r.prompt_len + r.max_new_tokens for r in self.routed
            if not r.output and np.isnan(r.admitted))
        busy_cost = 0.0
        for s in eng.batcher.slots:
            if s.occupied:
                req = s.request
                if s.phase == "prefill":
                    busy_cost += req.prompt_len - s.prefill_pos
                busy_cost += max(req.max_new_tokens - len(req.output), 0)
        return EngineView(
            engine_id=self.engine_id,
            n_slots=eng.ecfg.n_slots,
            busy=eng.batcher.n_busy,
            queued=len(self.queue),
            free_pages=eng.pager.counters()["free_pages"],
            total_pages=eng.pager.n_phys,
            role=self.role,
            accepting=self.accepting,
            queued_cost=queued_cost,
            busy_cost=busy_cost,
        )

    def ready_time(self) -> float:
        """Virtual time at which pumping this engine can make progress:
        its own clock while it holds live work, else the earliest queued
        arrival (never earlier than its clock), else never. A dead
        engine is never ready; a suspect one becomes the router's
        business again exactly at its watchdog deadline; an injected
        stall pushes readiness to the stall's end."""
        if self.dead:
            return float("inf")
        if self.recover_at is not None:
            return self.recover_at
        eng = self.engine
        if eng.pending_work:
            return max(eng.virtual_s, eng._stall_until)
        if self.stalled or not len(self.queue):
            return float("inf")
        return max(eng.virtual_s, eng._stall_until,
                   self.queue.next_arrival())


@dataclasses.dataclass
class FleetStats:
    n_requests: int
    tokens: int
    virtual_s: float              # fleet makespan (max engine clock delta)
    wall_s: float
    ttft: np.ndarray              # per finished request, fleet-wide
    tpot: np.ndarray
    per_engine: List[ServeStats]
    routed: List[int]             # requests placed per engine
    prefix: dict                  # aggregate prefix-cache deltas
    transfers: dict               # TransferLedger counters (roles mode)
    cancelled: int                # in-flight sweeps + queue drops
    scale_events: List[tuple]     # (virtual_t, delta, n_accepting)
    policy: dict                  # policy-internal counters
    faults: dict = dataclasses.field(default_factory=dict)  # fleet-wide
    # fault-recovery accounting: per-engine ServeStats.faults summed,
    # plus engines_killed / recoveries / handoff retry traffic. Empty on
    # fault-free runs

    def summary(self) -> dict:
        def pct(a, q):
            return float(np.percentile(a, q)) if len(a) else 0.0
        out = {
            "requests": self.n_requests,
            "tokens": self.tokens,
            "virtual_s": self.virtual_s,
            "tok_per_s_virtual": self.tokens / max(self.virtual_s, 1e-12),
            "ttft_p50": pct(self.ttft, 50),
            "ttft_p95": pct(self.ttft, 95),
            "ttft_p99": pct(self.ttft, 99),
            "tpot_p50": pct(self.tpot, 50),
            "prefix_hit_rate": self.prefix.get("hit_rate", 0.0),
            "transfers": self.transfers.get("transfers", 0),
            "transfer_bytes": self.transfers.get("bytes", 0.0),
            "cancelled": self.cancelled,
            "scale_events": len(self.scale_events),
            "routed": list(self.routed),
        }
        if self.faults:
            out["engines_killed"] = self.faults.get("engines_killed", 0)
            out["fault_retries"] = self.faults.get("retries", 0)
            out["fault_retry_bytes"] = self.faults.get("retry_bytes", 0.0)
            out["recovery_overhead_tokens"] = \
                self.faults.get("reprefilled_tokens", 0)
        return out


class FleetRouter:
    """Route a request trace across N engines; see module docstring."""

    def __init__(self, engines: Sequence[ServingEngine], fcfg: FleetConfig,
                 policy: Optional[PlacementPolicy] = None):
        if len(engines) != fcfg.n_engines:
            raise ValueError("engine count != fcfg.n_engines")
        self.fcfg = fcfg
        page_tokens = engines[0].ecfg.page_tokens
        self.policy = policy or make_policy(
            fcfg.policy, page_tokens=page_tokens)
        # ONE injector shared by every engine + substrate: per-site
        # Philox streams make the chaos schedule a pure function of the
        # plan, however engine events interleave
        self.faults: Optional[FaultInjector] = None
        if fcfg.faults is not None and fcfg.faults.active:
            self.faults = FaultInjector(fcfg.faults)
        self.handles: List[EngineHandle] = []
        n_start = (fcfg.autoscale.min_engines if fcfg.autoscale
                   else fcfg.n_engines)
        for i, eng in enumerate(engines):
            role = "unified"
            if fcfg.roles:
                role = "prefill" if i == 0 else "decode"
                eng.handoff_role = role == "prefill"
                if eng.cells.chunk_fn is None and role == "prefill":
                    raise ValueError(
                        "prefill role needs chunked prefill cells "
                        "(EngineConfig.prefill_chunk)"
                    )
            if self.faults is not None:
                eng.faults = self.faults
                eng.engine_id = i
                if eng.substrate is not None:
                    eng.substrate.faults = self.faults
                    eng.substrate.engine_id = i
            self.handles.append(EngineHandle(
                i, eng, role=role, accepting=(i < n_start)))
        self.autoscaler = (Autoscaler(fcfg.autoscale)
                           if fcfg.autoscale else None)
        self.ledger = TransferLedger()
        self.scale_events: List[tuple] = []
        self._pending_handoffs: List[tuple] = []   # (src_handle, record)
        self._pending_adoptions: List[Request] = []   # displaced in-
        # flight requests (emitted history) awaiting a live engine slot
        self.recoveries = 0

    # ------------------------------------------------------------- build
    @classmethod
    def build(cls, cfg, ctx, ecfg, fcfg: FleetConfig, *, params=None,
              mesh=None, rules=None, seed: int = 0,
              topo=None) -> "FleetRouter":
        """Compile ONE set of engine cells + one param tree, then stand
        up `fcfg.n_engines` engines over them (per-engine cache pools)."""
        first = ServingEngine.build(
            cfg, ctx, ecfg, params=params, mesh=mesh, rules=rules,
            seed=seed, topo=topo)
        engines = [first] + [
            ServingEngine(cfg, ctx, ecfg, first.params, first.cells,
                          topo=topo)
            for _ in range(fcfg.n_engines - 1)
        ]
        return cls(engines, fcfg)

    # ----------------------------------------------------------- routing
    def _eligible_views(self) -> List[EngineView]:
        views = []
        for h in self.handles:
            if not h.accepting:
                continue
            if self.fcfg.roles and h.role != "prefill":
                continue
            views.append(h.view())
        return views

    def _route(self, req: Request) -> None:
        views = self._eligible_views()
        eng = self.policy.place(views, req.tokens)
        self.policy.record(eng, req.tokens)
        h = self.handles[eng]
        h.queue.push(req)
        h.routed.append(req)
        h.stalled = False

    def _autoscale_tick(self, t: float) -> None:
        if self.autoscaler is None:
            return
        acc = [h for h in self.handles if h.accepting]
        slots = sum(h.engine.ecfg.n_slots for h in acc)
        load = sum(len(h.queue) + h.engine.batcher.n_busy for h in acc)
        delta = self.autoscaler.observe(load / max(slots, 1), len(acc))
        if delta > 0:
            parked = [h for h in self.handles if not h.accepting]
            if parked:
                parked[0].accepting = True
                self.scale_events.append(
                    (t, +1, sum(h.accepting for h in self.handles)))
        elif delta < 0:
            # drain the highest-id accepting engine IMMEDIATELY through
            # the migration path: queued work re-routes, in-flight slots
            # freeze and adopt onto the survivors, and the engine's page
            # pool is verified fully free — the engine parks empty
            # instead of tapering off for its slowest slot's tail
            self._evacuate_handle(acc[-1])
            self.scale_events.append(
                (t, -1, sum(h.accepting for h in self.handles)))

    # ------------------------------------------------------ fault recovery
    def _evacuate_handle(self, h: EngineHandle, *,
                         dead: bool = False) -> None:
        """Strip `h` of every queued and in-flight request and verify its
        pools drained clean. Queued requests re-route through placement
        with their ORIGINAL arrivals (the queue orders re-admissions by
        (priority, original arrival), so recovered work is deterministic
        and never jumps the line); in-flight requests with emitted
        history await adoption (teacher-forced refill) on a live engine;
        prefill-phase ones are clean requeues. `dead` fences the engine
        permanently (watchdog recovery); otherwise it parks empty and an
        autoscale-up may re-admit to it later."""
        h.accepting = False
        if dead:
            h.dead = True
            h.engine._dead = True
        moved = list(h.queue.drain())
        displaced = h.engine.evacuate()
        gone = set(map(id, moved)) | set(map(id, displaced))
        h.routed[:] = [r for r in h.routed if id(r) not in gone]
        for req in moved:
            self._route(req)
        for req in displaced:
            if req.output:
                self._pending_adoptions.append(req)
            else:
                self._route(req)
        c = h.engine.pager.counters()
        if c["free_pages"] != h.engine.pager.n_phys or c["pins"] != 0:
            raise RuntimeError(
                f"evacuation leaked pages on engine {h.engine_id}: "
                f"free {c['free_pages']}/{h.engine.pager.n_phys}, "
                f"pins {c['pins']}")

    def _recover_engine(self, h: EngineHandle, now: float) -> None:
        """The watchdog expired on a suspect engine: declare it dead and
        move everything it owned onto the survivors."""
        h.recover_at = None
        self.recoveries += 1
        self._evacuate_handle(h, dead=True)

    def _drain_adoptions(self) -> None:
        """Place displaced in-flight requests onto live engines: the
        least-busy decode-capable engine with a free slot replays prompt
        + emitted history (teacher-forced) and continues the stream
        bit-identically. Requests that do not fit yet stay pending."""
        if not self._pending_adoptions:
            return
        still = []
        for req in self._pending_adoptions:
            dsts = [d for d in self.handles
                    if not d.dead and d.recover_at is None
                    and d.role != "prefill"
                    and d.engine.batcher.n_free > 0]
            placed = None
            for d in sorted(dsts, key=lambda d: (d.engine.batcher.n_busy,
                                                 d.engine_id)):
                if d.engine.adopt(req, d.engine.virtual_s):
                    placed = d
                    break
            if placed is None:
                still.append(req)
                continue
            placed.routed.append(req)
            placed.stalled = False
        self._pending_adoptions = still

    # ---------------------------------------------------------- handoffs
    def _drain_handoffs(self) -> None:
        for h in self.handles:
            while h.engine.handoff_outbox:
                self._pending_handoffs.append(
                    (h, h.engine.handoff_outbox.pop(0)))
        if not self._pending_handoffs:
            return
        still = []
        for src_h, rec in self._pending_handoffs:
            dsts = [d for d in self.handles
                    if d.role == "decode" and can_accept_handoff(
                        d.engine, rec)]
            if not dsts:
                still.append((src_h, rec))
                continue
            dst = min(dsts, key=lambda d: (d.engine.batcher.n_busy,
                                           d.engine_id))
            execute_handoff(rec, src_h.engine, dst.engine,
                            src_id=src_h.engine_id, dst_id=dst.engine_id,
                            ledger=self.ledger, faults=self.faults)
            src_h.stalled = False     # a parked slot freed
            dst.stalled = False       # new live work landed
        self._pending_handoffs = still

    # --------------------------------------------------------------- run
    def run(self, requests: Sequence[Request],
            max_iters: int = 2_000_000) -> FleetStats:
        pending = sorted(requests, key=lambda r: (r.arrival, r.request_id))
        i = 0
        caps = [h.engine.begin_capture() for h in self.handles]
        wall0 = time.perf_counter()
        clocks0 = [h.engine.virtual_s for h in self.handles]
        iters = 0
        while True:
            iters += 1
            if iters > max_iters:
                raise RuntimeError("fleet router exceeded max_iters — "
                                   "stuck trace?")
            self._drain_handoffs()
            self._drain_adoptions()
            t_engines = min((h.ready_time() for h in self.handles),
                            default=float("inf"))
            t_arrival = pending[i].arrival if i < len(pending) \
                else float("inf")
            now = min(t_engines, t_arrival)
            if not np.isfinite(now):
                if self._pending_handoffs:
                    raise RuntimeError(
                        "handoffs pending but no decode engine can ever "
                        "accept them (capacity too small for one prompt)"
                    )
                if self._pending_adoptions:
                    raise RuntimeError(
                        "displaced requests pending adoption but no live "
                        "engine can ever take them (fleet capacity lost)"
                    )
                break
            # watchdog: suspects whose deadline the clock just reached
            # are recovered before anything else happens at `now`
            recovered = False
            for h in self.handles:
                if h.recover_at is not None and now >= h.recover_at:
                    self._recover_engine(h, now)
                    recovered = True
            if recovered:
                continue      # re-routes changed queues + ready times
            routed_any = False
            while i < len(pending) and pending[i].arrival <= now:
                self._route(pending[i])
                i += 1
                routed_any = True
            if routed_any:
                self._autoscale_tick(now)
                continue      # recompute ready times with the new queues
            ready = [h for h in self.handles
                     if h.ready_time() <= now]
            if not ready:
                continue      # a handoff drained; re-evaluate
            h = min(ready, key=lambda x: (x.ready_time(), x.engine_id))
            h.engine.advance_to(now)
            act = h.engine.pump(h.queue)
            if act == "dead":
                if h.recover_at is None and not h.dead:
                    # first silence: suspect now, dead at the deadline
                    h.recover_at = (h.engine.virtual_s
                                    + self.fcfg.watchdog_s)
            elif act == "stalled":
                stall_left = h.engine._stall_until - h.engine.virtual_s
                if stall_left > self.fcfg.watchdog_s \
                        and h.recover_at is None:
                    # a stall past the watchdog is indistinguishable
                    # from a kill: fence and recover the same way
                    h.recover_at = (h.engine.virtual_s
                                    + self.fcfg.watchdog_s)
            elif act == "idle" and len(h.queue) \
                    and h.queue.next_arrival() <= h.engine.virtual_s:
                # arrived work it cannot start (slots full of parked
                # handoffs / admission floor): wait for an external event
                h.stalled = True
        return self._stats(caps, clocks0, wall0)

    # ------------------------------------------------------------- stats
    def _stats(self, caps, clocks0, wall0) -> FleetStats:
        per = [h.engine.capture_stats(cap, h.routed)
               for h, cap in zip(self.handles, caps)]
        done = [r for h in self.handles for r in h.routed if r.output]
        ttft = np.array([r.token_times[0] - r.arrival for r in done])
        tpot = np.concatenate(
            [np.diff(r.token_times) for r in done
             if len(r.token_times) > 1] or [np.zeros(0)]
        )
        prefix: Dict[str, float] = {}
        for s in per:
            for k, v in s.prefix.items():
                if k not in ("hit_rate", "cached_pages"):
                    prefix[k] = prefix.get(k, 0) + v
        if prefix:
            n = prefix.get("hits", 0) + prefix.get("misses", 0)
            prefix["hit_rate"] = prefix.get("hits", 0) / n if n else 0.0
        cancelled = (
            sum(h.engine.cancelled for h in self.handles)
            + sum(h.queue.drop_cancelled for h in self.handles)
        )
        makespan = max(
            (h.engine.virtual_s - c0
             for h, c0 in zip(self.handles, clocks0)),
            default=0.0,
        )
        policy_counters = {}
        for key in ("steered", "cold"):
            if hasattr(self.policy, key):
                policy_counters[key] = getattr(self.policy, key)
        faults_agg: Dict[str, float] = {}
        if self.faults is not None:
            for s in per:
                for k, v in s.faults.items():
                    faults_agg[k] = faults_agg.get(k, 0) + v
            tc = self.ledger.counters()
            faults_agg["retries"] = (
                faults_agg.get("retries", 0) + tc["retries"])
            faults_agg["retry_bytes"] = (
                faults_agg.get("retry_bytes", 0.0) + tc["retry_bytes"])
            faults_agg["engines_killed"] = \
                sum(1 for h in self.handles if h.dead)
            faults_agg["recoveries"] = self.recoveries
            faults_agg["injected"] = self.faults.counters()
        return FleetStats(
            n_requests=len(done),
            tokens=sum(len(r.output) for r in done),
            virtual_s=makespan,
            wall_s=time.perf_counter() - wall0,
            ttft=ttft,
            tpot=tpot,
            per_engine=per,
            routed=[len(h.routed) for h in self.handles],
            prefix=prefix,
            transfers=self.ledger.counters(),
            cancelled=cancelled,
            scale_events=self.scale_events,
            policy=policy_counters,
            faults=faults_agg,
        )
