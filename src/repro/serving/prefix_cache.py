"""Shared-prefix radix cache over the pager's physical pages.

Serving traffic is dominated by requests that open with the same system
prompt. Their KV for those tokens is bit-identical (K/V at position i is a
function of token i, the weights and the rotary phase — not of the
suffix), so every slot re-prefilling and re-storing its own copy is pure
memory over-provisioning — the exact waste the source paper quantifies
and that a shared pool is meant to reclaim. This module is the lookup
structure that turns the pager's refcounted pages into a dedup cache.

KEYING — a radix trie at PAGE granularity. Each edge is one full block of
`page_tokens` token ids (a tuple, hashed directly); a node owns the
physical page holding that block's K/V. Matching a prompt walks full
blocks from the root and stops at the first divergent block, so a hit is
always a page-aligned prefix — the only grain the block table can alias.
A node may also hang TERMINAL partial-block children (key = the prompt's
trailing partial block, matched only when it equals the entire remaining
prompt): that is what makes copy-on-write real — a sharer of a partial
tail page must split it before its first decode token lands in the
unused slack of the shared page.

LIFECYCLE — the trie holds its pages via `KVPager.pin` (a non-slot
reference), so a cached prefix survives the donor slot's release; slots
that hit map the pages via `map_shared`/`remap_shared` (ref += 1 each).
Under free-list pressure the pager calls back into `reclaim`, which
unpins least-recently-matched leaves until enough pages actually return
to the free list — evicting a leaf whose page is still mapped by a live
slot frees nothing (the slot's ref keeps it alive), so reclaim keeps
walking. Capacity can also be capped directly (`capacity_pages`).

The trie stores no tensor data — pages live in the engine's paged pools;
for int8 pools the scale/zero leaves ride the same physical page ids, so
sharing quantized payload shares its quantization metadata for free.

SUBSTRATE INTERPLAY (`repro.serving.substrate`): pinned pages keep
ref > 0, so a pool-placed cached prefix stays host-RESIDENT in the
physical substrate across donor-slot release — one twin page no matter
how many slots map it (dedup is physical, not just accounting). When
`reclaim` unpins a leaf and the pager frees the page, the next drain
retires it as a zero-byte drop stream; a slot promoting a shared page
back to the local tier turns into a single page_in for all sharers.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


class _Node:
    __slots__ = ("key", "parent", "children", "partial", "phys", "stamp")

    def __init__(self, key: Tuple[int, ...], parent: Optional["_Node"],
                 phys: Optional[int], stamp: int):
        self.key = key          # the token block this node's page caches
        self.parent = parent
        self.children = {}      # full block tuple -> _Node
        self.partial = {}       # terminal partial-tail tuple -> _Node
        self.phys = phys        # physical page id (None only at root)
        self.stamp = stamp      # last match/insert tick (LRU eviction)


@dataclasses.dataclass
class PrefixHit:
    """A page-aligned prefix match: `pages` are the full-block physical
    pages (logical order), `tail_page` the optional terminal partial
    block (only when it equals the prompt's entire remainder)."""

    pages: List[int]
    n_full_tokens: int
    tail_page: Optional[int] = None
    n_tokens: int = 0

    @property
    def all_pages(self) -> List[int]:
        return self.pages + ([self.tail_page]
                             if self.tail_page is not None else [])


class PrefixCache:
    """Radix trie mapping page-granular token blocks to cached physical
    pages. Pure bookkeeping: pages are owned by the `KVPager` (the trie
    pins them) and the KV bytes live in the engine's paged pools."""

    def __init__(self, page_tokens: int,
                 capacity_pages: Optional[int] = None):
        if page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")
        if capacity_pages is not None and capacity_pages < 1:
            raise ValueError("capacity_pages must be >= 1 (or None)")
        self.page_tokens = page_tokens
        self.capacity_pages = capacity_pages
        self._root = _Node((), None, None, 0)
        self._stamp = 0
        self.cached_pages = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.hit_pages = 0
        self.inserted_pages = 0
        self.evicted_pages = 0

    def _tick(self) -> int:
        self._stamp += 1
        return self._stamp

    # ------------------------------------------------------------ match
    def match(self, tokens) -> Optional[PrefixHit]:
        """Longest page-aligned cached prefix of `tokens`, plus the
        terminal partial block iff it covers the prompt's entire
        remainder. Returns None on a cold miss. Touches matched nodes'
        LRU stamps. The caller must `pin` the hit's pages before any
        allocation that could trigger `reclaim` (the guard pin)."""
        toks = tuple(int(t) for t in tokens)
        P = self.page_tokens
        node = self._root
        pages: List[int] = []
        i = 0
        while i + P <= len(toks):
            child = node.children.get(toks[i:i + P])
            if child is None:
                break
            node = child
            node.stamp = self._tick()
            pages.append(child.phys)
            i += P
        tail = None
        n_tail = 0
        rest = toks[i:]
        if 0 < len(rest) < P:
            pnode = node.partial.get(rest)
            if pnode is not None:
                pnode.stamp = self._tick()
                tail = pnode.phys
                n_tail = len(rest)
        if not pages and tail is None:
            self.misses += 1
            return None
        self.hits += 1
        self.hit_pages += len(pages) + (tail is not None)
        self.hit_tokens += len(pages) * P + n_tail
        return PrefixHit(pages=pages, n_full_tokens=len(pages) * P,
                         tail_page=tail, n_tokens=len(pages) * P + n_tail)

    # ----------------------------------------------------------- insert
    def insert(self, tokens, phys_row, pager,
               include_partial: bool = False) -> int:
        """Cache a freshly prefilled prompt: walk/extend the trie along
        `tokens`, pinning each NEW node's page from `phys_row` (the
        owning slot's physical page ids, logical order). Existing nodes
        keep their page — the caller deduplicates the slot's table
        against them via `remap_shared`/`map_shared`. With
        `include_partial`, a trailing partial block becomes a terminal
        node too (the COW-able shared tail). Returns pages added."""
        toks = tuple(int(t) for t in tokens)
        P = self.page_tokens
        node = self._root
        added = 0
        i = 0
        j = 0                       # logical page index into phys_row
        while i + P <= len(toks):
            key = toks[i:i + P]
            child = node.children.get(key)
            if child is None:
                child = _Node(key, node, int(phys_row[j]), self._tick())
                node.children[key] = child
                pager.pin([child.phys])
                self.cached_pages += 1
                self.inserted_pages += 1
                added += 1
            else:
                child.stamp = self._tick()
            node = child
            i += P
            j += 1
        rest = toks[i:]
        if include_partial and 0 < len(rest) < P:
            pnode = node.partial.get(rest)
            if pnode is None:
                pnode = _Node(rest, node, int(phys_row[j]), self._tick())
                node.partial[rest] = pnode
                pager.pin([pnode.phys])
                self.cached_pages += 1
                self.inserted_pages += 1
                added += 1
            else:
                pnode.stamp = self._tick()
        if self.capacity_pages is not None:
            while self.cached_pages > self.capacity_pages:
                if not self._evict_lru(pager):
                    break
        return added

    # --------------------------------------------------------- eviction
    def _leaves(self) -> List[_Node]:
        out: List[_Node] = []
        stack = [self._root]
        while stack:
            n = stack.pop()
            kids = list(n.children.values()) + list(n.partial.values())
            if n is not self._root and not kids:
                out.append(n)
            stack.extend(kids)
        return out

    def _evict_lru(self, pager) -> bool:
        """Unpin the least-recently-matched LEAF (interior nodes anchor
        longer cached prefixes and cannot go first). Returns False when
        the trie is empty."""
        leaves = self._leaves()
        if not leaves:
            return False
        leaf = min(leaves, key=lambda n: n.stamp)
        parent = leaf.parent
        if len(leaf.key) == self.page_tokens:
            del parent.children[leaf.key]
        else:
            del parent.partial[leaf.key]
        self.cached_pages -= 1
        self.evicted_pages += 1
        pager.unpin([leaf.phys])
        return True

    def reclaim(self, pager, n_pages: int) -> int:
        """Free-list pressure callback from `KVPager._take_free`: evict
        LRU leaves until at least `n_pages` pages actually reached the
        free list (an evicted page still mapped by a live slot frees
        nothing — keep walking) or the trie is empty. Returns pages
        freed."""
        freed0 = len(pager._free_phys)
        while len(pager._free_phys) - freed0 < n_pages:
            if not self._evict_lru(pager):
                break
        return len(pager._free_phys) - freed0

    def clear(self, pager) -> None:
        """Drop every cached prefix (unpinning all pages)."""
        while self._evict_lru(pager):
            pass

    # ---------------------------------------------------------- metrics
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def counters(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "hit_tokens": self.hit_tokens,
            "hit_pages": self.hit_pages,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
            "cached_pages": self.cached_pages,
        }
