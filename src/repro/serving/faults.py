"""Deterministic, seedable fault injection for the serving fleet.

A :class:`FaultPlan` is a frozen description of WHAT goes wrong and
WHEN; a :class:`FaultInjector` is the runtime that engines, the
substrate and the router consult at the named fault sites.  Every
stochastic decision (does THIS transfer fail?) is drawn from a
counter-indexed Philox stream keyed on ``(plan.seed, site)``, so a
chaos run is a pure function of the plan — replaying the same plan
over the same trace reproduces every failure at the same site, in the
same order, regardless of how other sites interleave.  That is what
makes the chaos bit-parity gate testable at all: the recovered run is
deterministic, so its tokens can be compared bit-for-bit against the
fault-free run.

Fault sites (each opt-in via a plan field; ``FaultPlan()`` is a no-op):

``transfer``   — substrate ``page_out``/``page_in`` stream issues and
                 prefill->decode handoff copies fail with probability
                 ``transfer_fail_rate``; the caller retries with
                 bounded exponential backoff (``backoff_base_s``
                 doubling per attempt up to ``backoff_cap_s``, at most
                 ``max_retries`` retries before the fault is
                 re-raised as fatal), logging every retry in the
                 owning ledger.
``kill``       — engine ``kill_engine`` stops responding permanently
                 once it has taken ``kill_at_step`` decode steps; the
                 router's watchdog declares it dead and recovers its
                 queued + in-flight requests.
``stall``      — engine ``stall_engine`` freezes for ``stall_s`` of
                 virtual time at decode step ``stall_at_step``; a
                 stall longer than the router watchdog is
                 indistinguishable from a kill and is recovered the
                 same way.
``shrink``     — engine ``shrink_engine``'s local page budget is
                 multiplied by ``shrink_frac`` at step
                 ``shrink_at_step`` (a pool-pressure spike: the
                 hotness rebalancer demotes pages to the pool tier to
                 fit the new budget).
``pool_lost``  — engine ``lose_pool_engine`` loses its pool tier at
                 step ``lose_pool_at_step`` and enters degraded mode:
                 all live pages promote to the local tier, the
                 substrate drains its twin, and admission tightens
                 through the existing corridor budget.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional

import numpy as np

__all__ = ["FaultPlan", "FaultInjector", "PLANS", "make_plan"]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Immutable chaos schedule. All sites default off; seed pins the
    per-site Philox streams so the run is exactly replayable."""

    seed: int = 0
    # --- transfer flaking (substrate streams + handoff copies) ---
    transfer_fail_rate: float = 0.0
    max_retries: int = 8
    backoff_base_s: float = 1e-4
    backoff_cap_s: float = 2e-2
    # --- engine kill / stall ---
    kill_engine: Optional[int] = None
    kill_at_step: int = 0
    stall_engine: Optional[int] = None
    stall_at_step: int = 0
    stall_s: float = 0.0
    # --- pool-pressure spike / pool-tier loss ---
    shrink_engine: Optional[int] = None
    shrink_at_step: int = 0
    shrink_frac: float = 0.5
    lose_pool_engine: Optional[int] = None
    lose_pool_at_step: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.transfer_fail_rate < 1.0:
            raise ValueError("transfer_fail_rate must be in [0, 1)")
        if self.max_retries < 1:
            raise ValueError("max_retries must be >= 1")
        if not 0.0 < self.shrink_frac <= 1.0:
            raise ValueError("shrink_frac must be in (0, 1]")

    @property
    def active(self) -> bool:
        return (self.transfer_fail_rate > 0.0
                or self.kill_engine is not None
                or self.stall_engine is not None
                or self.shrink_engine is not None
                or self.lose_pool_engine is not None)


class FaultInjector:
    """Runtime oracle for a :class:`FaultPlan`.

    One injector is shared by every engine/substrate in a fleet run
    (the router builds it); per-site draw streams are independent, so
    the order in which sites consult the injector never perturbs
    another site's sequence of outcomes.
    """

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self._streams: Dict[str, np.random.Generator] = {}
        # observability: how many failures each site injected
        self.injected: Dict[str, int] = {}

    def _stream(self, site: str) -> np.random.Generator:
        gen = self._streams.get(site)
        if gen is None:
            key = [self.plan.seed & 0xFFFFFFFF, zlib.crc32(site.encode())]
            gen = np.random.Generator(np.random.Philox(key=key))
            self._streams[site] = gen
        return gen

    # ------------------------------------------------------ transfer
    def transfer_fails(self, site: str) -> bool:
        """One Bernoulli draw from `site`'s private stream: does the
        next transfer attempt at this site fail?"""
        if self.plan.transfer_fail_rate <= 0.0:
            return False
        fail = bool(self._stream(site).random()
                    < self.plan.transfer_fail_rate)
        if fail:
            self.injected[site] = self.injected.get(site, 0) + 1
        return fail

    def backoff_s(self, attempt: int) -> float:
        """Exponential backoff charged to the virtual clock for retry
        `attempt` (1-based)."""
        return min(self.plan.backoff_base_s * (2.0 ** (attempt - 1)),
                   self.plan.backoff_cap_s)

    # ----------------------------------------------------- lifecycle
    def kill_now(self, engine_id: int, step: int) -> bool:
        return (self.plan.kill_engine == engine_id
                and step >= self.plan.kill_at_step)

    def stall_now(self, engine_id: int, step: int) -> Optional[float]:
        """Stall duration if this engine stalls at this step (consumed:
        fires at most once), else None."""
        if (self.plan.stall_engine == engine_id
                and step >= self.plan.stall_at_step
                and "stall" not in self.injected):
            self.injected["stall"] = 1
            return self.plan.stall_s
        return None

    # -------------------------------------------------- pool budgets
    def shrink_now(self, engine_id: int, step: int) -> Optional[float]:
        """Budget multiplier if the shrink site fires here (consumed),
        else None."""
        if (self.plan.shrink_engine == engine_id
                and step >= self.plan.shrink_at_step
                and "shrink" not in self.injected):
            self.injected["shrink"] = 1
            return self.plan.shrink_frac
        return None

    def pool_lost_now(self, engine_id: int, step: int) -> bool:
        """True once when the pool tier drops out from under this
        engine (consumed)."""
        if (self.plan.lose_pool_engine == engine_id
                and step >= self.plan.lose_pool_at_step
                and "pool_lost" not in self.injected):
            self.injected["pool_lost"] = 1
            return True
        return False

    def counters(self) -> Dict[str, int]:
        return dict(self.injected)


# Named plans for CLI/CI lanes (`dev_serve.py --fault-plan NAME`).
PLANS: Dict[str, FaultPlan] = {
    # no-op plan: every site off — a chaos run under "none" must be
    # byte-identical to a run with no injector wired at all
    "none": FaultPlan(),
    # the acceptance-criteria plan: one of two fleet engines killed
    # mid-decode while substrate transfers flake at 10%
    "chaos_smoke": FaultPlan(seed=0, transfer_fail_rate=0.10,
                             kill_engine=1, kill_at_step=3),
    # pure link flaking, no engine loss — isolates the retry path
    "transfer_flake": FaultPlan(seed=0, transfer_fail_rate=0.25),
}


def make_plan(name: str) -> FaultPlan:
    try:
        return PLANS[name]
    except KeyError:
        raise ValueError(f"unknown fault plan {name!r}; choose from "
                         f"{', '.join(sorted(PLANS))}") from None
