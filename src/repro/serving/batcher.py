"""Slot bookkeeping for continuous batching.

The engine decodes a fixed batch of `n_slots` sequences; requests flow
through slots (admit on free, release on completion) so new prompts join
in-flight decode without ever changing the jitted cell's shapes. Inactive
slots park their write cursor at `park_pos` (>= cache length), which turns
the masked KV insert into a no-op (`models.attention._cache_insert` writes
nothing for out-of-range positions) — the "slot masking" half of the
fixed-shape contract.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.queue import Request


@dataclasses.dataclass
class Slot:
    index: int
    request: Optional[Request] = None
    t: int = 0                 # next cache write position (absolute)
    emitted: int = 0           # tokens generated so far (incl. prefill's)

    @property
    def active(self) -> bool:
        return self.request is not None


class ContinuousBatcher:
    """Fixed-slot admission/release with bucketed prefill shapes."""

    def __init__(self, n_slots: int, prefill_buckets: Sequence[int],
                 park_pos: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.buckets = tuple(sorted(prefill_buckets))
        self.park_pos = park_pos
        self.slots: List[Slot] = [Slot(i) for i in range(n_slots)]
        self._free: List[int] = list(range(n_slots))[::-1]  # pop() -> slot 0

    # ------------------------------------------------------------ buckets
    def bucket_for(self, prompt_len: int) -> int:
        """Prompts must land exactly on a bucket: SSM/conv state is a
        sequential reduction over the prompt, so right-padding would
        corrupt it — generators quantize lengths instead (see queue.py)."""
        if prompt_len not in self.buckets:
            raise ValueError(
                f"prompt_len {prompt_len} not in prefill buckets "
                f"{self.buckets}; quantize the stream"
            )
        return prompt_len

    # ----------------------------------------------------------- slots
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - self.n_free

    def active_mask(self) -> np.ndarray:
        return np.array([s.active for s in self.slots], dtype=bool)

    def admit(self, request: Request, start_pos: int) -> Slot:
        if not self._free:
            raise RuntimeError("no free slot")
        slot = self.slots[self._free.pop()]
        slot.request = request
        slot.t = start_pos
        slot.emitted = 1            # prefill emits the first token
        return slot

    def release(self, slot: Slot) -> Request:
        req = slot.request
        if req is None:
            raise RuntimeError(f"slot {slot.index} already free")
        slot.request = None
        slot.t = self.park_pos
        slot.emitted = 0
        self._free.append(slot.index)
        return req

    # ------------------------------------------------------- step arrays
    def t_vector(self) -> np.ndarray:
        """Per-slot write positions; inactive slots parked out of range so
        their cache writes mask away."""
        return np.array(
            [s.t if s.active else self.park_pos for s in self.slots],
            dtype=np.int32,
        )

    def advance(self) -> None:
        for s in self.slots:
            if s.active:
                s.t += 1
                s.emitted += 1
