"""Slot bookkeeping for continuous batching.

The engine decodes a fixed batch of `n_slots` sequences; requests flow
through slots (admit on free, release on completion) so new prompts join
in-flight decode without ever changing the jitted cell's shapes. Inactive
slots park their write cursor at `park_pos` (>= cache length), which turns
the masked KV insert into a no-op (`models.attention._cache_insert` writes
nothing for out-of-range positions; the paged write path drops the scatter
the same way) — the "slot masking" half of the fixed-shape contract.

A slot has two phases. `decode` is the classic lane: the request was
prefilled in one shot (or finished its chunks) and generates one token per
engine step. `prefill` is the chunked-prefill lane: the slot is OCCUPIED
(it owns KV pages and blocks admission) but excluded from the decode
batch — its prompt advances one page-aligned chunk at a time, interleaved
with everyone else's decode steps, until `begin_decode` flips it live.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.queue import Request


@dataclasses.dataclass
class Slot:
    index: int
    request: Optional[Request] = None
    t: int = 0                 # next cache write position (absolute)
    emitted: int = 0           # tokens generated so far (incl. prefill's)
    phase: str = "decode"      # "decode" | "prefill" (chunked prefill)
    prefill_pos: int = 0       # prompt tokens prefilled so far
    seq: int = -1              # admission order (FIFO chunk scheduling)

    @property
    def occupied(self) -> bool:
        return self.request is not None

    @property
    def active(self) -> bool:
        """In the decode batch (occupied AND past its prefill phase)."""
        return self.request is not None and self.phase == "decode"

    @property
    def prefilling(self) -> bool:
        return self.request is not None and self.phase == "prefill"


class ContinuousBatcher:
    """Fixed-slot admission/release with bucketed prefill shapes."""

    def __init__(self, n_slots: int, prefill_buckets: Sequence[int],
                 park_pos: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.buckets = tuple(sorted(prefill_buckets))
        self.park_pos = park_pos
        self.slots: List[Slot] = [Slot(i) for i in range(n_slots)]
        self._free: List[int] = list(range(n_slots))[::-1]  # pop() -> slot 0
        self._seq = itertools.count()

    # ------------------------------------------------------------ buckets
    def bucket_for(self, prompt_len: int) -> int:
        """Prompts must land exactly on a bucket: SSM/conv state is a
        sequential reduction over the prompt, so right-padding would
        corrupt it — generators quantize lengths instead (see queue.py)."""
        if prompt_len not in self.buckets:
            raise ValueError(
                f"prompt_len {prompt_len} not in prefill buckets "
                f"{self.buckets}; quantize the stream"
            )
        return prompt_len

    # ----------------------------------------------------------- slots
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s.active)

    @property
    def n_prefilling(self) -> int:
        return sum(1 for s in self.slots if s.prefilling)

    @property
    def n_busy(self) -> int:
        """Occupied slots (decode-active + mid-chunked-prefill)."""
        return self.n_slots - self.n_free

    def active_mask(self) -> np.ndarray:
        return np.array([s.active for s in self.slots], dtype=bool)

    def prefilling_slots(self) -> List[Slot]:
        """Mid-prefill slots in admission order (FIFO chunk scheduling)."""
        return sorted(
            (s for s in self.slots if s.prefilling), key=lambda s: s.seq
        )

    def admit(self, request: Request, start_pos: int,
              phase: str = "decode", prefill_pos: int = 0,
              emitted: Optional[int] = None) -> Slot:
        """`prefill_pos` (prefill phase only): first prompt token still to
        be prefilled — a prefix-cache hit maps the leading pages shared
        and starts chunking at the first divergent page instead of 0.
        `emitted` (decode phase only) overrides the default of 1 — the
        thaw/migration path resumes a request that already generated
        several tokens before it was preempted or its engine died."""
        if not self._free:
            raise RuntimeError("no free slot")
        slot = self.slots[self._free.pop()]
        slot.request = request
        slot.phase = phase
        slot.seq = next(self._seq)
        if phase == "decode":
            slot.t = start_pos
            # prefill emits the first token; a resumed slot picks up its
            # pre-preemption count
            slot.emitted = 1 if emitted is None else emitted
        else:
            slot.t = self.park_pos  # masked until begin_decode
            slot.emitted = 0
            slot.prefill_pos = prefill_pos
        return slot

    def begin_decode(self, slot: Slot, start_pos: int) -> None:
        """A chunked prefill finished: the slot joins the decode batch."""
        if not slot.prefilling:
            raise RuntimeError(f"slot {slot.index} is not prefilling")
        slot.phase = "decode"
        slot.t = start_pos
        slot.emitted = 1
        slot.prefill_pos = 0

    def release(self, slot: Slot) -> Request:
        req = slot.request
        if req is None:
            raise RuntimeError(f"slot {slot.index} already free")
        slot.request = None
        slot.t = self.park_pos
        slot.emitted = 0
        slot.phase = "decode"
        slot.prefill_pos = 0
        slot.seq = -1
        self._free.append(slot.index)
        return req

    # ------------------------------------------------------- step arrays
    def t_vector(self) -> np.ndarray:
        """Per-slot write positions; inactive (free or still-prefilling)
        slots parked out of range so their cache writes mask away."""
        return np.array(
            [s.t if s.active else self.park_pos for s in self.slots],
            dtype=np.int32,
        )

    def advance(self, counts: Optional[np.ndarray] = None) -> None:
        """Advance every active slot's cursor: by 1 (plain greedy decode)
        or by `counts[slot.index]` tokens (speculative decode — one
        verify step commits `1 + accepted` tokens per slot)."""
        for s in self.slots:
            if s.active:
                n = 1 if counts is None else int(counts[s.index])
                s.t += n
                s.emitted += n
