"""Tier-aware continuous-batching serving subsystem.

This package is the serving-side realization of the paper's quantitative
workflow: decode is the catalog's link-saturating, latency-sensitive cell,
so it is where disaggregated-memory placement and admission decisions
matter most (cf. the CXL-pooling studies arXiv:2211.02682, 2303.06420).

The KV cache is a PHYSICAL page pool end-to-end (the default,
`EngineConfig.paged`): self-attention K/V lives as (stack, n_slots *
n_pages, page_tokens, heads, head_dim) arrays, and every jitted cell —
decode, prefill-insert, chunked prefill — reads and writes it through the
live (n_slots, n_pages) block table the pager emits. Page placement is
therefore real at the data-layout level, not an accounting overlay: the
paper's three-level local/pool byte split prices exactly the pages the
kernels gather.

POOL DTYPE (`EngineConfig.pool_dtype`): the pool payload is polymorphic.

* "fp" stores cfg.dtype bit-identically — the exact safety net; the
  engine is token-for-token equal to the contiguous layout. Parity
  tests and lanes that assert exactness pin this mode.
* "bf16" stores a 2-byte cast of the payload (fp16-class pooling).
* "int8" (the DEFAULT since the physical substrate made pool bytes
  real) BLOCK-QUANTIZES every page: the payload pool is int8 and each
  attention cache dict grows per-page float32 (scale, zero) leaves
  "k_sz"/"v_sz" of shape (stack, n_slots * n_pages, kv_heads, 2)
  (`repro.kernels.quant` layout, affine mid-range: q = round((x -
  zero)/scale), |dequant(q) - x| <= scale/2 per element). Inserts
  quantize (bucket-insert and chunk cells quantize whole pages; the
  decode cell requantizes the slot's tail page around the new token) and
  the paged kernels dequantize each gathered page in their epilogue, so
  only int8 payload plus the per-page scalars ever cross the pool link.

  Bytes per cached token (the pager's dtype-aware accounting, also in
  closed form as `core.access.kv_pool_token_bytes`):

      2 (K and V) * kv_heads * head_dim * payload_bytes * n_attn_layers
      + 2 * kv_heads * 8 / page_tokens * n_attn_layers     [int8 only]

  i.e. ~4x fewer pool bytes than an fp32 pool (~2x vs bf16) at a
  bounded logit drift — and under a FIXED local-tier byte budget the
  remote share drops further because the same HBM holds ~4x more pages
  (the serve_int8 bench lane asserts <= 0.30x of the fp16 lane's pool
  bytes at >= 0.95x tokens/s).

SPECULATIVE DECODING (`EngineConfig.speculative`, `serving/
speculative.py`): greedy decode is the serving stack's lowest-
arithmetic-intensity loop — every emitted token costs one full sweep of
the slot batch's pool-resident KV pages, which is exactly the traffic
the paper's corridor prices. Speculation raises that intensity without
changing the tokens: a PROPOSER guesses `speculative_k - 1` draft
tokens per slot ("ngram" — self-speculative suffix matching over the
slot's own history, zero parameters, zero device work; or "draft" — a
small draft model decoding ahead against its own contiguous scratch
caches, weights deterministic and shared engine-wide through
`EngineCells`), the VERIFY cell (`runtime.serve.
build_decode_verify_paged`) flattens the (slots, k) candidates to
slots*k decode rows with vector positions and k-repeated block-table
rows and scores all of them in ONE paged-decode call, and greedy
acceptance commits the longest candidate prefix matching the verify
argmaxes — `1 + accepted` tokens per slot per sweep, BIT-IDENTICAL to
plain greedy decode on fp pools by construction (int8 pools keep the
same bounded drift either way). The pager's multi-token accounting
(`KVPager.step(tokens=...)`) charges the sweep once while lengths
advance by the acceptance length; `ensure_tail_pages(lookahead=k)`
makes all k candidate write positions live+private up front, and
`KVPager.truncate` rolls the page accounting back over the rejected
tail (whose dead KV every kernel already masks and the next verify
overwrites). int8 pools switch to the PER-TOKEN sub-scale layout
(`sz_granularity="token"`, k_sz/v_sz at (stack, P, page_tokens,
kv_heads, 2)): each candidate row quantizes its own K/V rows
independently, a pure disjoint scatter — the per-page requantize
round trip would have k rows of one slot read-modify-writing the same
tail page concurrently. `ServeStats.spec` reports verify steps and the
mean acceptance length; the serve_speculative bench lane gates the
tokens/s win (>= 1.5x the greedy chat lane at equal output tokens).

SHARED-PREFIX RADIX CACHE (`EngineConfig.prefix_cache`): requests behind
the same system prompt share bit-identical prefix KV (K/V at position i
depends on token i, the weights and the rotary phase — not the suffix),
so the pager refcounts physical pages and `prefix_cache.py` keys a radix
trie on page-granular token blocks (one full `page_tokens`-token tuple
per edge; terminal partial-block nodes cover a prompt's trailing partial
page). Lifecycle: on admission the prompt is matched against the trie
and the hit's pages are guard-pinned, mapped into the slot's block table
(bucketed prefill inserts into private pages then `remap_shared`
deduplicates — the fused-scatter contract below demands uniquely owned
write targets — while chunked prefill `map_shared`s up front and starts
at the first divergent chunk, genuinely skipping the shared chunks'
compute); the trie pins its pages (`KVPager.pin`) so they outlive the
donor slot, `release` decrefs and frees only at refcount zero, and LRU
leaves are reclaimed under free-list pressure. A shared page is NEVER
written: the moment a slot's write cursor lands inside one (a shared
partial tail), `KVPager.cow_split` repoints the writer at a fresh page
and the engine's `page_copy` cell (`runtime.serve.build_page_copy`)
materializes the private copy first. int8 pools share their per-page
(scale, zero) leaves alongside the payload by construction (same
physical page ids). Capacity accounting is deduplicated — a prefix
shared by n slots occupies ONE page of budget (`phys_tiers()`,
`local/pool_bytes_used`); per-token footprint in closed form is
`core.access.kv_dedup_token_bytes`:

    (n_sharers * (n_tokens - shared) + shared) * token_bytes
        / (n_sharers * n_tokens)

PHYSICAL SUBSTRATE (`serving/substrate/`, `EngineConfig.substrate`):
the pager's local/pool tier map stops being bookkeeping and becomes
physical placement. `TierSubstrate` owns a host-resident TWIN of the
paged pool leaves (`models.blocks.init_pool_twin`) placed through
`runtime.sharding.named(..., memory_kind=...)` — pinned_host where the
backend supports it ("physical" mode), default memory with identical
program shapes where it doesn't ("emulated", the XLA:CPU CI fallback;
`runtime.capability.substrate_mode` resolves "auto" per backend probe).
Each decode step the engine drains the substrate: the pager's pool page
set is diffed against the twin's residency and reconciled with jitted
async transfer STREAMS — page_out (device pool -> twin, donated twin
scatter), page_in (twin gather -> device, promotion), drop (freed, no
bytes move) — every stream recorded in a completion-tracked
`SubstrateLedger` whose `page_bytes` are MEASURED from the twin arrays'
nbytes. Contract (bench-gated): after every drain,
`KVPager.pool_bytes_used() == ledger.placement_bytes()` — the virtual
clock prices exactly the bytes that physically moved. Fleet handoffs
(`fleet/roles.py`) price their page copies off the same measured
number. Prefix-cache interplay: trie-pinned pages keep ref > 0, so a
shared cold prefix stays POOL-placed across donor-slot release (one
twin page however many slots map it); reclaim drops the pin and the
next drain turns the freed pages into a drop stream.

MESH-SHARDED SERVING (`runtime.serve.make_engine_cells(mesh=...)`): all
cells jit with NamedSharding in/out shardings — KV heads over the tp
axis, slots over dp for contiguous leaves, the PAGE AXIS always
unsharded (pages are gathered through the block table, which stays
replicated as do the tokens/positions the host mutates) — see
`runtime.sharding.paged_cache_pspec`. The substrate twin carries the
same partitioning (pool_pspec), so tier transfers move per-shard
without resharding. The sharded-parity CI lane forces 8 host devices
(`--xla_force_host_platform_device_count=8`) and asserts token parity
vs the single-device engine: bit-exact for fp pools, drift-bounded for
int8.

FUSED-SCATTER CONTRACT: on the kernel backends (pallas / interpret) no
serving cell issues a standalone jnp page-scatter over the pool. The
chunked-prefill cell's chunk K/V write is fused into the paged-prefill
kernel itself — the chunk tiles (int8: pre-quantized payload +
(scale, zero) rows) are kernel operands and the pool arrays are aliased
input->output (`input_output_aliases`), killing the one-full-extra
read+write of the chunk's K/V the separate scatter cost — and the
bucket prefill-insert cell lands whole pages through the same aliased
page-writer kernel (`kernels.page_io`). The reference backend keeps the
unfused scatter-then-attend oracle, and fp-mode fused-vs-unfused cache
parity is bit-for-bit (`tests/test_kernels.py` checks both, plus a
jaxpr scan asserting the fused cells contain zero scatter ops).

FLEET LAYER (`serving/fleet/`): N engines behind a `FleetRouter` — the
paper's rack-scale thesis (placement/interference policy over a SHARED
pool decides performance, sec 6-7) applied one level up, across
engines instead of across pages. The router is pure-Python
orchestration over the engines' re-entrant tick primitives (`pump` /
`advance_to` / `begin_capture` / `capture_stats`); all engines share
ONE compiled cell set and one param tree (`FleetRouter.build`), each
with its own page pool, pager and virtual clock.

* PLACEMENT PROTOCOL (`fleet/placement.py`): a policy maps (eligible
  `EngineView` snapshots, prompt tokens) -> engine_id and is notified
  via `record` once per placed request — a pure function of the views,
  so decisions are deterministic and unit-testable without engines.
  Three policies: `round_robin` (baseline; with greedy decoding the
  token streams are placement-invariant, the CI fleet-parity lane's
  gate), `kv_aware` (queue depth / slot capacity + half-weighted pool
  pressure from free physical pages, lowest-id tie-break), and
  `prefix_aware` (a router-side radix index over page-granular token
  blocks steering shared-prefix traffic to the engine whose radix trie
  already holds those pages; kv-aware fallback on cold misses).
* ROLES + PAGE-HANDOFF LEDGER (`fleet/roles.py`): disaggregated
  prefill/decode. A prefill-role engine completes chunked prefill,
  emits the first token, guard-PINS the prompt pages and parks the
  slot in the `handoff` phase; `execute_handoff` admits the request
  into a decode-role engine, allocates destination pages
  (`KVPager.admit`), copies every paged leaf (k/v + int8 scale planes)
  along the physical-page axis, prices the transfer at pool bandwidth
  on the decode engine's clock, then the source UNPINS and releases
  (`complete_handoff`). The `TransferLedger` logs pages/bytes/latency
  per transfer. Contract: pinned pages are immutable until the copy
  lands; the destination slot starts at `start_pos = prompt_len` with
  the prefill-emitted first token.
* PRIORITIES + CANCELLATION (`queue.py`): `RequestQueue` orders by
  (priority class, arrival) — single-class traces stay bit-identical
  FIFO; requests cancel eagerly or at a virtual-time deadline, are
  dropped at the queue or swept out of slots
  (`ServingEngine.sweep_cancelled` -> `KVPager.release`).
* AUTOSCALING (`fleet/autoscale.py`): queue-depth hysteresis
  (watermarks + patience + cooldown) activates/drains engines between
  min/max; the decision loop is side-effect-free and unit-tested. A
  scale-down drains the victim engine IMMEDIATELY through the fault
  layer's migration path below — pools verified fully free, nothing
  lingers.

FAULT TOLERANCE (`serving/faults.py`, `FleetConfig.faults`): the
paper's pooled tier is a shared, link-attached resource — transfers
flake, engines die, budgets shrink — so the serving stack treats
failure as a first-class, DETERMINISTIC input rather than an
environmental accident.

* FAULT PLANS: a `FaultPlan` (seedable, frozen) names every injection
  site up front — substrate page_in/page_out transfer failure and
  fleet handoff flaking (per-site Philox streams keyed on
  crc32(site), so one site's draw sequence never depends on another's
  interleaving), engine kill/stall at decode step t, pool-page-budget
  shrink, whole-pool loss. `FaultInjector` wraps a plan with consumed
  one-shot triggers and counters; `make_plan("chaos_smoke")` et al.
  name the canonical scenarios. Every chaos run is exactly replayable.
* PREEMPTION / MIGRATION: `ServingEngine.freeze_slot` evicts a live
  slot wholesale — pages pinned and force-placed POOL (or spilled:
  released outright), a `FrozenSlot` snapshot keeps the request,
  emitted history and last token; `thaw_slot` remaps the pages and
  resumes bit-exactly. `adopt` migrates a frozen/displaced request to
  ANOTHER engine by teacher-forced refill: bucketed re-prefill of the
  prompt, then the emitted history is force-fed one token per decode
  step (other slots' clocks parked, writes masked) — greedy decode is
  deterministic per request, so the rebuilt KV is the KV, and on fp
  pools the resumed stream is bit-identical to the never-failed one.
  Admission uses the same lever: when a prompt cannot get pages, the
  lowest-priority active slot is frozen-with-spill instead of
  deadlocking the queue (`_ensure_pages_for`), and `_thaw_tick`
  restores frozen work FIFO ahead of lower-priority arrivals.
* RECOVERY POLICY: substrate transfers and handoffs retry with
  exponential backoff (`_attempt_transfer`), every failed attempt
  logged in the ledgers as a "retry" stream — wasted link bytes move,
  placement unchanged — and fatal past `max_retries`. The router's
  watchdog marks an engine dead when `pump` reports it (or a stall
  outlives `watchdog_s`), then `_recover_engine` evacuates it: queued
  work re-routes with ORIGINAL arrivals, in-flight slots re-adopt on
  survivors, and the dead engine's pool is asserted fully free (zero
  refcounts, empty placement). Pool-loss degrades the engine to
  local-only paging with tightened admission (`degrade_pool`).
  `ServeStats.faults` / `FleetStats.faults` carry the whole bill —
  retries, retry_bytes, re-prefilled tokens, preempt/restore counts,
  backoff seconds — and stay EMPTY ({}) on fault-free runs; the
  chaos-parity CI lane and the bench_fleet fault lane gate the
  headline contract: a fleet with one engine killed mid-decode and
  10% transfer flaking emits bit-identical tokens to the fault-free
  run on fp pools.

Architecture (one module per concern):

  queue.py    — `Request` / `RequestQueue` and deterministic arrival
                scenarios (chat / long-context / bursty /
                shared-prefix).
  faults.py   — deterministic fault injection: `FaultPlan` (seedable
                scenario description), `FaultInjector` (per-site
                Philox streams + one-shot triggers + counters), and
                the named `PLANS` registry — see the FAULT TOLERANCE
                section above.
  prefix_cache.py — the shared-prefix radix trie over the pager's
                physical pages: page-block keying, LRU leaf eviction,
                free-list-pressure reclaim (see the section above).
  substrate/  — the physical memory substrate: `TierSubstrate` (host
                pool twin + jitted transfer streams, drained per decode
                step) and `SubstrateLedger` (completion-tracked events,
                measured bytes, placement accounting) — see the
                PHYSICAL SUBSTRATE section above.
  speculative.py — speculative-decoding proposers and the greedy
                acceptance ladder: `ngram_propose` (self-speculative
                suffix matching, stateless) and `accept_greedy` (longest
                candidate prefix matching the verify argmaxes). The
                engine drives them per verify step; see the SPECULATIVE
                DECODING section above.
  batcher.py  — fixed-slot continuous batching: requests flow through
                `n_slots` decode lanes; admission on free slot, release on
                completion; inactive slots mask their cache writes by
                parking the write cursor out of range. With chunked
                prefill, a slot also has a `prefill` phase: occupied but
                outside the decode batch while its prompt advances one
                chunk at a time.
  kv_pager.py — the single page ALLOCATOR plus tier-aware placement: a
                shared free list hands each valid (slot, page) a physical
                page id; `block_table()` is the logical->physical map the
                engine's paged cells and the paged pallas kernels
                (`kernels/decode_attention/paged.py`,
                `kernels/flash_attention/paged_prefill.py`) chase;
                `phys_tiers()` tags every physical page local or pool.
                Hot tail pages stay local, the cold prefix is evicted to
                the pool tier by the paper's placement engine
                (`core.placement`) under the hot/cold decode traffic
                model shared with the workload catalog (`core.access`).
                `static` is the first-touch no-paging baseline; `none`
                the all-local control. With `PagerConfig.prefetch` set,
                cold-prefix page-in is prediction-driven (`repro.
                prefetch` predictor zoo): staged pool transfers overlap
                compute, demand page-ins serialize.
  engine.py   — the event loop over fixed-shape jitted cells built by
                `runtime.serve.make_engine_cells` (prefill per prompt
                bucket, one slot-batched greedy decode cell with per-slot
                positions over the page pool, page-scatter insert cells,
                and — on attention-only archs — a chunked-prefill cell
                that interleaves page-aligned prompt chunks with decode
                steps so prefill never serializes a long prompt against
                the in-flight batch; `ServeStats.decode_stall` measures
                exactly that gap). The admission controller throttles
                batch growth at the M/D/1-knee corridor budget
                (`core.interference.corridor_budget`) using cached
                `core.quantify.profile_for` submission-time metrics,
                tightened online by the pager's measured prefetch-excess
                pool traffic.

No recompilation occurs at steady state: every cell's shapes are fixed at
build time, and admissions/completions/page churn/chunk progress only flip
mask/position/block-table ARRAYS — `tests/test_serving.py` asserts the
executable-cache sizes stay constant. CI gates this subsystem three ways:
the tier-1 fast lane runs the serving tests on every push; the
paged-engine-parity lane replays `scripts/dev_serve.py --paged` with
interpret-mode pallas kernels, asserting token-for-token equality between
the paged engine and the contiguous naive loop; and the benchmark smoke
job runs `benchmarks/bench_serving` (chat / long-context / bursty /
chunked-prefill) and uploads the BENCH artifacts, including the
long-context pager-vs-static comparison that must show the tier-aware
pager cutting the remote (pool-tier) access share at equal tokens/s and
the chunked-prefill lane that must show a lower p95 decode-step stall
than serialized prefill.
"""

from repro.serving.batcher import ContinuousBatcher, Slot
from repro.serving.engine import (
    AdmissionController,
    EngineConfig,
    INT8_TOKEN_AGREEMENT,
    ServeStats,
    ServingEngine,
)
from repro.serving.faults import FaultInjector, FaultPlan, PLANS, make_plan
from repro.serving.kv_pager import KVPager, PagerConfig, StepTraffic
from repro.serving.prefix_cache import PrefixCache, PrefixHit
from repro.serving.speculative import accept_greedy, ngram_propose
from repro.serving.substrate import SubstrateLedger, TierSubstrate
from repro.serving.queue import (
    Request,
    RequestQueue,
    SCENARIOS,
    bursty_stream,
    chat_stream,
    long_context_stream,
    make_scenario,
    multi_tenant_stream,
    shared_prefix_stream,
)
from repro.serving import fleet

__all__ = [
    "AdmissionController",
    "ContinuousBatcher",
    "EngineConfig",
    "FaultInjector",
    "FaultPlan",
    "INT8_TOKEN_AGREEMENT",
    "KVPager",
    "PLANS",
    "PagerConfig",
    "PrefixCache",
    "PrefixHit",
    "Request",
    "RequestQueue",
    "SCENARIOS",
    "ServeStats",
    "ServingEngine",
    "Slot",
    "StepTraffic",
    "SubstrateLedger",
    "TierSubstrate",
    "accept_greedy",
    "bursty_stream",
    "chat_stream",
    "fleet",
    "long_context_stream",
    "make_plan",
    "make_scenario",
    "multi_tenant_stream",
    "ngram_propose",
    "shared_prefix_stream",
]
