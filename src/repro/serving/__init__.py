"""Tier-aware continuous-batching serving subsystem.

This package is the serving-side realization of the paper's quantitative
workflow: decode is the catalog's link-saturating, latency-sensitive cell,
so it is where disaggregated-memory placement and admission decisions
matter most (cf. the CXL-pooling studies arXiv:2211.02682, 2303.06420).

Architecture (one module per concern):

  queue.py    — `Request` / `RequestQueue` and deterministic arrival
                scenarios (chat / long-context / bursty).
  batcher.py  — fixed-slot continuous batching: requests flow through
                `n_slots` decode lanes; admission on free slot, release on
                completion; inactive slots mask their cache writes by
                parking the write cursor out of range.
  kv_pager.py — page-grain tier-aware KV-cache manager: hot tail pages
                local, cold prefix evicted to the pool tier, placed by the
                paper's placement engine (`core.placement`) under the
                hot/cold decode traffic model shared with the workload
                catalog (`core.access`). `static` is the first-touch
                no-paging baseline; `none` the all-local control. With
                `PagerConfig.prefetch` set, cold-prefix page-in is
                prediction-driven (`repro.prefetch` predictor zoo):
                staged pool transfers overlap compute, demand page-ins
                serialize, and `block_table()` exposes the
                logical->physical page map the paged decode-attention
                kernel gathers through.
  engine.py   — the event loop over fixed-shape jitted cells built by
                `runtime.serve.make_engine_cells` (prefill per prompt
                bucket, one slot-batched greedy decode cell with per-slot
                positions, cache-splice cells), plus the admission
                controller that throttles batch growth at the M/D/1-knee
                corridor budget (`core.interference.corridor_budget`)
                using cached `core.quantify.profile_for` submission-time
                metrics.

No recompilation occurs at steady state: every cell's shapes are fixed at
build time and admissions/completions only flip mask/position vectors —
`tests/test_serving.py` asserts the executable-cache sizes stay constant.
CI gates this subsystem twice: the tier-1 fast lane runs the serving tests
on every push, and the benchmark smoke job runs `benchmarks/bench_serving`
(chat / long-context / bursty) and uploads the BENCH artifacts, including
the long-context pager-vs-static comparison that must show the tier-aware
pager cutting the remote (pool-tier) access share at equal tokens/s.
"""

from repro.serving.batcher import ContinuousBatcher, Slot
from repro.serving.engine import (
    AdmissionController,
    EngineConfig,
    ServeStats,
    ServingEngine,
)
from repro.serving.kv_pager import KVPager, PagerConfig, StepTraffic
from repro.serving.queue import (
    Request,
    RequestQueue,
    SCENARIOS,
    bursty_stream,
    chat_stream,
    long_context_stream,
    make_scenario,
)

__all__ = [
    "AdmissionController",
    "ContinuousBatcher",
    "EngineConfig",
    "KVPager",
    "PagerConfig",
    "Request",
    "RequestQueue",
    "SCENARIOS",
    "ServeStats",
    "ServingEngine",
    "Slot",
    "StepTraffic",
    "bursty_stream",
    "chat_stream",
    "long_context_stream",
    "make_scenario",
]
