"""Tier-aware continuous-batching serving engine over a physical paged-KV
runtime.

The engine turns the one-shot prefill+decode loop of `launch/serve.py` into
an event loop over fixed-shape jitted cells (`runtime.serve.
make_engine_cells`):

  admit   — pop arrived requests while slots are free AND the admission
            controller projects the pool link below the M/D/1 knee; run
            the bucketed prefill cell and scatter the request's caches
            into the slot batch (or, with `prefill_chunk` set, park the
            request in a prefilling slot), emit its first greedy token;
  decode  — one step of the whole slot batch with per-slot positions
            (inactive slots are masked by parked write cursors);
  chunk   — with chunked prefill enabled, at most one page-aligned prompt
            chunk advances between decode steps, so a long prompt never
            stalls in-flight decode for more than one chunk (the
            prefill-serializes-against-decode fix; `ServeStats` reports
            the p95 inter-decode-step stall this is for);
  retire  — completed requests free their slot and their KV pages.

In paged mode (the default) the KV cache IS a physical page pool: the
`KVPager` is the single allocator — its free list hands out physical
pages, its `block_table()` is what every decode/insert/chunk cell reads
and writes the cache through, and its tier tags price every byte. Tier
awareness lives in two places:

* the `KVPager` keeps each slot's hot KV tail in the local tier and evicts
  the cold prefix to the pool tier (hot/cold per `core.access`'s decode
  traffic model, placement per `core.placement` — the same engine
  `runtime/tiering.py` uses at tensor grain for training state);
* the `AdmissionController` consults the catalog profile (cached
  `core.quantify.profile_for`, the paper's §7.2 submission-time metrics)
  for a prior per-slot injected LoI, refines it with the pager's measured
  traffic, and throttles batch growth when the projected pool-link LoI —
  plus the pager's measured prefetch EXCESS traffic (speculative
  transfers that never paid off are still pool-link interference, the
  paper's SuperLU 37% case) — would cross the corridor budget
  (`core.interference.corridor_budget`, the M/D/1 knee).

The clock is dual: wall time measures what this host actually does;
virtual time prices each step on the target tier topology (compute from
the decode roofline, local/pool bytes from the pager, staged pool
transfers overlapped with compute in the layer-ahead regime —
`prefetch.static` — while demand page-ins serialize). Latency metrics
(TTFT/TPOT/stall) are virtual; throughput is reported on both clocks.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import hw
from repro.common.config import SHAPES, ModelConfig
from repro.common.parallel import ParallelCtx
from repro.common.pytree import leaf_bytes
from repro.core import interference as itf
from repro.core import roofline as rl
from repro.core import tiers as tr
from repro.models import model as M
from repro.models.frontends import synthetic_frontend_embeds
from repro.runtime import capability
from repro.runtime import serve as serve_rt
from repro.serving.batcher import ContinuousBatcher
from repro.serving.kv_pager import LOCAL, KVPager, PagerConfig
from repro.serving.prefix_cache import PrefixCache
from repro.serving.queue import Request, RequestQueue
from repro.serving.substrate import TierSubstrate

# Minimum per-request greedy-token agreement an int8 pool must keep vs
# the fp reference: per-page block quantization bounds logit drift, but a
# near-tie can flip a token and diverge the suffix, so parity is measured
# as prefix agreement, not exactness. Shared by dev_serve's CI lanes and
# the prefix-cache parity tests (an int8 pool with the prefix cache ON
# dequantizes the same shared (payload, scale, zero) pages every sharer,
# so ON-vs-OFF drift stays inside the same bar).
INT8_TOKEN_AGREEMENT = 0.5


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8
    max_seq: int = 128              # prompt+gen per slot (excl. vision pfx)
    prefill_buckets: tuple = (32,)
    # --- paged-KV runtime ---
    paged: bool = True              # cache = physical page pool + block
    # tables end-to-end (False keeps the per-slot contiguous layout — the
    # refactor's safety net, token-for-token identical)
    pool_dtype: str = "int8"        # pool payload (models.blocks.
    # POOL_DTYPES): "int8" per-page block quantization — the DEFAULT now
    # that the substrate makes pool bytes physical placement (~4x fewer
    # pool bytes per cached token, host-side too, at a bounded logit
    # drift; quantize-on-insert, dequantize-in-kernel); "fp" stores
    # cfg.dtype bit-identically (the exact safety net the parity gates
    # pin), "bf16" a 2-byte cast
    prefill_chunk: Optional[int] = None   # tokens per prefill chunk
    # (paged, attention-only archs): interleave prompt chunks with decode
    # steps instead of serializing whole prompts against the batch
    # --- pager ---
    page_tokens: int = 16
    local_budget_frac: Optional[float] = 0.5   # of peak KV bytes; None=all
    local_budget_bytes: Optional[float] = None  # ABSOLUTE local budget,
    # overriding the fraction — the knob for cross-pool-dtype comparisons
    # (same HBM, smaller pooled footprint: an int8 pool fits ~4x more
    # pages locally than fp32 under the same byte budget)
    pager_policy: str = "hotness"              # hotness | static | none
    hot_window: int = 32
    cold_touch: float = 0.05
    # prediction-driven page-in (repro.prefetch): None = legacy weighted
    # accounting, "demand" = discrete demand-paging baseline, else a
    # predictor name whose staged page-ins overlap compute
    prefetch: Optional[str] = None
    prefetch_degree: int = 8
    # --- shared-prefix radix cache (serving.prefix_cache) ---
    prefix_cache: bool = False      # dedup page-aligned shared prompt
    # prefixes across requests: trie match on admission maps cached pages
    # into the slot's block table (refcounted; prefill skipped for the
    # matched prefix in virtual time — chunked prefill genuinely starts
    # at the first divergent page), COW split on first write into a
    # shared tail page. Paged mode only; frontend/encoder archs excluded
    # (per-request embeds/cross-KV make "same tokens" != "same KV")
    prefix_cache_pages: Optional[int] = None   # trie capacity cap (pages);
    # None = bounded only by free-list pressure (LRU reclaim on demand)
    # --- physical memory substrate (serving.substrate) ---
    substrate: str = "auto"         # off | emulated | physical | auto —
    # realize the pool tier as a host-resident twin of the paged leaves
    # (pinned_host NamedSharding where the backend supports it) kept in
    # sync by async jitted transfer streams with a completion ledger;
    # "auto" resolves per runtime.capability probes (physical on TPU,
    # emulated on XLA:CPU — identical program shapes and accounting)
    # --- admission ---
    admission: str = "loi"                     # loi | greedy
    knee_excess: float = 0.75
    catalog_arch: Optional[str] = None         # profile_for prior (paper
    catalog_shape: str = "decode_32k"          # §7.2 submission metrics)
    # --- speculative decoding (serving.speculative) ---
    speculative: str = "off"        # off | ngram | draft — propose
    # speculative_k-1 draft tokens per slot and score all k candidates in
    # ONE paged verify call (runtime.serve.build_decode_verify_paged);
    # greedy acceptance keeps the token stream bit-identical to plain
    # greedy decode while each pool sweep yields 1 + accepted tokens.
    # Paged + attention-only archs; int8 pools switch to the per-token
    # sub-scale layout automatically (sz_granularity="token")
    speculative_k: int = 4          # candidates per verify call (>= 2)
    draft_arch: Optional[str] = None   # draft model for "draft" mode:
    # an arch name resolved through configs.reduced (this stack only ever
    # instantiates reduced models), or None to draft with the TARGET
    # arch itself — deterministic PRNGKey(0) weights either way, shared
    # engine-wide through EngineCells
    # --- virtual clock ---
    step_overhead_s: float = 5e-6              # host dispatch/launch floor
    # per decode step; keeps the virtual clock of tiny reduced models in a
    # physically plausible regime so arrival processes actually overlap


class AdmissionController:
    """Throttle slot admissions at the projected pool-link LoI knee.

    Projection: per-slot LoI = one slot's share of pool-link utilization,
    seeded from the catalog profile (`profile_for(arch, shape)` — cached,
    computed once per workload exactly like PR 1's scheduler does at
    submission time) and refined online with an EMA of the pager's
    measured pool time per step. Admitting slot n+1 is allowed while
    (n+1) * per_slot_loi PLUS the measured prefetch-excess LoI stays
    under the corridor budget — the same derived M/D/1-knee budget the
    rack scheduler's binpack policy packs against. Excess counts because
    a speculative prefetcher's fetched-but-unused pages occupy the same
    link the admitted slots must share (`PrefetchEngine`'s excess metric,
    fed back here just as `core.access.with_prefetch_excess` feeds it
    back into catalog profiles): the more the pager mispredicts, the
    earlier admission closes."""

    EMA = 0.5

    def __init__(self, topo: tr.TierTopology, *, mode: str = "loi",
                 knee_excess: float = 0.75, prior_loi: float = 0.0):
        if mode not in ("loi", "greedy"):
            raise ValueError(f"unknown admission mode {mode!r}")
        self.mode = mode
        self.budget = itf.corridor_budget(topo, knee_excess)
        self.per_slot_loi = float(prior_loi)
        self.excess_loi = 0.0
        self.blocks = 0

    @classmethod
    def from_catalog(cls, topo, arch: Optional[str], shape_name: str,
                     **kw) -> "AdmissionController":
        prior = 0.0
        if arch is not None:
            from repro.core.quantify import profile_for  # lazy: pulls jax

            prof = profile_for(arch, shape_name, use_dryrun=False)
            prior = prof.injected_loi() / SHAPES[shape_name].global_batch
        return cls(topo, prior_loi=prior, **kw)

    def observe(self, n_active: int, t_pool: float, dt: float,
                t_excess: float = 0.0) -> None:
        """`t_excess`: pool-link seconds this step spent on prefetched
        pages that never became useful (the pager's excess traffic)."""
        if n_active < 1 or dt <= 0.0:
            return
        measured = min(1.0, t_pool / dt) / n_active
        self.per_slot_loi = (
            (1 - self.EMA) * self.per_slot_loi + self.EMA * measured
        )
        self.excess_loi = (
            (1 - self.EMA) * self.excess_loi
            + self.EMA * min(1.0, max(t_excess, 0.0) / dt)
        )

    def projected_loi(self, n_slots: int) -> float:
        return min(1.0, n_slots * self.per_slot_loi)

    def admit(self, n_active: int) -> bool:
        if self.mode == "greedy" or n_active == 0:
            return True     # never deadlock an idle engine
        ok = (self.projected_loi(n_active + 1) + self.excess_loi
              <= self.budget)
        if not ok:
            self.blocks += 1
        return ok


@dataclasses.dataclass
class ServeStats:
    n_requests: int
    tokens: int
    steps: int
    wall_s: float
    virtual_s: float
    ttft: np.ndarray               # per request, virtual seconds
    tpot: np.ndarray               # per generated token (after the first)
    decode_stall: np.ndarray       # virtual gap between consecutive decode
    #             steps (admissions/prefill chunks land in these gaps — the
    #             prefill-serializes-against-decode stall made measurable)
    pager: dict
    admission_blocks: int
    max_concurrency: int
    prefix: dict = dataclasses.field(default_factory=dict)   # prefix-cache
    # counter deltas for this run (empty when the cache is off)
    substrate: dict = dataclasses.field(default_factory=dict)  # transfer-
    # ledger deltas (serving.substrate) for this run; placement_bytes /
    # resident_pages are end-of-run levels (empty when the substrate is
    # off)
    spec: dict = dataclasses.field(default_factory=dict)   # speculative-
    # decoding deltas: verify_steps / emitted / draft_calls /
    # accept_len_mean (tokens per verify step, = 1 + mean accepted
    # drafts). Empty when speculation is off. `tokens` above already
    # counts every ACCEPTED token (multi-token steps append each emitted
    # token to the request output), so tok_per_s_* and bytes-per-token
    # ratios need no special-casing
    faults: dict = dataclasses.field(default_factory=dict)  # fault-
    # recovery deltas (serving.faults): preempts / restores / spills /
    # migrations_in / reprefilled_tokens (recovery overhead: prompt +
    # history tokens recomputed by teacher-forced refill) / retries /
    # retry_bytes (failed substrate transfer attempts) / backoff_s
    # (virtual seconds the clock charged for retry backoff). Empty on
    # fault-free runs, so existing summaries and baselines are untouched

    def summary(self) -> Dict[str, float]:
        def pct(a, q):
            return float(np.percentile(a, q)) if len(a) else float("nan")

        out = {
            "n_requests": self.n_requests,
            "tokens": self.tokens,
            "steps": self.steps,
            "tok_per_s_wall": self.tokens / max(self.wall_s, 1e-9),
            "tok_per_s_virtual": self.tokens / max(self.virtual_s, 1e-12),
            "ttft_p50_s": pct(self.ttft, 50),
            "tpot_p50_s": pct(self.tpot, 50),
            "tpot_p99_s": pct(self.tpot, 99),
            "stall_p95_s": pct(self.decode_stall, 95),
            "remote_share": self.pager["remote_share"],
            "demand_share": self.pager.get("demand_share", 0.0),
            "admission_blocks": self.admission_blocks,
            "max_concurrency": self.max_concurrency,
        }
        if self.prefix:
            out["prefix_hit_rate"] = self.prefix["hit_rate"]
            out["cow_splits"] = self.pager.get("cow_splits", 0)
        if self.substrate:
            # MEASURED physical tier traffic (real array nbytes on the
            # transfer streams), the regression-gated bench metric
            out["substrate_transfer_bytes"] = (
                self.substrate["page_out_bytes"]
                + self.substrate["page_in_bytes"]
                + self.substrate["handoff_bytes"]
            )
            out["substrate_placement_bytes"] = \
                self.substrate["placement_bytes"]
        if self.spec:
            out["accept_len_mean"] = self.spec["accept_len_mean"]
            out["verify_steps"] = self.spec["verify_steps"]
        if self.faults:
            out["fault_preempts"] = self.faults["preempts"]
            out["fault_restores"] = self.faults["restores"]
            out["fault_retries"] = self.faults["retries"]
            out["fault_retry_bytes"] = self.faults["retry_bytes"]
            out["recovery_overhead_tokens"] = \
                self.faults["reprefilled_tokens"]
        return out


_PAGED_KEYS = ("k", "v", "k_sz", "v_sz")


@dataclasses.dataclass
class HandoffRecord:
    """A completed prefill awaiting pool transfer to a decode-role engine
    (disaggregated prefill/decode, `serving.fleet.roles`). The prefill
    engine emitted the first token and parked the slot in the `handoff`
    phase; `pages` are the slot's physical prompt pages, guard-pinned so
    nothing (COW splits, prefix-cache reclaim) can recycle them before
    the transfer copies their payload out. `complete_handoff` drops the
    pin and releases the slot."""

    slot: int
    request: object               # serving.queue.Request
    first_token: int
    n_tokens: int                 # cached prompt tokens to transfer
    pages: List[int]              # physical page ids, logical order
    t_emit: float                 # prefill engine's clock at completion


@dataclasses.dataclass
class FrozenSlot:
    """A preempted in-flight request (slot preemption/migration — see
    `freeze_slot`). Two flavors: a PINNED freeze keeps the slot's
    physical pages alive under a freeze pin (tagged pool tier, so the
    substrate spills their payload host-side on the next drain) and
    `thaw_slot` remaps them wholesale; a SPILLED freeze (`pages is
    None`) released the pages entirely — restore runs the teacher-forced
    refill of prompt + emitted history (`adopt`), which is also how a
    dead engine's in-flight requests migrate to a live one."""

    request: object               # serving.queue.Request
    length: int                   # cached tokens at freeze (== slot.t)
    emitted: int                  # tokens generated before the freeze
    last_token: int               # next decode step's feed token
    pages: Optional[np.ndarray]   # physical page ids; None = spilled
    t_frozen: float               # engine clock at preemption


def _kv_bytes_per_token(acaches) -> float:
    """Self-attention K/V bytes per cached token per slot, from the global
    abstract cache tree — DTYPE-AWARE: the payload contribution follows
    each k/v leaf's dtype (4B fp32, 2B bf16, 1B int8), and an int8 pool's
    per-page float32 (scale, zero) leaves are amortized over the page's
    tokens, so the pager, `phys_tiers()` and the admission corridor all
    price the real pooled footprint (`core.access.kv_pool_token_bytes`
    is the closed-form twin of this walk)."""
    total = 0.0
    for pos, c in acaches.items():
        if "k" not in c:
            continue
        k = c["k"]
        tokens = k.shape[1] * k.shape[2]   # paged: P_phys * page_tokens;
        # dense: slots * max_seq — both are total cached token-slots
        for key in _PAGED_KEYS:
            if key in c:
                total += leaf_bytes(c[key]) / tokens
    return total


def _resident_bytes_per_slot(acaches) -> float:
    """Per-slot bytes of the non-paged decode state (SSM state, conv
    tails, cross-attention KV) — pinned local, streamed every step."""
    total = 0.0
    for pos, c in acaches.items():
        for key, leaf in c.items():
            if key not in _PAGED_KEYS:
                total += leaf_bytes(leaf) / leaf.shape[1]
    return total


class ServingEngine:
    """Continuous-batching serve loop over fixed-shape cells."""

    def __init__(self, cfg: ModelConfig, ctx: ParallelCtx,
                 ecfg: EngineConfig, params,
                 cells: serve_rt.EngineCells,
                 topo: Optional[tr.TierTopology] = None):
        self.cfg = cfg
        self.ctx = ctx
        self.ecfg = ecfg
        self.params = params
        self.cells = cells
        self.topo = topo or tr.v5e_topology()

        self.npfx = cells.n_prefix
        # paged mode parks PAST the pool's page-aligned position space:
        # a parked position inside the last partial logical page would
        # pass the page-range guard and scribble into physical page 0
        # through the slot's zeroed block-table row
        park = (cells.n_pages * cells.page_tokens if cells.paged
                else cells.max_seq_total)
        self.batcher = ContinuousBatcher(
            ecfg.n_slots, ecfg.prefill_buckets, park_pos=park,
        )
        kv_tok = _kv_bytes_per_token(cells.abstract_caches)
        resident = _resident_bytes_per_slot(cells.abstract_caches)
        budget = None
        if ecfg.local_budget_bytes is not None:
            budget = float(ecfg.local_budget_bytes)
        elif ecfg.local_budget_frac is not None:
            peak = kv_tok * cells.max_seq_total * ecfg.n_slots
            budget = ecfg.local_budget_frac * peak
        self.pager = KVPager(
            ecfg.n_slots, cells.max_seq_total, kv_tok, resident,
            PagerConfig(
                page_tokens=ecfg.page_tokens,
                local_budget_bytes=budget,
                policy=ecfg.pager_policy,
                hot_window=ecfg.hot_window,
                cold_touch=ecfg.cold_touch,
                prefetch=ecfg.prefetch,
                prefetch_degree=ecfg.prefetch_degree,
            ),
            topo=self.topo,
        )
        self.prefix_cache: Optional[PrefixCache] = None
        if ecfg.prefix_cache:
            if not cells.paged:
                raise ValueError(
                    "prefix_cache needs paged=True: sharing happens by "
                    "aliasing block-table rows onto one physical page"
                )
            if cfg.frontend or cfg.num_encoder_layers:
                raise ValueError(
                    "prefix_cache requires token-only decoder archs: "
                    "frontend embeds and encoder cross-KV are per-request "
                    "state, so identical prompt tokens do not imply "
                    "identical cached KV"
                )
            if not serve_rt.chunked_prefill_supported(cfg):
                raise ValueError(
                    f"{cfg.name}: prefix_cache needs an attention-only "
                    "decoder stack — SSM/conv state is a per-slot "
                    "recurrence, not page-addressable KV, so aliasing "
                    "block-table rows shares nothing there"
                )
            self.prefix_cache = PrefixCache(
                ecfg.page_tokens, capacity_pages=ecfg.prefix_cache_pages,
            )
            # wire the free-list-pressure callback: the allocator evicts
            # LRU trie leaves before declaring the pool exhausted
            self.pager.prefix_cache = self.prefix_cache
        self.admission = AdmissionController.from_catalog(
            self.topo, ecfg.catalog_arch, ecfg.catalog_shape,
            mode=ecfg.admission, knee_excess=ecfg.knee_excess,
        )
        if cells.paged:
            self.caches = M.make_paged_decode_caches(
                cfg, ecfg.n_slots, cells.max_seq_total, cells.page_tokens,
                enc_len=self._enc_len(), pool_dtype=cells.pool_dtype,
                sz_granularity=cells.sz_granularity,
            )
        else:
            self.caches = M.make_decode_caches(
                cfg, ecfg.n_slots, cells.max_seq_total,
                enc_len=self._enc_len(),
            )
        if cells.cache_shardings is not None:
            self.caches = jax.device_put(self.caches, cells.cache_shardings)
        # physical memory substrate: host-resident pool twin reconciled
        # against the pager's tier map once per decode step. Disabled
        # when requested off, on the contiguous layout, and on cache
        # trees with no paged leaves (SSM-only stacks).
        self.substrate: Optional[TierSubstrate] = None
        if cells.paged and ecfg.substrate != "off":
            mode = capability.substrate_mode(ecfg.substrate)
            pool_pspec = None
            if cells.cache_shardings is not None:
                # twin carries the pool's own partitioning: per-shard
                # transfer streams, no resharding on the way out/in
                pool_pspec = {
                    pos: {k: cells.cache_shardings[pos][k].spec
                          for k in _PAGED_KEYS if k in c}
                    for pos, c in self.caches.items()
                    if any(k in c for k in _PAGED_KEYS)
                }
            sub = TierSubstrate(
                self.caches, ctx.mesh, mode, pool_pspec=pool_pspec,
                host_memory_kind=(self.topo.pool.memory_kind
                                  or "pinned_host"))
            if sub.enabled:
                self.substrate = sub
        self.tokens = np.zeros(ecfg.n_slots, dtype=np.int32)
        # --- speculative decoding (serving.speculative) ---
        self.spec_verify_steps = 0     # verify calls (speculative steps)
        self.spec_slot_steps = 0       # per-slot verify rows (sum active)
        self.spec_emitted = 0          # tokens committed by verify steps
        self.spec_draft_calls = 0      # draft-cell invocations
        self.draft_caches = None
        self._draft_fed = np.zeros(ecfg.n_slots, dtype=np.int64)
        self._draft_park = 0
        self._draft_tok_bytes = 0.0
        self._draft_params_n = 0
        if cells.draft_fn is not None:
            # contiguous fp scratch caches for the draft model, sized so
            # the k-1 self-fed proposal positions fit past max_seq_total
            dseq = cells.max_seq_total + cells.spec_k
            self.draft_caches = M.make_decode_caches(
                cells.draft_cfg, ecfg.n_slots, dseq,
            )
            self._draft_park = dseq
            total_b = sum(leaf_bytes(leaf) for leaf in
                          jax.tree.leaves(self.draft_caches))
            self._draft_tok_bytes = total_b / (ecfg.n_slots * dseq)
            self._draft_params_n = cells.draft_cfg.active_param_count()
        self._active_params = cfg.active_param_count()
        self.steps = 0
        self.virtual_s = 0.0
        self._t_compute_s = 0.0
        self._prev_excess_b = 0.0      # pager excess fed to admission
        self._decode_gaps: List[float] = []
        self._last_decode_end: Optional[float] = None
        self._bt_host = None           # block-table upload cache: the
        self._bt_dev = None            # pager returns the SAME array
        # object until the mapping changes, so steady-state decode skips
        # the per-step host->device transfer by identity
        self._max_conc = 0
        self.cancelled = 0             # in-flight cancellations swept
        # --- disaggregated prefill/decode (serving.fleet.roles) ---
        self.handoff_role = False      # True: completed chunked prefills
        # park in the `handoff` phase and queue a HandoffRecord instead of
        # joining this engine's decode batch
        self.handoff_outbox: List[HandoffRecord] = []
        # --- fault tolerance (serving.faults) ---
        self.faults = None             # FaultInjector; the fleet router
        # wires it (and engine_id) after build — unset means every fault
        # site is dormant and the engine behaves byte-identically to
        # pre-fault builds
        self.engine_id = 0
        self.frozen: List[FrozenSlot] = []   # preempted slots, FIFO
        self._dead = False
        self._stall_until = 0.0
        self._degraded = False         # pool tier lost -> local-only
        self._fault_counters: Dict[str, float] = {
            "preempts": 0, "restores": 0, "spills": 0,
            "migrations_in": 0, "reprefilled_tokens": 0,
            "budget_shrinks": 0, "degraded": 0, "backoff_s": 0.0,
        }

    # ------------------------------------------------------------- build
    @classmethod
    def build(cls, cfg: ModelConfig, ctx: ParallelCtx, ecfg: EngineConfig,
              *, params=None, mesh=None, rules=None, seed: int = 0,
              topo=None) -> "ServingEngine":
        enc_len = (
            max(ecfg.prefill_buckets) if cfg.num_encoder_layers else 0
        )
        draft_cfg = None
        if ecfg.speculative == "draft":
            if ecfg.draft_arch is None:
                draft_cfg = cfg      # self-draft: the target drafts for
                # itself (perfect-proposer ceiling; useful for parity and
                # acceptance-dynamics testing)
            else:
                from repro import configs

                draft_cfg = dataclasses.replace(
                    configs.reduced(ecfg.draft_arch), dtype=cfg.dtype,
                )
        # int8 pools flip to per-token sub-scales under speculation: the
        # verify cell's k candidate rows land in one tail page, which the
        # per-page requantize round trip cannot do collision-free
        sz_gran = ("token" if ecfg.speculative != "off"
                   and ecfg.pool_dtype == "int8" else "page")
        cells = serve_rt.make_engine_cells(
            cfg, ctx, rules, mesh,
            n_slots=ecfg.n_slots, max_seq=ecfg.max_seq,
            buckets=ecfg.prefill_buckets, enc_len=enc_len,
            paged=ecfg.paged, page_tokens=ecfg.page_tokens,
            prefill_chunk=ecfg.prefill_chunk or 0,
            pool_dtype=ecfg.pool_dtype,
            sz_granularity=sz_gran,
            speculative=ecfg.speculative, spec_k=ecfg.speculative_k,
            draft_cfg=draft_cfg,
        )
        if params is None:
            params, _ = M.init_model(cfg, jax.random.PRNGKey(seed))
        return cls(cfg, ctx, ecfg, params, cells, topo=topo)

    def _enc_len(self) -> int:
        return (
            max(self.ecfg.prefill_buckets)
            if self.cfg.num_encoder_layers else 0
        )

    def compile_counts(self) -> Dict[str, int]:
        return self.cells.compile_counts()

    # ------------------------------------------------------------ admit
    def _frontend_extras(self, req: Request, bucket: int) -> dict:
        extras = {}
        if self.cfg.frontend in ("vision_stub", "audio_stub"):
            key = jax.random.fold_in(jax.random.PRNGKey(17), req.request_id)
            emb = synthetic_frontend_embeds(self.cfg, 1, bucket, key)
            name = ("patches" if self.cfg.frontend == "vision_stub"
                    else "frames")
            extras[name] = emb
        return extras

    def _admit(self, req: Request, now: float) -> None:
        if req.output:
            raise ValueError(
                f"request {req.request_id} was already served — build a "
                "fresh trace per run (Request objects are consumed)"
            )
        if req.prompt_len + req.max_new_tokens > self.ecfg.max_seq:
            raise ValueError(
                f"request {req.request_id}: prompt+gen exceeds max_seq "
                f"{self.ecfg.max_seq}"
            )
        if self.cells.chunk_fn is not None:
            self._admit_chunked(req, now)
            return
        bucket = self.batcher.bucket_for(req.prompt_len)
        # shared-prefix match BEFORE any allocation, guard-pinned so the
        # admission's own page allocation cannot reclaim the matched trie
        # pages out from under the hit
        hit = None
        if self.prefix_cache is not None:
            hit = self.prefix_cache.match(req.tokens)
            if hit is not None:
                self.pager.pin(hit.all_pages)
        batch = {"tokens": jnp.asarray(req.tokens[None, :]),
                 **self._frontend_extras(req, bucket)}
        slot_caches, tok = self.cells.prefill_fns[bucket](self.params, batch)
        start = self.npfx + req.prompt_len
        slot = self.batcher.admit(req, start_pos=start)
        # the pager allocates BEFORE the insert: in paged mode the insert
        # cell scatters through the block table, so the slot's pages must
        # already be owned (in dense mode the order is irrelevant)
        self.pager.admit(slot.index, start)
        if self.cells.paged:
            self.caches = self.cells.insert_fns[bucket](
                self.caches, slot_caches, np.int32(slot.index),
                self._block_table_dev(),
            )
        else:
            self.caches = self.cells.insert_fns[bucket](
                self.caches, slot_caches, np.int32(slot.index)
            )
        n_matched = 0
        if self.prefix_cache is not None:
            if hit is not None:
                # insert-then-dedupe: the fused insert scattered the full
                # prompt into private pages (its kernel contract demands
                # uniquely owned targets); the matched prefix now remaps
                # onto the trie's bit-identical pages and the duplicates
                # free — so the matched pages cost no pool capacity and,
                # below, no prefill time
                self.pager.remap_shared(slot.index, hit.all_pages)
                self.pager.unpin(hit.all_pages)
                n_matched = hit.n_tokens
            self.prefix_cache.insert(
                req.tokens, self.pager.phys[slot.index], self.pager,
                include_partial=True,
            )
        self.virtual_s += self._prefill_dt(start - n_matched)
        first = int(np.asarray(tok)[0])
        self.tokens[slot.index] = first
        req.admitted = now
        req.output.append(first)
        req.token_times.append(self.virtual_s)
        if req.done:                      # max_new_tokens == 1
            req.finished = self.virtual_s
            self._retire(slot)

    def _admit_chunked(self, req: Request, now: float) -> None:
        """Chunked admission: the request only claims a slot; its prompt
        advances chunk-by-chunk in `_prefill_tick`, interleaved with
        decode steps."""
        C = self.cells.chunk
        if req.prompt_len <= 0 or req.prompt_len % C:
            raise ValueError(
                f"request {req.request_id}: prompt_len {req.prompt_len} "
                f"must be a positive multiple of prefill_chunk {C}"
            )
        # prefix-cache hit: map the matched full pages shared and start
        # chunking at the first divergent CHUNK — those chunks never tick,
        # so their compute and virtual prefill time are genuinely skipped.
        # The final chunk always runs (its logits emit the first token),
        # so the slot's write frontier never lands inside a shared page
        # from this path (COW comes from the bucket path's partial tails).
        n_share = 0
        shared_pages: List[int] = []
        if self.prefix_cache is not None:
            hit = self.prefix_cache.match(req.tokens)
            if hit is not None:
                n_share = (min(hit.n_full_tokens, req.prompt_len - C)
                           // C) * C
                if n_share > 0:
                    shared_pages = hit.pages[
                        :n_share // self.ecfg.page_tokens]
                    self.pager.pin(shared_pages)   # guard pin
                else:
                    n_share = 0
        slot = self.batcher.admit(req, start_pos=0, phase="prefill",
                                  prefill_pos=n_share)
        if n_share:
            self.pager.map_shared(slot.index, shared_pages, n_share)
            self.pager.unpin(shared_pages)
        req.admitted = now

    def _prefill_tick(self) -> bool:
        """Advance the oldest mid-prefill request by ONE chunk (chunked
        mode only). Returns True if a chunk ran — at most one per engine
        loop iteration, so prefill interleaves with decode instead of
        serializing a whole prompt against the batch."""
        if self.cells.chunk_fn is None:
            return False
        slots = self.batcher.prefilling_slots()
        if not slots:
            return False
        slot = slots[0]
        req = slot.request
        C = self.cells.chunk
        end = slot.prefill_pos + C
        self.pager.extend(slot.index, end)      # own the pages first
        toks = jnp.asarray(req.tokens[None, slot.prefill_pos:end])
        tok, self.caches = self.cells.chunk_fn(
            self.params, toks, self.caches, np.int32(slot.index),
            np.int32(slot.prefill_pos // C),
            self._block_table_dev(),
        )
        self.virtual_s += self._prefill_dt(C, final=(end == req.prompt_len))
        slot.prefill_pos = end
        if end == req.prompt_len:
            if self.prefix_cache is not None:
                # chunked prompts are page-multiples: full blocks only
                self.prefix_cache.insert(
                    req.tokens, self.pager.phys[slot.index], self.pager,
                    include_partial=False,
                )
            first = int(np.asarray(tok)[0])
            req.output.append(first)
            req.token_times.append(self.virtual_s)
            if req.done:                  # max_new_tokens == 1
                self.batcher.begin_decode(slot, start_pos=req.prompt_len)
                req.finished = self.virtual_s
                self._retire(slot)
            elif self.handoff_role:
                # disaggregated prefill role: do NOT join this engine's
                # decode batch — park the slot (its write cursor stays
                # masked), guard-pin the prompt pages, and queue the
                # handoff for the fleet router's pool-transfer ledger
                n_pages = -(-req.prompt_len // self.ecfg.page_tokens)
                pages = [int(p) for p in
                         self.pager.phys[slot.index, :n_pages]]
                self.pager.pin(pages)
                slot.phase = "handoff"
                self.handoff_outbox.append(HandoffRecord(
                    slot=slot.index, request=req, first_token=first,
                    n_tokens=req.prompt_len, pages=pages,
                    t_emit=self.virtual_s,
                ))
            else:
                self.batcher.begin_decode(slot, start_pos=req.prompt_len)
                self.tokens[slot.index] = first
        return True

    def complete_handoff(self, rec: HandoffRecord) -> None:
        """The transfer copied `rec`'s pages into the decode engine's
        pool: drop the guard pin and release the prefill slot (its pages
        return to this engine's free list unless the prefix trie still
        holds them)."""
        slot = self.batcher.slots[rec.slot]
        if slot.request is not rec.request:
            raise RuntimeError(
                f"handoff slot {rec.slot} no longer owns request "
                f"{rec.request.request_id}"
            )
        self.pager.unpin(rec.pages)
        self._retire(slot)

    # ------------------------------------ fault tolerance (serving.faults)
    def _pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.ecfg.page_tokens)

    def _reclaimable(self, need: int) -> bool:
        """Can `need` pages be produced without preempting anyone? Free
        pages plus trie-cached pages (LRU-reclaimable clean copies).
        This OVER-estimates — trie pages aliased by live slots survive
        reclaim — so a pass here can still exhaust in `_take_free`,
        which is exactly the pre-preemption failure mode (no admission
        the old allocator accepted is ever blocked)."""
        free = self.pager.counters()["free_pages"]
        cached = (self.prefix_cache.counters()["cached_pages"]
                  if self.prefix_cache is not None else 0)
        return free + cached >= need

    def _preempt_victim(self, priority: int):
        """The active decode slot to freeze for an incoming request of
        `priority`: strictly LOWER class only (higher priority number),
        youngest admission within the lowest class — preempting equals
        or betters never happens, so thaw cannot cycle."""
        victims = [s for s in self.batcher.slots
                   if s.active and s.request.priority > priority]
        if not victims:
            return None
        return max(victims, key=lambda s: (s.request.priority, s.seq))

    def _ensure_pages_for(self, req: Request) -> bool:
        """Make room for `req`'s prompt pages, spill-freezing strictly
        lower-priority decode slots if the pool cannot otherwise supply
        them. Returns False (leave `req` queued — NOT the old
        pool-exhausted RuntimeError) when no victim exists. Bucket-path
        paged mode only: the chunked path allocates per-chunk and the
        dense path has no shared pool to exhaust."""
        if not self.cells.paged or self.cells.chunk_fn is not None:
            return True
        need = self._pages_needed(self.npfx + req.prompt_len)
        while not self._reclaimable(need):
            victim = self._preempt_victim(req.priority)
            if victim is None:
                return False
            self.freeze_slot(victim, spill=True)
        return True

    def freeze_slot(self, slot, *, spill: bool = False) -> FrozenSlot:
        """Preempt an active decode slot: snapshot (emitted count, cached
        length, feed token), evict its pages wholesale and release the
        slot. Pinned mode keeps the pages alive under a freeze pin,
        retagged pool tier (the substrate's next drain spills their
        payload host-side); spill mode releases them outright — restore
        then teacher-force-refills from the request's own history."""
        if not slot.active:
            raise RuntimeError(
                f"freeze needs an active decode slot, got {slot.index} "
                f"in phase {slot.phase!r}")
        if not spill and not self.cells.paged:
            raise RuntimeError(
                "pinned freeze is paged-only: dense caches key KV by "
                "slot index, so remapping pages moves nothing")
        fs = FrozenSlot(
            request=slot.request,
            length=int(slot.t),
            emitted=int(slot.emitted),
            last_token=int(self.tokens[slot.index]),
            pages=None,
            t_frozen=self.virtual_s,
        )
        snap = self.pager.freeze(slot.index, spill=spill)
        fs.pages = snap["pages"]
        if snap["length"] != fs.length:
            raise RuntimeError(
                f"freeze: pager length {snap['length']} != slot cursor "
                f"{fs.length} for slot {slot.index}")
        self.batcher.release(slot)
        self._draft_fed[slot.index] = 0
        self.frozen.append(fs)
        self._fault_counters["preempts"] += 1
        if spill:
            self._fault_counters["spills"] += 1
        return fs

    def thaw_slot(self, fs: FrozenSlot) -> bool:
        """Resume a frozen request on THIS engine. A pinned snapshot
        remaps its pages wholesale into a fresh slot (no recompute); a
        spilled one re-runs prompt + emitted history through `adopt`'s
        teacher-forced refill. Returns False if no slot/pages are
        available right now."""
        if self.batcher.n_free == 0:
            return False
        if fs.pages is None:
            return self.adopt(fs.request, self.virtual_s, migrated=False)
        slot = self.batcher.admit(fs.request, start_pos=fs.length,
                                  emitted=fs.emitted)
        self.pager.thaw(slot.index,
                        {"pages": fs.pages, "length": fs.length})
        self.tokens[slot.index] = fs.last_token
        self._fault_counters["restores"] += 1
        return True

    def adopt(self, req: Request, now: float, *,
              migrated: bool = True) -> bool:
        """Resume a request that already emitted tokens elsewhere (a dead
        engine's in-flight slot, or a spilled freeze): re-prefill the
        prompt through the bucket cell, then teacher-force the emitted
        history through the plain decode cell one token at a time —
        every other slot's write cursor stays parked, so their KV and
        cursors are untouched. Greedy decode is deterministic per
        request, so the recomputed KV matches what the recovered
        continuation would have attended to and the token stream stays
        bit-identical (fp pools). Returns False when no slot or pages
        are available yet (the caller retries on a later tick)."""
        if not req.output:
            raise ValueError(
                f"request {req.request_id} has no emitted history — "
                "requeue it through the router instead of adopting")
        if self.cells.chunk_fn is not None:
            raise RuntimeError(
                "adopt needs the bucketed prefill cell; chunked-prefill "
                "engines cannot replay a migrated request")
        if self.batcher.n_free == 0:
            return False
        emitted = [int(t) for t in req.output]
        start = self.npfx + req.prompt_len
        if self.cells.paged and not self._reclaimable(
                self._pages_needed(start + len(emitted))):
            return False
        bucket = self.batcher.bucket_for(req.prompt_len)
        batch = {"tokens": jnp.asarray(req.tokens[None, :]),
                 **self._frontend_extras(req, bucket)}
        slot_caches, _ = self.cells.prefill_fns[bucket](self.params, batch)
        slot = self.batcher.admit(req, start_pos=start,
                                  emitted=len(emitted))
        self.pager.admit(slot.index, start)
        if self.cells.paged:
            self.caches = self.cells.insert_fns[bucket](
                self.caches, slot_caches, np.int32(slot.index),
                self._block_table_dev(),
            )
        else:
            self.caches = self.cells.insert_fns[bucket](
                self.caches, slot_caches, np.int32(slot.index)
            )
        self.virtual_s += self._prefill_dt(start)
        self._force_feed(slot, start, emitted[:-1])
        self.tokens[slot.index] = emitted[-1]
        slot.t = start + len(emitted) - 1
        self._fault_counters["restores"] += 1
        if migrated:
            self._fault_counters["migrations_in"] += 1
        self._fault_counters["reprefilled_tokens"] += (
            start + max(0, len(emitted) - 1))
        return True

    def _force_feed(self, slot, start: int, toks: List[int]) -> None:
        """Teacher-forced replay: feed each already-emitted token at its
        original position through the full-batch decode cell. The
        returned tokens are DISCARDED — determinism guarantees they
        equal the history being fed — only the KV writes matter. Other
        slots ride along parked (masked writes, garbage logits ignored),
        so interleaving a replay between fleet steps perturbs nothing."""
        if not toks:
            return
        mask = np.zeros(self.ecfg.n_slots, dtype=bool)
        mask[slot.index] = True
        park = self.batcher.park_pos
        for j, tok in enumerate(toks):
            t_vec = np.full(self.ecfg.n_slots, park, dtype=np.int32)
            t_vec[slot.index] = start + j
            feed = self.tokens.copy()
            feed[slot.index] = np.int32(tok)
            if self.cells.paged:
                for old, new in self.pager.ensure_tail_pages(mask):
                    self.caches = self.cells.copy_fn(
                        self.caches, np.int32(old), np.int32(new)
                    )
                _, _, self.caches = self.cells.decode_fn(
                    self.params, jnp.asarray(feed), self.caches,
                    jnp.asarray(t_vec), self._block_table_dev(),
                )
            else:
                _, _, self.caches = self.cells.decode_fn(
                    self.params, jnp.asarray(feed), self.caches,
                    jnp.asarray(t_vec),
                )
            self.pager.step(mask)
        # priced as recovery recompute: decode-shaped flops over the
        # replayed tokens, KV writes to the local tier, no per-step
        # launch floor (the replay rides one recovery event)
        self.virtual_s += self._prefill_dt(len(toks), final=False)

    def _thaw_tick(self, q: RequestQueue) -> bool:
        """Restore frozen slots (oldest first) while capacity allows.
        A frozen request yields to an ARRIVED strictly-higher-class
        request (which would just re-preempt it); preemption only ever
        picks strictly lower classes, so yield + preempt cannot cycle."""
        progressed = False
        while self.frozen and self.batcher.n_free:
            fs = self.frozen[0]
            if fs.request.is_cancelled(self.virtual_s):
                self.frozen.pop(0)
                self.pager.drop_frozen({"pages": fs.pages})
                fs.request.finished = self.virtual_s
                self.cancelled += 1
                progressed = True
                continue
            nxt = q.peek(self.virtual_s)
            if nxt is not None and nxt.priority < fs.request.priority:
                break
            if not self.thaw_slot(fs):
                break
            self.frozen.pop(0)
            progressed = True
        return progressed

    def _shrink_budget(self, frac: float) -> None:
        """Pool-pressure spike: the local page budget shrinks to `frac`
        of itself; the hotness rebalancer demotes to fit immediately."""
        pg = self.pager
        if not np.isfinite(pg.budget):
            return
        pg.cfg = dataclasses.replace(
            pg.cfg, local_budget_bytes=pg.budget * frac)
        self._fault_counters["budget_shrinks"] += 1
        if pg.cfg.policy == "hotness":
            pg.rebalance()

    def degrade_pool(self) -> None:
        """The pool tier dropped out: fall back to LOCAL-ONLY paging.
        Every live page retags local (the substrate's next drain pages
        the twin's content back in and empties host placement), the
        pager stops evicting (policy "none"), and admission tightens —
        halving the corridor budget models the local tier absorbing
        traffic the corridor priced for the pool link."""
        if self._degraded:
            return
        self._degraded = True
        self._fault_counters["degraded"] = 1
        pg = self.pager
        pg.tier_phys[:] = LOCAL
        pg.cfg = dataclasses.replace(
            pg.cfg, policy="none", local_budget_bytes=None)
        self.admission.budget *= 0.5

    def _fault_tick(self) -> Optional[str]:
        """Consult the injector before any engine work. Returns "dead" /
        "stalled" when this engine cannot make progress (the router's
        watchdog takes it from there), None to proceed normally."""
        if self._dead:
            return "dead"
        f = self.faults
        if f is None:
            return None
        if f.kill_now(self.engine_id, self.steps):
            self._dead = True
            return "dead"
        stall = f.stall_now(self.engine_id, self.steps)
        if stall is not None:
            self._stall_until = self.virtual_s + stall
        if self.virtual_s < self._stall_until:
            return "stalled"
        frac = f.shrink_now(self.engine_id, self.steps)
        if frac is not None:
            self._shrink_budget(frac)
        if f.pool_lost_now(self.engine_id, self.steps):
            self.degrade_pool()
        return None

    def evacuate(self) -> List[Request]:
        """Strip the engine for recovery or drain: every occupied slot,
        frozen snapshot and handoff pin releases WITHOUT finishing its
        request (the router re-routes or adopts them), the prefix trie
        gives back every cached page, and the substrate reconciles to
        an empty pool. Afterward the page pool is fully free with zero
        refcounts — asserted by the recovery tests. Returns the
        displaced requests in slot order (decode slots first carry
        emitted history for adoption; prefill-phase ones are clean
        requeues)."""
        displaced: List[Request] = []
        for rec in self.handoff_outbox:
            self.pager.unpin(rec.pages)
        self.handoff_outbox = []
        for slot in self.batcher.slots:
            if slot.occupied:
                displaced.append(slot.request)
                self._retire(slot)
        for fs in self.frozen:
            self.pager.drop_frozen({"pages": fs.pages})
            displaced.append(fs.request)
        self.frozen = []
        if self.prefix_cache is not None:
            self.prefix_cache.reclaim(self.pager, self.pager.n_phys)
        if self.substrate is not None:
            self.substrate.drain(self.pager, self.caches, step=self.steps)
            self.substrate.sync()
            self.virtual_s += self.substrate.take_backoff()
        return displaced

    def _prefill_dt(self, n_tokens: int, final: bool = True) -> float:
        """Virtual cost of prefilling `n_tokens` on the target topology:
        prefill compute + writing the new KV into the local tier. The
        resident-state write and the host dispatch floor are charged once
        per prompt (on the final/only chunk): interleaved chunks ride the
        engine's already-running step cadence, so chunking must not pay
        the launch overhead per chunk — only the serialization it
        actually removes."""
        t_comp = (
            rl.model_flops_decode(self._active_params, n_tokens)
            / hw.V5E.peak_flops_bf16
        )
        write = (
            self.pager.bytes_per_token * n_tokens
            + (self.pager.resident_bytes if final else 0.0)
        ) / self.topo.local.bandwidth
        return max(t_comp, write) + (
            self.ecfg.step_overhead_s if final else 0.0
        )

    def _retire(self, slot) -> Request:
        req = self.batcher.release(slot)
        self.pager.release(slot.index)
        self._draft_fed[slot.index] = 0
        return req

    def _block_table_dev(self):
        bt = self.pager.block_table()
        if bt is not self._bt_host:
            self._bt_host = bt
            self._bt_dev = jnp.asarray(bt)
        return self._bt_dev

    # ------------------------------------------------------------- step
    def _step_decode(self) -> None:
        """One fixed-shape decode step over all slots + accounting."""
        if self._last_decode_end is not None:
            self._decode_gaps.append(
                self.virtual_s - self._last_decode_end
            )
        active = self.batcher.active_mask()
        n_active = int(active.sum())
        t_vec = self.batcher.t_vector()
        if self.cells.paged:
            # the write-position page must be live AND private BEFORE the
            # cell runs: the block table it receives is the layout it
            # writes through. A shared tail page splits here (COW) and
            # the copy cell materializes the private duplicate — the
            # shared page is never mutated.
            for old, new in self.pager.ensure_tail_pages(active):
                self.caches = self.cells.copy_fn(
                    self.caches, np.int32(old), np.int32(new)
                )
            next_tok, finite, self.caches = self.cells.decode_fn(
                self.params, jnp.asarray(self.tokens), self.caches,
                jnp.asarray(t_vec), self._block_table_dev(),
            )
        else:
            next_tok, finite, self.caches = self.cells.decode_fn(
                self.params, jnp.asarray(self.tokens), self.caches,
                jnp.asarray(t_vec),
            )
        next_np = np.asarray(next_tok)
        if not bool(np.asarray(finite)[active].all()):
            raise FloatingPointError(
                f"non-finite decode logits at step {self.steps} "
                f"(active slots: {n_active})"
            )

        traffic = self.pager.step(active)
        t_backoff = 0.0
        if self.substrate is not None:
            # reconcile physical placement with the step's tier flips
            # (async: the streams complete under sync()/capture_stats)
            self.substrate.drain(self.pager, self.caches,
                                 step=self.steps)
            t_backoff = self.substrate.take_backoff()
            self._fault_counters["backoff_s"] += t_backoff
        t_compute = (
            rl.model_flops_decode(self._active_params, n_active)
            / hw.V5E.peak_flops_bf16
        )
        t_local = traffic.local_bytes / self.topo.local.bandwidth
        # staged/prefetched pool transfers overlap compute (issued a step
        # ahead — repro.prefetch; in the legacy weighted mode all pool
        # traffic is assumed prefetchable) -> roofline max; DEMAND
        # page-ins stall the step and serialize
        t_staged = traffic.prefetch_pool_bytes / self.topo.pool.bandwidth
        t_demand = traffic.demand_pool_bytes / self.topo.pool.bandwidth
        t_pool = t_staged + t_demand
        # retry backoff (fault injection) serializes like a demand stall
        dt = float(
            itf.step_time_vec(t_staged, t_local, t_compute, 0.0)
        ) + t_demand + self.ecfg.step_overhead_s + t_backoff
        self.virtual_s += dt
        self._last_decode_end = self.virtual_s
        self.steps += 1
        self._t_compute_s += t_compute
        # prefetch-excess feedback: pages staged over the link that never
        # became useful are interference the admission budget must absorb
        excess_b = (
            (self.pager.prefetch_issued - self.pager.prefetch_useful)
            * self.pager.page_bytes
        )
        t_excess = max(0.0, excess_b - self._prev_excess_b) \
            / self.topo.pool.bandwidth
        self._prev_excess_b = excess_b
        self.admission.observe(n_active, t_pool, dt, t_excess=t_excess)

        self.batcher.advance()
        for slot in self.batcher.slots:
            if not slot.active:
                continue
            req = slot.request
            tok = int(next_np[slot.index])
            self.tokens[slot.index] = tok
            req.output.append(tok)
            req.token_times.append(self.virtual_s)
            if req.done:
                req.finished = self.virtual_s
                self._retire(slot)

    # ------------------------------------------------------- speculative
    def _history(self, slot) -> np.ndarray:
        """The slot's committed token history: prompt + everything
        emitted (the last element is the token the next step feeds)."""
        req = slot.request
        return np.concatenate([
            np.asarray(req.tokens, dtype=np.int64),
            np.asarray(req.output, dtype=np.int64),
        ])

    def _propose(self, cand: np.ndarray, active: np.ndarray) -> float:
        """Fill `cand[:, 1:]` with draft tokens for active slots; returns
        the proposal's virtual-time cost (0 for the host-side n-gram
        proposer)."""
        from repro.serving import speculative as spec

        k = self.cells.spec_k
        if self.ecfg.speculative == "ngram":
            for slot in self.batcher.slots:
                if slot.active:
                    cand[slot.index, 1:] = spec.ngram_propose(
                        self._history(slot), k - 1
                    )
            return 0.0
        return self._propose_draft(cand, active)

    def _propose_draft(self, cand: np.ndarray,
                       active: np.ndarray) -> float:
        """Draft-model proposal: catch the draft's contiguous caches up
        to each active slot's committed history (refeed overwrites any
        rejected speculation from earlier steps — garbage past the
        frontier is masked by the vector-`t` length masks, same
        invariant as the paged pool), then feed the last committed token
        and self-feed k-2 more times. `_draft_fed[s]` counts committed
        tokens already in the draft cache."""
        k = self.cells.spec_k
        n_slots = self.ecfg.n_slots
        idxs = np.nonzero(active)[0]
        hists = {int(i): self._history(self.batcher.slots[i])
                 for i in idxs}
        calls = 0
        park = self._draft_park
        # catch-up: one committed token per call, all slots in parallel,
        # until every active slot holds all but its last token
        while True:
            tok = np.zeros(n_slots, dtype=np.int32)
            t = np.full(n_slots, park, dtype=np.int32)
            any_feed = False
            for i in idxs:
                h, f = hists[int(i)], int(self._draft_fed[i])
                if f < len(h) - 1:
                    tok[i] = h[f]
                    t[i] = f
                    self._draft_fed[i] = f + 1
                    any_feed = True
            if not any_feed:
                break
            _, self.draft_caches = self.cells.draft_fn(
                self.cells.draft_params, jnp.asarray(tok),
                self.draft_caches, jnp.asarray(t),
            )
            calls += 1
        # proposal: feed the last committed token, then self-feed
        cur = np.zeros(n_slots, dtype=np.int32)
        t = np.full(n_slots, park, dtype=np.int32)
        for i in idxs:
            cur[i] = hists[int(i)][-1]
            t[i] = len(hists[int(i)]) - 1
        for j in range(1, k):
            nxt, self.draft_caches = self.cells.draft_fn(
                self.cells.draft_params, jnp.asarray(cur),
                self.draft_caches, jnp.asarray(t),
            )
            calls += 1
            nxt = np.asarray(nxt)
            for i in idxs:
                cand[i, j] = nxt[i]
            cur = np.where(active, nxt, cur).astype(np.int32)
            t = np.where(active, t + 1, t).astype(np.int32)
        for i in idxs:
            # the proposal loop's first feed (the last committed token)
            # counts as fed; the self-fed drafts do not — they refeed
            # above if accepted, overwrite-in-place if not
            self._draft_fed[i] = len(hists[int(i)])
        self.spec_draft_calls += calls
        # virtual cost: the draft runs serially before verify — its
        # flops plus its contiguous-cache reads from the LOCAL tier
        # (draft caches are slot-local scratch, never pooled)
        n_active = int(active.sum())
        lengths = float(sum(len(hists[int(i)]) for i in idxs))
        t_comp = calls * (
            rl.model_flops_decode(self._draft_params_n, n_active)
            / hw.V5E.peak_flops_bf16
        )
        t_read = (calls * lengths * self._draft_tok_bytes
                  / self.topo.local.bandwidth)
        return t_comp + t_read

    def _step_speculative(self) -> None:
        """One speculative verify step: propose k-1 drafts per slot,
        score all k candidates in ONE paged verify call, commit the
        greedy-matching prefix, roll the page accounting back over the
        rejected tail. Emits 1..k tokens per active slot against ONE
        pool sweep — the amortization `KVPager.step(tokens=...)` prices.
        Token-stream parity with `_step_decode` is by construction
        (serving.speculative module docstring)."""
        from repro.serving import speculative as spec

        k = self.cells.spec_k
        if self._last_decode_end is not None:
            self._decode_gaps.append(self.virtual_s - self._last_decode_end)
        active = self.batcher.active_mask()
        n_active = int(active.sum())
        t_vec = self.batcher.t_vector()
        cand = np.zeros((self.ecfg.n_slots, k), dtype=np.int32)
        cand[:, 0] = self.tokens
        t_draft = self._propose(cand, active)
        # all k candidate rows write KV: their pages must be live and
        # private BEFORE the verify cell runs (rejected tails roll back
        # through truncate below)
        for old, new in self.pager.ensure_tail_pages(active, lookahead=k):
            self.caches = self.cells.copy_fn(
                self.caches, np.int32(old), np.int32(new)
            )
        greedy, finite, self.caches = self.cells.verify_fn(
            self.params, jnp.asarray(cand), self.caches,
            jnp.asarray(t_vec), self._block_table_dev(),
        )
        greedy_np = np.asarray(greedy)
        if not bool(np.asarray(finite)[active].all()):
            raise FloatingPointError(
                f"non-finite verify logits at step {self.steps} "
                f"(active slots: {n_active})"
            )

        # greedy acceptance per slot, capped by the request's remaining
        # decode budget (the verify row may overshoot max_new_tokens)
        counts = np.zeros(self.ecfg.n_slots, dtype=np.int64)
        emits: Dict[int, List[int]] = {}
        for slot in self.batcher.slots:
            if not slot.active:
                continue
            i = slot.index
            _, emit = spec.accept_greedy(cand[i], greedy_np[i])
            budget = slot.request.max_new_tokens - len(slot.request.output)
            emit = emit[:max(1, min(len(emit), budget))]
            counts[i] = len(emit)
            emits[i] = emit

        traffic = self.pager.step(active, tokens=counts)
        t_backoff = 0.0
        if self.substrate is not None:
            self.substrate.drain(self.pager, self.caches, step=self.steps)
            t_backoff = self.substrate.take_backoff()
            self._fault_counters["backoff_s"] += t_backoff
        # ONE pool sweep (the reads in `traffic`) scored k tokens per
        # slot: compute scales with k, memory does not — that asymmetry
        # is the whole speedup
        t_compute = (
            rl.model_flops_decode(self._active_params, k * n_active)
            / hw.V5E.peak_flops_bf16
        )
        t_local = traffic.local_bytes / self.topo.local.bandwidth
        t_staged = traffic.prefetch_pool_bytes / self.topo.pool.bandwidth
        t_demand = traffic.demand_pool_bytes / self.topo.pool.bandwidth
        t_pool = t_staged + t_demand
        dt = float(
            itf.step_time_vec(t_staged, t_local, t_compute, 0.0)
        ) + t_demand + self.ecfg.step_overhead_s + t_draft + t_backoff
        self.virtual_s += dt
        self._last_decode_end = self.virtual_s
        self.steps += 1
        self.spec_verify_steps += 1
        self.spec_slot_steps += n_active
        self.spec_emitted += int(counts.sum())
        self._t_compute_s += t_compute
        excess_b = (
            (self.pager.prefetch_issued - self.pager.prefetch_useful)
            * self.pager.page_bytes
        )
        t_excess = max(0.0, excess_b - self._prev_excess_b) \
            / self.topo.pool.bandwidth
        self._prev_excess_b = excess_b
        self.admission.observe(n_active, t_pool, dt, t_excess=t_excess)

        self.batcher.advance(counts)
        for slot in self.batcher.slots:
            if not slot.active:
                continue
            req = slot.request
            emit = emits[slot.index]
            self.tokens[slot.index] = emit[-1]
            for tok in emit:
                req.output.append(int(tok))
                req.token_times.append(self.virtual_s)
            if req.done:
                req.finished = self.virtual_s
                self._retire(slot)     # releases every page incl. lookahead
            else:
                # partial acceptance: free the lookahead pages past the
                # committed frontier so pool footprint tracks ACCEPTED
                # tokens (the rejected KV itself is dead weight the
                # kernels mask and the next verify overwrites)
                self.pager.truncate(slot.index)

    # ----------------------------------------------- admission <-> sched
    def measured_profile(self) -> itf.InterferenceProfile:
        """The engine's MEASURED interference profile (paper §7.2 closed
        loop, ROADMAP's admission<->scheduler item): per-step pool/local
        traffic from the pager's exact byte accounting plus the decode
        roofline compute time, as an `InterferenceProfile` the rack
        simulator prices like any other submitted job. Feed it to
        `sched.workload.serving_stream` so co-located serving instances
        throttle each other by their OBSERVED injected LoI rather than a
        catalog prior."""
        if self.steps == 0:
            raise RuntimeError(
                "measured_profile needs at least one decode step — run a "
                "trace first (the catalog prior covers cold starts)"
            )
        c = self.pager.counters()
        return itf.InterferenceProfile(
            arch=self.cfg.name,
            shape="serve_measured",
            pool_traffic=c["pool_bytes"] / self.steps,
            local_traffic=c["local_bytes"] / self.steps,
            t_compute=self._t_compute_s / self.steps,
            topo=self.topo,
        )

    # -------------------------------------------------------- tick layer
    # The engine loop decomposed into re-entrant primitives so a fleet
    # router (`serving.fleet.router`) can drive N engines step-by-step on
    # interleaved virtual clocks; `run()` composes exactly the same
    # primitives, so single-engine traces are bit-identical to the
    # pre-fleet monolith.
    @property
    def pending_work(self) -> bool:
        """True while a tick could make local progress: any occupied slot
        that is not parked awaiting a fleet handoff, or a frozen request
        a free slot could thaw."""
        return any(s.occupied and s.phase != "handoff"
                   for s in self.batcher.slots) \
            or (bool(self.frozen) and self.batcher.n_free > 0)

    def advance_to(self, t: float) -> None:
        """Advance the virtual clock to `t` (idle wait, never backwards).
        Arrival/transfer-bounded idling is not decode stall: the gap
        origin moves past the wait so the next gap counts only the work
        (admissions/prefill) done after it."""
        if t > self.virtual_s:
            self.virtual_s = t
            if self._last_decode_end is not None:
                self._last_decode_end = self.virtual_s

    def sweep_cancelled(self) -> int:
        """Retire every occupied slot whose request is cancelled (eager
        flag or `cancel_at` deadline passed on the virtual clock),
        releasing its KV pages back through `KVPager.release` — the
        refcount path, so shared prefix pages survive under the trie's
        pin. Handoff-parked slots are skipped (the router owns them
        mid-transfer)."""
        n = 0
        for slot in self.batcher.slots:
            if (slot.occupied and slot.phase != "handoff"
                    and slot.request.is_cancelled(self.virtual_s)):
                slot.request.finished = self.virtual_s
                self._retire(slot)
                n += 1
        self.cancelled += n
        return n

    def pump(self, q: RequestQueue) -> str:
        """One engine-loop iteration against `q`: sweep cancellations,
        admit while slots/admission allow, advance at most one prefill
        chunk, then one decode step if any slot is live. Returns what
        happened: "decode" | "chunk" | "admit" | "idle" (nothing
        possible — the caller owns clock advancement) | "dead" /
        "stalled" (fault injection: the engine cannot make progress;
        the fleet router's watchdog recovers it)."""
        act = self._fault_tick()
        if act is not None:
            return act
        self.sweep_cancelled()
        restored = self._thaw_tick(q) if self.frozen else False
        admitted = False
        while self.batcher.n_free:
            req = q.peek(self.virtual_s)
            if req is None or not self.admission.admit(self.batcher.n_busy):
                break
            if not self._ensure_pages_for(req):
                break       # stays queued; no victim to preempt
            self._admit(q.pop(self.virtual_s), self.virtual_s)
            admitted = True
        chunk_ran = self._prefill_tick()
        if self.batcher.n_active == 0:
            if chunk_ran:
                return "chunk"
            return "admit" if admitted or restored else "idle"
        self._max_conc = max(self._max_conc, self.batcher.n_active)
        if self.cells.verify_fn is not None:
            self._step_speculative()
        else:
            self._step_decode()
        return "decode"

    def begin_capture(self) -> dict:
        """Snapshot every per-run counter (`run()`'s stats are deltas, so
        the engine object stays reusable across traces)."""
        self._max_conc = 0
        return {
            "now0": self.virtual_s,
            "steps0": self.steps,
            "blocks0": self.admission.blocks,
            "gaps0": len(self._decode_gaps),
            "pager0": self.pager.counters(),
            "prefix0": (self.prefix_cache.counters()
                        if self.prefix_cache is not None else None),
            "substrate0": (self.substrate.counters()
                           if self.substrate is not None else None),
            "spec0": (self.spec_verify_steps, self.spec_slot_steps,
                      self.spec_emitted, self.spec_draft_calls),
            "faults0": dict(self._fault_counters),
            "sub_retries0": ((self.substrate.retries,
                              self.substrate.retry_bytes)
                             if self.substrate is not None else (0, 0.0)),
            "cancelled0": self.cancelled,
            "wall0": time.perf_counter(),
        }

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request],
            max_steps: Optional[int] = None) -> ServeStats:
        """Serve a request trace to completion (deterministic for a fixed
        trace). Returns aggregate stats; per-request outputs/latencies are
        left on the `Request` objects."""
        q = RequestQueue(requests)
        cap = self.begin_capture()
        while len(q) or self.batcher.n_busy or self.frozen:
            act = self.pump(q)
            if act == "decode":
                if max_steps is not None and self.steps >= max_steps:
                    break
            elif act == "dead":
                break       # single engine: nowhere to recover to
            elif act == "stalled":
                self.advance_to(self._stall_until)
            elif act == "idle":
                nxt = q.next_arrival()
                if not np.isfinite(nxt):
                    if self.frozen:
                        raise RuntimeError(
                            "engine wedged: frozen request cannot thaw "
                            "and nothing is running to free pages")
                    break
                if nxt <= self.virtual_s:
                    raise RuntimeError(
                        "engine starved: an arrived request cannot be "
                        "admitted (prompt exceeds the reclaimable pool "
                        "and no lower-priority victim to preempt)")
                self.advance_to(nxt)
        return self.capture_stats(cap, requests)

    def capture_stats(self, cap: dict, requests: List[Request],
                      ) -> ServeStats:
        """Aggregate stats since `cap = begin_capture()` over `requests`
        (per-request outputs/latencies live on the `Request` objects)."""
        wall = time.perf_counter() - cap["wall0"]
        now0, steps0 = cap["now0"], cap["steps0"]
        blocks0, gaps0 = cap["blocks0"], cap["gaps0"]
        pager0, prefix0 = cap["pager0"], cap["prefix0"]
        max_conc = self._max_conc
        substrate_delta: dict = {}
        if self.substrate is not None:
            # final reconcile (retired slots freed pages after the last
            # decode drain) + completion barrier, so the captured ledger
            # reflects finished transfers and current placement
            self.substrate.drain(self.pager, self.caches,
                                 step=self.steps)
            self.substrate.sync()
            t_backoff = self.substrate.take_backoff()
            self.virtual_s += t_backoff
            self._fault_counters["backoff_s"] += t_backoff
            s0, s1 = cap["substrate0"], self.substrate.counters()
            substrate_delta = {
                k: (s1[k] - s0[k]) if isinstance(s1[k], (int, float))
                else s1[k]
                for k in s1
            }
            # placement is a level, not a flow — report the current one
            substrate_delta["resident_pages"] = s1["resident_pages"]
            substrate_delta["placement_bytes"] = s1["placement_bytes"]

        done = [r for r in requests if r.output]
        ttft = np.array([r.token_times[0] - r.arrival for r in done])
        tpot = np.concatenate(
            [np.diff(r.token_times) for r in done if len(r.token_times) > 1]
            or [np.zeros(0)]
        )
        # every counter in the stats is a delta for THIS run() call — the
        # engine object stays reusable across traces without mixing
        # lifetime totals into per-run metrics
        pager1 = self.pager.counters()
        dlocal = pager1["local_bytes"] - pager0["local_bytes"]
        dpool = pager1["pool_bytes"] - pager0["pool_bytes"]
        ddemand = (pager1["demand_pool_bytes"]
                   - pager0["demand_pool_bytes"])
        pager_delta = {
            "steps": pager1["steps"] - pager0["steps"],
            "local_bytes": dlocal,
            "pool_bytes": dpool,
            "demand_pool_bytes": ddemand,
            "prefetch_pool_bytes": (pager1["prefetch_pool_bytes"]
                                    - pager0["prefetch_pool_bytes"]),
            "remote_share": dpool / (dlocal + dpool) if dlocal + dpool
            else 0.0,
            "demand_share": ddemand / (dlocal + dpool) if dlocal + dpool
            else 0.0,
            "evictions": pager1["evictions"] - pager0["evictions"],
            "promotions": pager1["promotions"] - pager0["promotions"],
            "prefetch_issued": (pager1["prefetch_issued"]
                                - pager0["prefetch_issued"]),
            "prefetch_useful": (pager1["prefetch_useful"]
                                - pager0["prefetch_useful"]),
            "local_used": pager1["local_used"],
            "pool_used": pager1["pool_used"],
            "cow_splits": pager1["cow_splits"] - pager0["cow_splits"],
            "shared_mapped_pages": (pager1["shared_mapped_pages"]
                                    - pager0["shared_mapped_pages"]),
        }
        prefix_delta: dict = {}
        if prefix0 is not None:
            prefix1 = self.prefix_cache.counters()
            prefix_delta = {
                k: prefix1[k] - prefix0[k]
                for k in ("hits", "misses", "hit_tokens", "hit_pages",
                          "inserted_pages", "evicted_pages")
            }
            n = prefix_delta["hits"] + prefix_delta["misses"]
            prefix_delta["hit_rate"] = (
                prefix_delta["hits"] / n if n else 0.0
            )
            prefix_delta["cached_pages"] = prefix1["cached_pages"]
        spec_delta: dict = {}
        if self.cells.verify_fn is not None:
            v0, s0_, e0, d0 = cap["spec0"]
            vsteps = self.spec_verify_steps - v0
            slot_steps = self.spec_slot_steps - s0_
            emitted = self.spec_emitted - e0
            spec_delta = {
                "verify_steps": vsteps,
                "emitted": emitted,
                "draft_calls": self.spec_draft_calls - d0,
                # tokens each slot commits per verify step it takes part
                # in (1 = no draft ever accepted, k = perfect proposer)
                "accept_len_mean": (emitted / slot_steps
                                    if slot_steps else 0.0),
            }
        faults_delta: dict = {}
        f0 = cap.get("faults0", {})
        f1 = self._fault_counters
        delta = {k: f1[k] - f0.get(k, 0) for k in f1}
        r0, rb0 = cap.get("sub_retries0", (0, 0.0))
        delta["retries"] = (self.substrate.retries - r0
                            if self.substrate is not None else 0)
        delta["retry_bytes"] = (self.substrate.retry_bytes - rb0
                                if self.substrate is not None else 0.0)
        if self.faults is not None or any(delta.values()):
            faults_delta = delta    # fault-free runs keep faults == {}
        return ServeStats(
            n_requests=len(done),
            tokens=sum(len(r.output) for r in done),
            steps=self.steps - steps0,
            wall_s=wall,
            virtual_s=self.virtual_s - now0,
            ttft=ttft,
            tpot=tpot,
            decode_stall=np.array(self._decode_gaps[gaps0:]),
            pager=pager_delta,
            admission_blocks=self.admission.blocks - blocks0,
            max_concurrency=max_conc,
            prefix=prefix_delta,
            substrate=substrate_delta,
            spec=spec_delta,
            faults=faults_delta,
        )
