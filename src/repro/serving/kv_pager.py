"""Paged tier-aware KV-cache manager — the serving realization of the
paper's page-grain placement (its PEBS/page analysis, §4) on top of the
framework's tier model.

The decode caches of the in-flight batch are divided into fixed-size pages
(`page_tokens` tokens of self-attention K/V per slot). Each page lives in
one tier: `local` (HBM) or `pool` (the disaggregated tier behind the shared
link). Per decode step the pager:

  1. derives each page's access weight from the hot-tail/cold-prefix decode
     traffic model (`core.access.decode_cache_split` constants — the same
     model the workload catalog uses, so engine accounting and catalog
     analysis agree);
  2. charges the step's bytes to the tier each page currently occupies
     (plus the non-paged resident state: SSM state/conv tails/cross-KV,
     always local);
  3. under the `hotness` policy, re-places pages with the paper's placement
     engine (`core.placement.place`, the same hotness policy
     `runtime/tiering.py` applies to training state at tensor grain):
     hottest pages stay local until the local budget is spent, cold pages
     are evicted to the pool.

Policies:
  hotness — tier-aware paging (the tentpole): recency-hot tail pages local,
            cold prefix evicted to the pool.
  static  — no-paging baseline: a page's tier is fixed at allocation
            (first-come local until the budget fills, then pool). Under
            decode recency this strands the hot tail on the pool tier —
            the Linux first-touch analogue the paper starts from.
  none    — no local budget (everything local; control case).

The pager is a *logical* manager plus exact byte accounting, matching the
rest of the framework: XLA memory kinds are tensor-grain (see
runtime/capability.py), so physical page moves cannot be expressed on this
backend — placement is tracked at page grain exactly like the paper tracks
pages it cannot individually pin either. Pool reads are assumed
layer-ahead-prefetchable (runtime/prefetch.py), which is why the engine's
step-time model overlaps pool time with compute instead of serializing it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import placement as plc
from repro.core import tiers as tr
from repro.core.access import DECODE_COLD_TOUCH, DECODE_HOT_WINDOW, \
    TensorAccess

LOCAL, POOL = 0, 1


@dataclasses.dataclass(frozen=True)
class PagerConfig:
    page_tokens: int = 32
    local_budget_bytes: Optional[float] = None   # None -> unbounded (no
    # eviction pressure; the "none" policy forces this)
    policy: str = "hotness"                      # hotness | static | none
    hot_window: int = DECODE_HOT_WINDOW          # tokens read at full rate
    cold_touch: float = DECODE_COLD_TOUCH        # cold-prefix touch/step
    rebalance_every: int = 1                     # steps between re-places

    def __post_init__(self):
        if self.policy not in ("hotness", "static", "none"):
            raise ValueError(f"unknown pager policy {self.policy!r}")
        if self.page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")


@dataclasses.dataclass
class StepTraffic:
    local_bytes: float
    pool_bytes: float

    @property
    def total(self) -> float:
        return self.local_bytes + self.pool_bytes


class KVPager:
    """Page table + tier accounting for `n_slots` in-flight sequences.

    `bytes_per_token`: self-attention K/V bytes per cached token per slot.
    `resident_bytes`: per-slot non-paged state (SSM state, conv tails,
    cross-attention KV) — pinned local, read whole every step.
    """

    def __init__(self, n_slots: int, max_seq: int, bytes_per_token: float,
                 resident_bytes: float, pcfg: PagerConfig,
                 topo: Optional[tr.TierTopology] = None):
        self.cfg = pcfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.bytes_per_token = float(bytes_per_token)
        self.resident_bytes = float(resident_bytes)
        self.page_bytes = self.bytes_per_token * pcfg.page_tokens
        self.n_pages = -(-max_seq // pcfg.page_tokens)  # ceil
        self.topo = topo or tr.v5e_topology()

        self.valid = np.zeros((n_slots, self.n_pages), dtype=bool)
        self.tier = np.full((n_slots, self.n_pages), LOCAL, dtype=np.int8)
        self.lengths = np.zeros(n_slots, dtype=np.int64)

        self._steps = 0
        self.total_local_bytes = 0.0
        self.total_pool_bytes = 0.0
        self.evictions = 0
        self.promotions = 0

    # ------------------------------------------------------------ budget
    @property
    def budget(self) -> float:
        if self.cfg.policy == "none" or self.cfg.local_budget_bytes is None:
            return float("inf")
        return float(self.cfg.local_budget_bytes)

    def local_bytes_used(self) -> float:
        return float((self.valid & (self.tier == LOCAL)).sum()
                     * self.page_bytes)

    def pool_bytes_used(self) -> float:
        return float((self.valid & (self.tier == POOL)).sum()
                     * self.page_bytes)

    # --------------------------------------------------------- lifecycle
    def _alloc_pages(self, slot: int, upto_page: int) -> None:
        """Mark pages [0, upto_page) of `slot` valid; new pages start in
        the tier the policy dictates."""
        newly = ~self.valid[slot, :upto_page]
        if not newly.any():
            return
        if self.cfg.policy == "static":
            # first-come local until the budget fills; permanent thereafter
            for p in np.nonzero(newly)[0]:
                fits = (self.local_bytes_used() + self.page_bytes
                        <= self.budget)
                self.tier[slot, p] = LOCAL if fits else POOL
                self.valid[slot, p] = True
        else:
            # hotness/none: allocate local (the tail is the hot end); the
            # next rebalance evicts whatever the budget cannot hold
            self.tier[slot, :upto_page][newly] = LOCAL
            self.valid[slot, :upto_page] = True

    def admit(self, slot: int, length: int) -> None:
        """A prefilled request enters `slot` with `length` cached tokens."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        self.valid[slot, :] = False
        self.lengths[slot] = length
        self._alloc_pages(slot, self._page_of(length - 1) + 1)
        if self.cfg.policy == "hotness":
            self.rebalance()

    def release(self, slot: int) -> None:
        self.valid[slot, :] = False
        self.lengths[slot] = 0

    def _page_of(self, pos: int) -> int:
        return max(int(pos), 0) // self.cfg.page_tokens

    # ------------------------------------------------------ access model
    def _page_weights(self) -> np.ndarray:
        """(n_slots, n_pages) per-step touch weight of each valid page
        under the hot-tail/cold-prefix model, fractional at the hot/cold
        page boundary."""
        starts = np.arange(self.n_pages) * self.cfg.page_tokens
        ends = starts + self.cfg.page_tokens
        hot_lo = self.lengths[:, None] - self.cfg.hot_window
        # tokens of each page inside [hot_lo, length)
        hot_tokens = np.clip(
            np.minimum(ends[None, :], self.lengths[:, None])
            - np.maximum(starts[None, :], hot_lo),
            0, self.cfg.page_tokens,
        )
        frac_hot = hot_tokens / self.cfg.page_tokens
        w = frac_hot + (1.0 - frac_hot) * self.cfg.cold_touch
        return np.where(self.valid, w, 0.0)

    def step(self, active: np.ndarray) -> StepTraffic:
        """Account one decode step for the `active` slot mask: reads per
        the traffic model against current page tiers, plus the new token's
        KV write into its (tail) page and the resident state."""
        active = np.asarray(active, dtype=bool)
        w = self._page_weights() * active[:, None]
        local_r = float((w * (self.tier == LOCAL)).sum() * self.page_bytes)
        pool_r = float((w * (self.tier == POOL)).sum() * self.page_bytes)

        # one token of KV written at the tail of each active slot
        wr_local = wr_pool = 0.0
        for s in np.nonzero(active)[0]:
            p = self._page_of(int(self.lengths[s]))  # write position == len
            if p < self.n_pages:
                if not self.valid[s, p]:
                    self._alloc_pages(s, p + 1)
                if self.tier[s, p] == POOL:
                    wr_pool += self.bytes_per_token
                else:
                    wr_local += self.bytes_per_token
                self.lengths[s] += 1
        local_b = local_r + wr_local + self.resident_bytes * active.sum()
        pool_b = pool_r + wr_pool

        self._steps += 1
        if (self.cfg.policy == "hotness"
                and self._steps % self.cfg.rebalance_every == 0):
            self.rebalance()

        self.total_local_bytes += local_b
        self.total_pool_bytes += pool_b
        return StepTraffic(local_b, pool_b)

    # --------------------------------------------------------- placement
    def rebalance(self) -> None:
        """Re-place valid pages with the paper's placement engine: build a
        page-grain access profile and run the `hotness` policy against the
        local budget — the exact analogue of `runtime/tiering.py` applying
        `core.placement` to training state at tensor grain."""
        idx = np.nonzero(self.valid)
        n_valid = len(idx[0])
        if (n_valid == 0 or not np.isfinite(self.budget)
                or self.page_bytes <= 0):
            return  # nothing paged (e.g. SSM-only archs: no self-attn KV)
        w = self._page_weights()
        # epsilon recency gradient: among equal-weight cold pages, evict
        # the oldest first (LRU within the cold class); placement-only,
        # never part of traffic accounting
        eps = 1e-9 / max(self.n_pages, 1)
        profile = [
            TensorAccess(f"s{s}/p{p}", int(self.page_bytes),
                         float(w[s, p]) + eps * (p + 1), "cache")
            for s, p in zip(*idx)
        ]
        total = n_valid * self.page_bytes
        pool_fraction = max(0.0, 1.0 - self.budget / total)
        place = plc.place(profile, self.topo, "hotness", pool_fraction)
        before = self.tier.copy()
        for (s, p), a in zip(zip(*idx), profile):
            self.tier[s, p] = (
                LOCAL if place.tier_of(a.name) == "hbm" else POOL
            )
        moved = (before != self.tier) & self.valid
        self.evictions += int((moved & (self.tier == POOL)).sum())
        self.promotions += int((moved & (self.tier == LOCAL)).sum())

    # ----------------------------------------------------------- metrics
    def remote_share(self) -> float:
        """Pool-tier share of cumulative cache traffic (the acceptance
        metric: tier-aware paging must push this down)."""
        total = self.total_local_bytes + self.total_pool_bytes
        return self.total_pool_bytes / total if total else 0.0

    def counters(self) -> dict:
        return {
            "steps": self._steps,
            "local_bytes": self.total_local_bytes,
            "pool_bytes": self.total_pool_bytes,
            "remote_share": self.remote_share(),
            "evictions": self.evictions,
            "promotions": self.promotions,
            "local_used": self.local_bytes_used(),
            "pool_used": self.pool_bytes_used(),
        }
