"""Paged tier-aware KV-cache manager — the serving realization of the
paper's page-grain placement (its PEBS/page analysis, §4) on top of the
framework's tier model.

The decode caches of the in-flight batch are divided into fixed-size pages
(`page_tokens` tokens of self-attention K/V per slot). Each page lives in
one tier: `local` (HBM) or `pool` (the disaggregated tier behind the shared
link). Per decode step the pager:

  1. derives each page's access weight from the hot-tail/cold-prefix decode
     traffic model (`core.access.decode_cache_split` constants — the same
     model the workload catalog uses, so engine accounting and catalog
     analysis agree);
  2. charges the step's bytes to the tier each page currently occupies
     (plus the non-paged resident state: SSM state/conv tails/cross-KV,
     always local);
  3. under the `hotness` policy, re-places pages with the paper's placement
     engine (`core.placement.place`, the same hotness policy
     `runtime/tiering.py` applies to training state at tensor grain):
     hottest pages stay local until the local budget is spent, cold pages
     are evicted to the pool.

Policies:
  hotness — tier-aware paging (the tentpole): recency-hot tail pages local,
            cold prefix evicted to the pool.
  static  — no-paging baseline: a page's tier is fixed at allocation
            (first-come local until the budget fills, then pool). Under
            decode recency this strands the hot tail on the pool tier —
            the Linux first-touch analogue the paper starts from.
  none    — no local budget (everything local; control case).

The pager is the serving stack's single PAGE ALLOCATOR: every valid
(slot, page) owns a physical page id from a shared free list, and
`block_table()` emits the logical->physical map that the engine's paged
cells read and write the cache through end-to-end — the decode gather
(`kernels/decode_attention/paged.py`), the prefill-insert scatter and the
chunked-prefill kernel (`kernels/flash_attention/paged_prefill.py`) all
chase this one table, so the (slots, pages) grain is the real data
layout, not bookkeeping. TIER placement stays accounting-grade on this
backend: XLA memory kinds are tensor-grain (see runtime/capability.py),
so a page's local-vs-pool tag (`phys_tiers()`) prices traffic exactly —
like the paper's pages it cannot individually pin — without issuing a
physical move.

SHARING (refcounted pages + copy-on-write). Block tables can alias: two
slots may point the same logical page at one physical page, and the paged
kernels never notice — the gather chases whatever the table says. The
pager therefore keeps a per-PHYSICAL-page refcount (`ref`) and tier tag
(`tier_phys`); the (slot, page) `tier` view is derived. Lifecycle:

  * `_alloc_pages`  — private page, ref = 1;
  * `map_shared`    — map already-cached prefix pages into a fresh slot's
                      leading table entries (ref += 1 each), the
                      prefix-cache hit path;
  * `remap_shared`  — swap a slot's freshly written private duplicates
                      onto cached pages (insert-then-dedupe, the bucketed
                      prefill path), freeing the duplicates;
  * `pin`/`unpin`   — a non-slot reference (the prefix trie's hold on its
                      cached pages, plus the engine's short guard pin
                      between trie match and remap). Counted in `pins` so
                      the global invariant is
                      `ref.sum() == valid.sum() + pins`;
  * `release`       — decrement, free only at zero (batched and order-
                      preserving exactly as the private path);
  * `cow_split`     — the moment a slot is about to WRITE into a shared
                      page (its non-full tail), split: take a free page,
                      repoint the writer, decref the shared original, and
                      report the (old, new) pair so the engine can run its
                      page-copy cell. A page with ref > 1 is never
                      mutated.

Shared bytes are accounted ONCE: `local/pool_bytes_used` and
`phys_tiers()` are physical-pool views, so a prefix cached under ten
slots occupies ten table rows but one page of budget — the deduplicated
footprint the paper's over-provisioning argument wants measured. Reads
stay per-slot (every sharer really does gather the page each step).

Pool-read accounting has two modes:

* `prefetch=None` (default, the pre-subsystem model): expected-value
  weighted accounting; all pool reads are ASSUMED layer-ahead
  prefetchable (`repro.prefetch.static`), so the engine overlaps pool
  time with compute.
* `prefetch=<predictor>` (prediction-driven page-in): the cold prefix is
  touched on a DISCRETE deterministic schedule (mean rate = `cold_touch`)
  and every pool touch is classified — staged ahead by the predictor
  (`repro.prefetch.predictors`, overlappable) or a demand page-in (the
  engine serializes it). `prefetch="demand"` is the null predictor: the
  demand-paging baseline the paper starts from. The overlap claim is now
  EARNED per page instead of assumed, and mispredicted stages are excess
  pool-link traffic (`counters()["prefetch_excess_bytes"]`).

An optional `recorder` (`repro.prefetch.trace.TraceRecorder`) captures
the discrete page-touch stream for offline predictor scoring in either
mode.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import placement as plc
from repro.core import tiers as tr
from repro.core.access import DECODE_COLD_TOUCH, DECODE_HOT_WINDOW, \
    TensorAccess

LOCAL, POOL = 0, 1


@dataclasses.dataclass(frozen=True)
class PagerConfig:
    page_tokens: int = 32
    local_budget_bytes: Optional[float] = None   # None -> unbounded (no
    # eviction pressure; the "none" policy forces this)
    policy: str = "hotness"                      # hotness | static | none
    hot_window: int = DECODE_HOT_WINDOW          # tokens read at full rate
    cold_touch: float = DECODE_COLD_TOUCH        # cold-prefix touch/step
    rebalance_every: int = 1                     # steps between re-places
    # --- prediction-driven page-in (repro.prefetch) ---
    prefetch: Optional[str] = None   # predictor name | "demand" | None
    prefetch_degree: int = 8         # max pages staged ahead per step
    # --- debug-mode consistency checking ---
    validate: bool = False           # cross-check frees vs the block table
    # (a freed page still mapped by a live slot raises instead of being
    # silently recycled into a second owner)

    def __post_init__(self):
        if self.policy not in ("hotness", "static", "none"):
            raise ValueError(f"unknown pager policy {self.policy!r}")
        if self.page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")
        if self.prefetch is not None and self.prefetch not in (
                "demand", "next_line", "stride", "stream", "markov",
                "ghb", "adaptive"):
            raise ValueError(
                f"pager prefetch {self.prefetch!r} must be a stream-"
                "learnable predictor (or 'demand'/'adaptive'); "
                "'static'/'frontier' need schedules/hints the pager "
                "does not have"
            )

    @property
    def cold_period(self) -> int:
        """Steps between discrete touches of one cold page (mean rate
        matches the weighted model's `cold_touch`)."""
        return max(1, int(round(1.0 / max(self.cold_touch, 1e-9))))


@dataclasses.dataclass
class StepTraffic:
    local_bytes: float
    pool_bytes: float
    # split of pool_bytes under prediction-driven page-in: staged-ahead
    # transfers overlap compute; demand page-ins serialize. The legacy
    # weighted mode reports everything as prefetchable (the old model).
    demand_pool_bytes: float = 0.0
    prefetch_pool_bytes: float = 0.0

    @property
    def total(self) -> float:
        return self.local_bytes + self.pool_bytes


class KVPager:
    """Page table + tier accounting for `n_slots` in-flight sequences.

    `bytes_per_token`: self-attention K/V bytes per cached token per slot.
    `resident_bytes`: per-slot non-paged state (SSM state, conv tails,
    cross-attention KV) — pinned local, read whole every step.
    """

    def __init__(self, n_slots: int, max_seq: int, bytes_per_token: float,
                 resident_bytes: float, pcfg: PagerConfig,
                 topo: Optional[tr.TierTopology] = None):
        self.cfg = pcfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.bytes_per_token = float(bytes_per_token)
        self.resident_bytes = float(resident_bytes)
        self.page_bytes = self.bytes_per_token * pcfg.page_tokens
        self.n_pages = -(-max_seq // pcfg.page_tokens)  # ceil
        self.n_phys = n_slots * self.n_pages
        self.topo = topo or tr.v5e_topology()

        self.valid = np.zeros((n_slots, self.n_pages), dtype=bool)
        self.lengths = np.zeros(n_slots, dtype=np.int64)
        # physical page ids: every valid (slot, page) maps to one from a
        # shared LIFO free list — interleaved admissions scatter a slot's
        # pages through the pool, which is exactly what the paged decode
        # kernel's block-index map exists for. Tables may ALIAS: `ref`
        # counts mappings (slot entries + pins) per physical page; a page
        # returns to the free list only when its refcount hits zero.
        self.phys = np.full((n_slots, self.n_pages), -1, dtype=np.int64)
        self.ref = np.zeros(self.n_phys, dtype=np.int32)
        self.tier_phys = np.full(self.n_phys, LOCAL, dtype=np.int8)
        self.pins = 0                 # non-slot refs (trie + guard pins)
        self._free_phys = list(range(self.n_phys))
        self._bt_cache: Optional[np.ndarray] = None
        # the engine wires a `serving.prefix_cache.PrefixCache` here; the
        # allocator calls back into it to reclaim trie-only pages when the
        # free list runs dry (LRU leaf eviction)
        self.prefix_cache = None

        self._steps = 0
        self.total_local_bytes = 0.0
        self.total_pool_bytes = 0.0
        self.total_demand_pool_bytes = 0.0
        self.total_prefetch_pool_bytes = 0.0
        self.evictions = 0
        self.promotions = 0
        self.prefetch_issued = 0
        self.prefetch_useful = 0
        self.cow_splits = 0
        self.shared_mapped_pages = 0
        self.freezes = 0
        self.thaws = 0
        # COW copy traffic (read old + write new) accumulates here and is
        # charged by the next `step` at the page's tier — the engine COWs
        # via `ensure_tail_pages` BEFORE the decode cell, so the bytes
        # land in that step's accounting
        self._cow_local_pending = 0.0
        self._cow_pool_pending = 0.0

        self.recorder = None          # optional prefetch.trace.TraceRecorder
        self._predictor = None
        self._staged: set = set()     # (slot, page) staged ahead, untouched
        if pcfg.prefetch is not None:
            from repro.prefetch.predictors import make_predictor

            if pcfg.prefetch == "stream":
                # one stream region per slot: global page ids are
                # slot-major, so each slot's cold walk is its own stream
                self._predictor = make_predictor(
                    "stream", region_pages=self.n_pages,
                    max_streams=max(n_slots, 2),
                )
            else:
                self._predictor = make_predictor(pcfg.prefetch)

    # ------------------------------------------------------------ budget
    @property
    def budget(self) -> float:
        if self.cfg.policy == "none" or self.cfg.local_budget_bytes is None:
            return float("inf")
        return float(self.cfg.local_budget_bytes)

    @property
    def tier(self) -> np.ndarray:
        """(n_slots, n_pages) tier of each mapped table entry — a derived
        READ-ONLY view now that tiers live per physical page (aliased
        entries must agree by construction). Invalid entries read LOCAL."""
        return np.where(
            self.valid, self.tier_phys[np.clip(self.phys, 0, None)],
            np.int8(LOCAL),
        )

    def local_bytes_used(self) -> float:
        """Deduplicated local-tier footprint: each live PHYSICAL page is
        counted once no matter how many slots map it."""
        return float(((self.ref > 0) & (self.tier_phys == LOCAL)).sum()
                     * self.page_bytes)

    def pool_bytes_used(self) -> float:
        return float(((self.ref > 0) & (self.tier_phys == POOL)).sum()
                     * self.page_bytes)

    def pool_page_ids(self) -> np.ndarray:
        """Physical ids of live pool-resident pages — the reconciliation
        target set the serving substrate (`serving.substrate`) mirrors
        into its host twin each step. Dedup rules match
        `pool_bytes_used`: a physical page counts once however many
        slot/trie mappings alias it, so after a drain the substrate
        ledger's placement_bytes equals pool_bytes_used exactly."""
        return np.nonzero((self.ref > 0) & (self.tier_phys == POOL))[0]

    # --------------------------------------------------------- lifecycle
    def _take_free(self, k: int) -> List[int]:
        """Pop `k` physical pages off the LIFO free-list tail, in the same
        order the old per-page pop() walked it (determinism: block tables
        replay identically across runs). Under free-list pressure the
        prefix trie gives back LRU cached pages first — trie-only pages
        are clean read copies, always safe to drop."""
        if len(self._free_phys) < k and self.prefix_cache is not None:
            self.prefix_cache.reclaim(self, k - len(self._free_phys))
        if len(self._free_phys) < k:
            raise RuntimeError(
                f"page pool exhausted: need {k}, "
                f"free {len(self._free_phys)}"
            )
        taken = self._free_phys[-k:]
        del self._free_phys[-k:]
        return taken[::-1]

    def _alloc_pages(self, slot: int, upto_page: int) -> None:
        """Mark pages [0, upto_page) of `slot` valid; new pages are
        PRIVATE (ref = 1) and start in the tier the policy dictates."""
        newly = ~self.valid[slot, :upto_page]
        if not newly.any():
            return
        self._bt_cache = None
        pages = np.nonzero(newly)[0]
        taken = self._take_free(len(pages))
        self.phys[slot, pages] = taken
        if self.cfg.policy == "static":
            # first-come local until the budget fills; permanent thereafter
            for p, g in zip(pages, taken):
                fits = (self.local_bytes_used() + self.page_bytes
                        <= self.budget)
                self.tier_phys[g] = LOCAL if fits else POOL
                self.ref[g] = 1
                self.valid[slot, p] = True
        else:
            # hotness/none: allocate local (the tail is the hot end); the
            # next rebalance evicts whatever the budget cannot hold
            self.tier_phys[taken] = LOCAL
            self.ref[taken] = 1
            self.valid[slot, :upto_page] = True

    def admit(self, slot: int, length: int) -> None:
        """A prefilled request enters `slot` with `length` cached tokens."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        self.release(slot)
        self.extend(slot, length)

    def extend(self, slot: int, length: int) -> None:
        """Grow `slot` to `length` cached tokens without releasing it —
        the chunked-prefill path: each chunk extends the slot by one
        page-aligned chunk BEFORE the chunk cell writes through the block
        table, so the pages it scatters into are always live. Pages
        already mapped (including shared prefix pages) are kept."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        if length <= self.lengths[slot]:
            return
        self.lengths[slot] = length
        self._alloc_pages(slot, self._page_of(length - 1) + 1)
        if self.cfg.policy == "hotness":
            self.rebalance()

    # ---------------------------------------------------------- sharing
    def pin(self, pages) -> None:
        """Take a non-slot reference on `pages` (the prefix trie's hold on
        its cached pages; also the engine's guard pin between trie match
        and table remap, so an allocation in between cannot reclaim the
        matched pages out from under the hit)."""
        pages = np.asarray(pages, dtype=np.int64)
        if pages.size == 0:
            return
        if self.cfg.validate and (self.ref[pages] <= 0).any():
            raise RuntimeError("pin of a free physical page")
        self.ref[pages] += 1
        self.pins += int(pages.size)

    def unpin(self, pages) -> None:
        """Drop a pin; pages whose refcount hits zero return to the free
        list (order-preserving, batched)."""
        pages = np.asarray(pages, dtype=np.int64)
        if pages.size == 0:
            return
        self.ref[pages] -= 1
        self.pins -= int(pages.size)
        if self.cfg.validate and (self.ref[pages] < 0).any():
            raise RuntimeError("unpin without a matching pin")
        dead = pages[self.ref[pages] == 0]
        if dead.size:
            if self.cfg.validate:
                self._validate_freed(dead)
            self._free_phys.extend(dead.tolist())

    def map_shared(self, slot: int, pages, n_tokens: int) -> None:
        """Map already-cached `pages` (physical ids, logical order) as the
        leading table entries of freshly admitted `slot`, increffing each —
        the prefix-cache HIT path. The slot's cached length becomes
        `n_tokens`; chunked prefill then starts at the first divergent
        page instead of token 0."""
        pages = np.asarray(pages, dtype=np.int64)
        k = int(pages.size)
        if k == 0:
            return
        if self.cfg.validate:
            if self.valid[slot, :k].any():
                raise RuntimeError("map_shared into a non-fresh slot")
            if (self.ref[pages] <= 0).any():
                raise RuntimeError("map_shared of a free physical page")
        self._bt_cache = None
        self.phys[slot, :k] = pages
        self.valid[slot, :k] = True
        self.ref[pages] += 1
        self.lengths[slot] = max(int(self.lengths[slot]), int(n_tokens))
        self.shared_mapped_pages += k
        if self.cfg.policy == "hotness":
            self.rebalance()

    def remap_shared(self, slot: int, pages) -> None:
        """Swap the leading logical pages of `slot` onto already-cached
        physical `pages`, freeing the slot's private duplicates — the
        insert-then-dedupe path for bucketed (single-shot) prefill: the
        fused insert scatters into freshly allocated private pages (its
        kernel contract demands uniquely owned targets), then the matched
        prefix deduplicates against the trie's identical copies."""
        tgt = np.asarray(pages, dtype=np.int64)
        k = int(tgt.size)
        if k == 0:
            return
        if self.cfg.validate and not self.valid[slot, :k].all():
            raise RuntimeError("remap_shared past the slot's mapped pages")
        cur = self.phys[slot, :k].copy()
        diff = cur != tgt
        if not diff.any():
            return
        self._bt_cache = None
        self.ref[tgt[diff]] += 1
        self.phys[slot, :k][diff] = tgt[diff]
        old = cur[diff]
        self.ref[old] -= 1
        dead = old[self.ref[old] == 0]
        if dead.size:
            if self.cfg.validate:
                self._validate_freed(dead)
            self._free_phys.extend(dead.tolist())
        self.shared_mapped_pages += int(diff.sum())

    def cow_split(self, slot: int, page: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: `slot` is about to write into logical `page`
        whose physical page is shared (ref > 1). Take a free page, repoint
        the writer at it, decref the shared original, and return the
        (old_phys, new_phys) pair so the engine can run its page-copy cell
        — the shared page itself is NEVER mutated. Returns None when the
        page is already private."""
        old = int(self.phys[slot, page])
        if self.ref[old] <= 1:
            return None
        new = self._take_free(1)[0]
        self._bt_cache = None
        self.ref[old] -= 1
        self.ref[new] = 1
        self.tier_phys[new] = self.tier_phys[old]
        self.phys[slot, page] = new
        self.cow_splits += 1
        # the copy reads the shared page and writes the private one, both
        # at the page's tier; charged by the next step()
        if self.tier_phys[new] == POOL:
            self._cow_pool_pending += 2.0 * self.page_bytes
        else:
            self._cow_local_pending += 2.0 * self.page_bytes
        return (old, new)

    def ensure_tail_pages(self, active: np.ndarray,
                          lookahead: int = 1) -> List[Tuple[int, int]]:
        """Make every active slot's next `lookahead` write-position pages
        PRIVATE and live — called by the engine BEFORE the paged decode
        cell so the block table it passes already names physical pages
        the slot exclusively owns for the tokens about to be written
        (`step` allocates/splits lazily otherwise, which is too late for
        a layout that is real on device). `lookahead=1` covers plain
        greedy decode (the single tail token); the speculative engine
        passes `lookahead=spec_k` so all k candidate rows of the verify
        cell land in live private pages (only the first page can be
        shared — pages past the tail are fresh allocations — but the COW
        check runs over the whole window anyway). Pages a partial
        acceptance leaves unused are rolled back by `truncate`. Returns
        the (old_phys, new_phys) COW pairs the engine must copy before
        the write."""
        cow: List[Tuple[int, int]] = []
        for s in np.nonzero(np.asarray(active, dtype=bool))[0]:
            lo = self._page_of(int(self.lengths[s]))
            hi = self._page_of(int(self.lengths[s]) + lookahead - 1)
            for p in range(lo, min(hi, self.n_pages - 1) + 1):
                if not self.valid[s, p]:
                    self._alloc_pages(int(s), p + 1)
                elif self.ref[self.phys[s, p]] > 1:
                    pair = self.cow_split(int(s), p)
                    if pair is not None:
                        cow.append(pair)
        return cow

    def truncate(self, slot: int) -> int:
        """Roll back `slot`'s page table to its committed length:
        release every valid page wholly beyond `lengths[slot]` — the
        speculative-decode rollback. A partially accepted verify step
        leaves the pages `ensure_tail_pages(lookahead=k)` allocated for
        the rejected candidates mapped but unused (and their KV content
        is garbage beyond the frontier, which every kernel masks); this
        returns them to the free list so the pool footprint tracks
        ACCEPTED tokens, not proposed ones. The pages are private by
        construction (fresh allocations or COW splits), but the release
        is refcounted like every other decref anyway. Returns the number
        of table entries dropped."""
        length = int(self.lengths[slot])
        first_keep = 0 if length <= 0 else self._page_of(length - 1) + 1
        drop = np.nonzero(self.valid[slot, first_keep:])[0] + first_keep
        if drop.size == 0:
            return 0
        self._bt_cache = None
        pages = self.phys[slot, drop]
        self.ref[pages] -= 1
        if self.cfg.validate and (self.ref[pages] < 0).any():
            raise RuntimeError(
                f"truncate: slot {slot} released a page whose refcount "
                "was already zero"
            )
        self.valid[slot, drop] = False
        self.phys[slot, drop] = -1
        dead = pages[self.ref[pages] == 0]
        if dead.size:
            if self.cfg.validate:
                self._validate_freed(dead)
            self._free_phys.extend(dead.tolist())
        if self._staged:
            dropped = set(drop.tolist())
            self._staged = {
                (s, p) for (s, p) in self._staged
                if not (s == slot and p in dropped)
            }
        return int(drop.size)

    # ------------------------------------------------- preempt / restore
    def freeze(self, slot: int, *, spill: bool = False) -> dict:
        """Preempt `slot`: snapshot its table and give the slot back.

        Default (``spill=False``) — the cheap paged preemption ROADMAP
        item 5 asks for: the slot's pages are pinned (a non-slot
        "freeze hold" reference, exactly like the prefix trie's), evicted
        WHOLESALE to the pool tier (the next substrate drain pages them
        out to the host twin), and the slot itself is released for
        another request. The returned snapshot names the physical pages
        in logical order; `thaw` remaps them into a fresh slot with the
        KV content intact — no recompute.

        ``spill=True`` — forfeit the pages entirely (the
        pool-exhaustion preemption path, where keeping them would defeat
        the point): the slot is released, its pages return to the free
        list, and the snapshot carries ``pages=None`` — restoring
        requires a teacher-forced refill of prompt + emitted history.

        Either way the refcount cover invariant
        (`ref.sum() == valid.sum() + pins`) holds throughout, so
        `validate=True` stays green across any preempt/restore
        interleaving.
        """
        length = int(self.lengths[slot])
        owned = np.nonzero(self.valid[slot])[0]
        if owned.size == 0 or (owned != np.arange(owned.size)).any():
            raise RuntimeError(
                f"freeze: slot {slot} table is empty or non-contiguous")
        self.freezes += 1
        if spill:
            self.release(slot)
            return {"pages": None, "length": length}
        pages = self.phys[slot, owned].copy()
        self.pin(pages)
        if np.isfinite(self.budget):
            # wholesale eviction to the pool tier; a budget-less pager
            # (policy "none") has no pool to evict to — the pages just
            # sit pinned in local memory
            self.tier_phys[pages] = POOL
        self.release(slot)
        return {"pages": pages, "length": length}

    def thaw(self, slot: int, snap: dict) -> None:
        """Restore a frozen snapshot into fresh `slot`: remap the held
        pages as the slot's leading table entries and drop the freeze
        hold. The hotness rebalancer re-promotes the hot tail on the
        next step; until then reads hit the pool tier (the restore cost
        the virtual clock prices)."""
        pages = snap["pages"]
        if pages is None:
            raise ValueError(
                "thaw of a spilled snapshot — the KV content is gone; "
                "restore via teacher-forced refill instead")
        self.map_shared(slot, pages, snap["length"])
        # map_shared counts toward the prefix-dedup stat; a thaw is a
        # restore, not a dedup — keep the stat's meaning
        self.shared_mapped_pages -= int(np.asarray(pages).size)
        self.unpin(pages)
        self.thaws += 1

    def drop_frozen(self, snap: dict) -> None:
        """Abandon a frozen snapshot (cancelled or migrated request):
        drop the freeze hold so unshared pages return to the free
        list."""
        if snap["pages"] is not None:
            self.unpin(snap["pages"])

    def release(self, slot: int) -> None:
        """Decref a finished/evicted slot's pages in ONE batched call;
        pages whose refcount hits zero return to the free list (order-
        preserving — shared prefix pages survive under the trie's pin or
        another slot's mapping)."""
        owned = self.valid[slot]
        if owned.any():
            self._bt_cache = None
            pages = self.phys[slot, owned]
            self.ref[pages] -= 1
            if self.cfg.validate and (self.ref[pages] < 0).any():
                raise RuntimeError(
                    f"double free: slot {slot} released a page whose "
                    "refcount was already zero"
                )
            dead = pages[self.ref[pages] == 0]
            if dead.size:
                if self.cfg.validate:
                    self._validate_freed(dead, skip_slot=slot)
                self._free_phys.extend(dead.tolist())
        self.phys[slot, :] = -1
        self.valid[slot, :] = False
        self.lengths[slot] = 0
        self._staged = {(s, p) for (s, p) in self._staged if s != slot}

    def _validate_freed(self, dead: np.ndarray,
                        skip_slot: Optional[int] = None) -> None:
        """Debug-mode liveness cross-check (`PagerConfig.validate`): a
        page about to re-enter the free list must not be mapped by any
        live block-table entry — a stale table entry would silently hand
        the recycled page a second owner and corrupt both sequences."""
        if dead.size == 0:
            return
        mask = self.valid.copy()
        if skip_slot is not None:
            mask[skip_slot] = False     # the releasing slot's own entries
        live = self.phys[mask]
        bad = np.intersect1d(dead, live)
        if bad.size:
            raise RuntimeError(
                f"pager free: physical pages {bad.tolist()} returned to "
                "the free list while still mapped in the block table "
                "(stale-entry reuse)"
            )

    def _page_of(self, pos: int) -> int:
        return max(int(pos), 0) // self.cfg.page_tokens

    def block_table(self) -> np.ndarray:
        """(n_slots, n_pages) logical->physical page map for the paged
        kernels (`kernels.decode_attention.ops.paged_decode_mha`,
        `kernels.flash_attention.ops.paged_prefill_mha`) AND the engine's
        paged cache-write cells. Invalid entries are 0 — the kernels'
        length/causal masks keep them out of the math (ops clamps
        identically). Rows may alias (shared prefixes): the gather path
        reads aliased pages fine; the WRITE paths never see an aliased
        target because `ensure_tail_pages`/`remap_shared` guarantee write
        pages are private before any scatter. The returned array is
        cached until the mapping changes (steady-state decode re-reads
        the same object, so the engine can skip the device upload by
        identity); treat it as read-only."""
        if self._bt_cache is None:
            self._bt_cache = np.where(self.valid, self.phys, 0).astype(
                np.int32)
        return self._bt_cache

    def phys_tiers(self) -> np.ndarray:
        """(n_slots * n_pages,) tier tag of every PHYSICAL page: LOCAL /
        POOL for live pages (ref > 0, slot-mapped or trie-cached), -1 for
        free-list pages. The physical-pool view of the tier split — what
        the byte accounting charges and what a memory-kind-capable
        backend would pin each page to. Shared pages appear ONCE here by
        construction (the deduplicated footprint)."""
        return np.where(self.ref > 0, self.tier_phys,
                        np.int8(-1)).astype(np.int8)

    # ------------------------------------------------------ access model
    def _page_weights(self) -> np.ndarray:
        """(n_slots, n_pages) per-step touch weight of each valid page
        under the hot-tail/cold-prefix model, fractional at the hot/cold
        page boundary."""
        starts = np.arange(self.n_pages) * self.cfg.page_tokens
        ends = starts + self.cfg.page_tokens
        hot_lo = self.lengths[:, None] - self.cfg.hot_window
        # tokens of each page inside [hot_lo, length)
        hot_tokens = np.clip(
            np.minimum(ends[None, :], self.lengths[:, None])
            - np.maximum(starts[None, :], hot_lo),
            0, self.cfg.page_tokens,
        )
        frac_hot = hot_tokens / self.cfg.page_tokens
        w = frac_hot + (1.0 - frac_hot) * self.cfg.cold_touch
        return np.where(self.valid, w, 0.0)

    def _discrete_touches(self, active: np.ndarray) -> list:
        """Deterministic per-step page-touch list [(slot, page), ...]:
        hot-tail pages every step, cold-prefix pages on a round-robin of
        period `cold_period` (page p of any slot is touched at steps
        where p ≡ step (mod period), so the touched cold set walks +1
        page per step — the same mean rate as the weighted model, made
        observable)."""
        period = self.cfg.cold_period
        touches = []
        for s in np.nonzero(active)[0]:
            length = int(self.lengths[s])
            if length <= 0:
                continue
            last = self._page_of(length - 1)
            hot_lo = self._page_of(max(length - self.cfg.hot_window, 0))
            for p in range(hot_lo, last + 1):
                if self.valid[s, p]:
                    touches.append((int(s), p, False))
            for p in range(0, hot_lo):
                if self.valid[s, p] and (p - self._steps) % period == 0:
                    touches.append((int(s), p, True))
        return touches

    def _gid(self, slot: int, page: int) -> int:
        return slot * self.n_pages + page

    def step(self, active: np.ndarray,
             tokens: Optional[np.ndarray] = None) -> StepTraffic:
        """Account one decode step for the `active` slot mask: reads per
        the traffic model against current page tiers, plus the new token's
        KV write into its (tail) page and the resident state. Pending COW
        copy bytes (splits since the last step) are flushed here.

        `tokens` (n_slots,) commits a PER-SLOT token count instead of 1 —
        the speculative-verify path: one verify call emits `1 + accepted`
        tokens per slot but sweeps the pool-resident pages ONCE, so the
        read side of this accounting is charged once per call while the
        lengths (and tail writes) advance by `tokens[s]`. That read-once/
        advance-many asymmetry IS the speculative speedup under the
        paper's corridor: decode traffic is page reads, and amortizing a
        sweep over the acceptance length divides the bytes per emitted
        token by it. (Rejected candidate rows also wrote KV, but those
        are overwritten in place before ever being read — sub-token
        noise against the per-step page sweep, excluded by the model.)"""
        active = np.asarray(active, dtype=bool)
        touches = None
        if self.recorder is not None or self._predictor is not None:
            touches = self._discrete_touches(active)
            if self.recorder is not None:
                self.recorder.record(
                    self._gid(s, p) for s, p, _ in touches
                )

        demand_b = staged_b = 0.0
        if self._predictor is None:
            # expected-value weighted accounting (the pre-subsystem
            # model); every pool byte is assumed layer-ahead prefetchable
            tier = self.tier
            w = self._page_weights() * active[:, None]
            local_r = float(
                (w * (tier == LOCAL)).sum() * self.page_bytes
            )
            pool_r = float(
                (w * (tier == POOL)).sum() * self.page_bytes
            )
        else:
            # discrete prediction-driven paging: each pool touch is a
            # demand page-in unless the predictor staged it ahead. Only
            # the COLD walk feeds the predictor — hot-tail touches are
            # local by placement and move with the tail; they are not
            # page-in candidates and would only pollute the stream the
            # predictor must learn.
            local_r = pool_r = 0.0
            for s, p, cold in touches:
                if self.tier_phys[self.phys[s, p]] == LOCAL:
                    local_r += self.page_bytes
                elif (s, p) in self._staged:
                    self._staged.discard((s, p))
                    self.prefetch_useful += 1
                    local_r += self.page_bytes   # staged copy: local read
                else:
                    demand_b += self.page_bytes
                if cold:
                    self._predictor.observe(self._gid(s, p))
            # stage the predictor's forecast for the NEXT step's touches:
            # the transfer crosses the pool link now (overlapped with
            # compute); mispredictions become excess link traffic
            self._predictor.start_step()
            for gid in self._predictor.predict(self.cfg.prefetch_degree):
                s, p = divmod(int(gid), self.n_pages)
                if (0 <= s < self.n_slots and 0 <= p < self.n_pages
                        and self.valid[s, p]
                        and self.tier_phys[self.phys[s, p]] == POOL
                        and (s, p) not in self._staged):
                    self._staged.add((s, p))
                    self.prefetch_issued += 1
                    staged_b += self.page_bytes

        # tokens[s] (default 1) tokens of KV written at the tail of each
        # active slot — each write page must be private, so a shared tail
        # page splits first (COW; never mutate a page with ref > 1)
        wr_local = wr_pool = 0.0
        counts = None if tokens is None else np.asarray(tokens)
        for s in np.nonzero(active)[0]:
            n_s = 1 if counts is None else int(counts[s])
            for _ in range(n_s):
                p = self._page_of(int(self.lengths[s]))  # write pos == len
                if p >= self.n_pages:
                    break
                if not self.valid[s, p]:
                    self._alloc_pages(int(s), p + 1)
                elif self.ref[self.phys[s, p]] > 1:
                    self.cow_split(int(s), p)
                if self.tier_phys[self.phys[s, p]] == POOL:
                    wr_pool += self.bytes_per_token
                else:
                    wr_local += self.bytes_per_token
                self.lengths[s] += 1
        cow_local, cow_pool = self._cow_local_pending, self._cow_pool_pending
        self._cow_local_pending = self._cow_pool_pending = 0.0
        local_b = (local_r + wr_local + cow_local
                   + self.resident_bytes * active.sum())
        pool_b = pool_r + wr_pool + demand_b + staged_b + cow_pool

        self._steps += 1
        if (self.cfg.policy == "hotness"
                and self._steps % self.cfg.rebalance_every == 0):
            self.rebalance()

        self.total_local_bytes += local_b
        self.total_pool_bytes += pool_b
        if self._predictor is None:
            # legacy overlap assumption: all pool traffic prefetchable
            demand, staged = 0.0, pool_b
        else:
            # COW pool copies serialize like demand page-ins: the split
            # must land before the write the decode cell is about to do
            demand = demand_b + wr_pool + cow_pool
            staged = staged_b
        self.total_demand_pool_bytes += demand
        self.total_prefetch_pool_bytes += staged
        return StepTraffic(local_b, pool_b, demand, staged)

    # --------------------------------------------------------- placement
    def rebalance(self) -> None:
        """Re-place live pages with the paper's placement engine: build a
        PHYSICAL-page-grain access profile and run the `hotness` policy
        against the local budget — the exact analogue of
        `runtime/tiering.py` applying `core.placement` to training state
        at tensor grain. A shared page's weight is the SUM of its
        sharers' touch weights (ten sharers of a prefix page make it ten
        times hotter than any single copy — dedup concentrates heat);
        trie-only pages carry no slot weight and drift poolward first."""
        owned = np.nonzero(self.ref > 0)[0]
        n_owned = len(owned)
        if (n_owned == 0 or not np.isfinite(self.budget)
                or self.page_bytes <= 0):
            return  # nothing paged (e.g. SSM-only archs: no self-attn KV)
        w_sp = self._page_weights()
        wg = np.zeros(self.n_phys)
        np.add.at(wg, self.phys[self.valid], w_sp[self.valid])
        # epsilon recency gradient: among equal-weight cold pages, evict
        # the oldest first (LRU within the cold class) — recency of a
        # shared page is its NEWEST mapping; placement-only, never part
        # of traffic accounting
        rec = np.zeros(self.n_phys)
        s_idx, p_idx = np.nonzero(self.valid)
        if s_idx.size:
            np.maximum.at(rec, self.phys[s_idx, p_idx], p_idx + 1)
        eps = 1e-9 / max(self.n_pages, 1)
        profile = [
            TensorAccess(f"g{g}", int(self.page_bytes),
                         float(wg[g]) + eps * float(rec[g]), "cache")
            for g in owned
        ]
        total = n_owned * self.page_bytes
        pool_fraction = max(0.0, 1.0 - self.budget / total)
        place = plc.place(profile, self.topo, "hotness", pool_fraction)
        before = self.tier_phys.copy()
        for g, a in zip(owned, profile):
            self.tier_phys[g] = (
                LOCAL if place.tier_of(a.name) == "hbm" else POOL
            )
        moved = (before != self.tier_phys) & (self.ref > 0)
        self.evictions += int((moved & (self.tier_phys == POOL)).sum())
        self.promotions += int((moved & (self.tier_phys == LOCAL)).sum())
        if self._staged:
            # a staged copy whose page got promoted (or freed) is moot
            self._staged = {
                (s, p) for (s, p) in self._staged
                if self.valid[s, p]
                and self.tier_phys[self.phys[s, p]] == POOL
            }

    # ----------------------------------------------------------- metrics
    def remote_share(self) -> float:
        """Pool-tier share of cumulative cache traffic (the acceptance
        metric: tier-aware paging must push this down)."""
        total = self.total_local_bytes + self.total_pool_bytes
        return self.total_pool_bytes / total if total else 0.0

    def demand_share(self) -> float:
        """Share of cumulative traffic that STALLS on the pool tier
        (demand page-ins; staged transfers overlap compute). Prediction-
        driven page-in must push this down vs the 'demand' baseline."""
        total = self.total_local_bytes + self.total_pool_bytes
        return self.total_demand_pool_bytes / total if total else 0.0

    def counters(self) -> dict:
        return {
            "steps": self._steps,
            "local_bytes": self.total_local_bytes,
            "pool_bytes": self.total_pool_bytes,
            "demand_pool_bytes": self.total_demand_pool_bytes,
            "prefetch_pool_bytes": self.total_prefetch_pool_bytes,
            "remote_share": self.remote_share(),
            "demand_share": self.demand_share(),
            "evictions": self.evictions,
            "promotions": self.promotions,
            "prefetch_issued": self.prefetch_issued,
            "prefetch_useful": self.prefetch_useful,
            "prefetch_excess_bytes": (
                (self.prefetch_issued - self.prefetch_useful)
                * self.page_bytes
            ),
            "local_used": self.local_bytes_used(),
            "pool_used": self.pool_bytes_used(),
            "cow_splits": self.cow_splits,
            "shared_mapped_pages": self.shared_mapped_pages,
            "freezes": self.freezes,
            "thaws": self.thaws,
            "pins": self.pins,
            "free_pages": len(self._free_phys),
        }
