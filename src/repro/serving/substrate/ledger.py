"""Completion-tracked transfer ledger for the physical KV substrate.

One `TransferEvent` per issued stream (see the package docstring for
the stream kinds). `bytes` is measured from the actual twin arrays'
`nbytes` — `page_bytes` here is handed in by `TierSubstrate` as
sum(leaf.nbytes / n_phys_pages) over the twin leaves, so the ledger
never re-derives footprint from model math. `placement_bytes()` is the
running host-resident footprint the engine's `phys_tiers()` pool
accounting must match after every drain.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple


# stream kinds -> whether bytes actually move on the tier link.
# "retry" is a FAILED transfer attempt re-issued by the fault-recovery
# layer: the bytes crossed the link and were wasted, so they count as
# moved, but the pages never changed placement — the placement contract
# (`pool_bytes_used == placement_bytes`) stays exact through any number
# of retries.
KINDS = ("page_out", "page_in", "drop", "handoff", "retry")
_MOVES = {"page_out": True, "page_in": True, "drop": False,
          "handoff": True, "retry": True}
# placement delta (host-resident pages) per stream page
_PLACEMENT = {"page_out": +1, "page_in": -1, "drop": -1, "handoff": 0,
              "retry": 0}


@dataclasses.dataclass
class TransferEvent:
    step: int
    kind: str                   # one of KINDS
    n_pages: int
    bytes: float                # measured payload bytes on the stream
    mode: str                   # "physical" | "emulated"
    completed: bool = False
    # in-flight jax arrays for completion tracking; dropped on sync()
    payload: Tuple = dataclasses.field(
        default=(), repr=False, compare=False)


class SubstrateLedger:
    """Append-only event log + running placement/byte counters."""

    def __init__(self, page_bytes: float, mode: str):
        self.page_bytes = float(page_bytes)
        self.mode = mode
        self.events: List[TransferEvent] = []
        self.resident_pages = 0
        self.bytes_by_kind = {k: 0.0 for k in KINDS}

    def record(self, kind: str, n_pages: int, *, step: int,
               payload: Tuple = ()) -> TransferEvent:
        if kind not in KINDS:
            raise ValueError(f"unknown stream kind {kind!r}; "
                             f"expected one of {KINDS}")
        moved = n_pages * self.page_bytes if _MOVES[kind] else 0.0
        ev = TransferEvent(
            step=step, kind=kind, n_pages=int(n_pages), bytes=moved,
            mode=self.mode, completed=not payload,
            payload=tuple(payload),
        )
        self.resident_pages += _PLACEMENT[kind] * ev.n_pages
        self.bytes_by_kind[kind] += moved
        self.events.append(ev)
        return ev

    def placement_bytes(self) -> float:
        """Host-resident pool footprint, from measured page bytes."""
        return self.resident_pages * self.page_bytes

    def sync(self) -> int:
        """Block on every in-flight stream payload; returns how many
        events this call completed. Payload references are dropped so
        the transferred buffers don't outlive their accounting."""
        n = 0
        for ev in self.events:
            if ev.completed:
                continue
            for arr in ev.payload:
                # a buffer donated into a LATER stream (the twin chains
                # through page_out via donate_argnums) was necessarily
                # materialized before that stream consumed it — deleted
                # here means completed, not lost
                if not arr.is_deleted():
                    arr.block_until_ready()
            ev.payload = ()
            ev.completed = True
            n += 1
        return n

    def counters(self) -> dict:
        done = sum(1 for ev in self.events if ev.completed)
        return {
            "mode": self.mode,
            "events": len(self.events),
            "completed": done,
            "in_flight": len(self.events) - done,
            "resident_pages": self.resident_pages,
            "placement_bytes": self.placement_bytes(),
            **{f"{k}_bytes": v for k, v in self.bytes_by_kind.items()},
            **{f"{k}_pages": sum(ev.n_pages for ev in self.events
                                 if ev.kind == k) for k in KINDS},
        }
