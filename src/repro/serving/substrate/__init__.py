"""Physical memory substrate under the paged-KV runtime.

The paper's two-tier local/pool memory system, made physical. Before
this package the pool tier was bookkeeping: `KVPager.phys_tiers()`
priced every page against `core.tiers` bandwidth/latency while all
pages lived in one default-memory array. The substrate realizes the
split:

  device pool   — the engine's paged cache leaves ("k"/"v" and the int8
                  "k_sz"/"v_sz" scale arrays) stay authoritative in
                  device memory: every kernel keeps reading the same
                  arrays, so token streams are bit-identical with the
                  substrate on or off.
  host twin     — a same-shape zeros twin of the paged leaves
                  (`models.blocks.init_pool_twin`), placed with a
                  `pinned_host` NamedSharding when the backend supports
                  it. Pages whose pager tier is POOL are mirrored here;
                  LOCAL and free pages are not.

TIER TRANSITIONS ARE RECONCILED, NOT HOOKED: once per decode step the
engine calls `TierSubstrate.drain(pager, caches)`, which diffs the
pager's live pool set (`KVPager.pool_page_ids()`) against the pages
currently host-resident and issues the difference as async transfer
streams —

  page_out  — newly pool-tiered pages (hot-tail eviction, cold-prefix
              demotion, static-policy spill, COW copies landing in the
              pool) gather from the device pool and scatter into the
              host twin in one jitted program whose output sharding IS
              the twin's placement (a real device->host DMA stream in
              physical mode).
  page_in   — pool pages promoted back to LOCAL gather out of the twin
              with a device-memory output sharding (host->device
              stream); the device pool already holds the payload, so
              the result is only held for completion tracking.
  drop      — pages freed while pool-resident (slot release, prefix
              trie reclaim) leave the twin with zero transfer bytes.

Within-step churn (a page evicted and promoted between two drains)
coalesces to its net placement change — the stream contract is
placement-accurate, not event-replaying. Page-id vectors are padded to
power-of-two lengths (repeating the last id: a duplicate scatter of
identical data is a no-op) so the jitted transfer programs compile
O(log pool_size) times, not per distinct burst size.

Every stream appends a `TransferEvent` to the `SubstrateLedger`:
MEASURED bytes (leaf `nbytes` of the actual twin arrays, not the
closed-form kv-byte walk), completion tracked via `sync()`
(`block_until_ready` over the in-flight payloads — transfers are
issued without blocking the step). The accounting contract, tested in
`tests/test_tier_substrate.py`:

    pager.pool_bytes_used() == ledger.placement_bytes()

after every drain, in both modes.

MODES (`runtime.capability.resolve_substrate_mode`): "physical" places
the twin with `memory_kind="pinned_host"` and needs the backend's
host-input + internal-transfer probes (XLA:TPU); "emulated" runs the
identical program shapes with default-memory placement (XLA:CPU, this
CI) so the ledger, byte accounting and tests are the same everywhere;
"auto" picks physical when the backend can; "off" disables the
substrate (and `ServingEngine` also disables it when the cache has no
paged leaves — SSM-only stacks have no page-addressable KV).
"""

from repro.serving.substrate.ledger import SubstrateLedger, TransferEvent
from repro.serving.substrate.tier_substrate import TierSubstrate

__all__ = ["SubstrateLedger", "TierSubstrate", "TransferEvent"]
