"""`TierSubstrate` — owns the host-resident pool twin and the jitted
transfer streams that reconcile it against the pager's tier map.

See the package docstring for the model. Shape notes: every paged leaf
has the physical page axis at position 1 (k/v: (nb, P_phys,
page_tokens, KV, hd); k_sz/v_sz: (nb, P_phys, KV, 2)), so one gather/
scatter index vector drives all leaves of a stream.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import blocks
from repro.runtime import sharding as shd
from repro.serving.substrate.ledger import SubstrateLedger


def _pool_leaves(caches, twin):
    """The device-pool subtree of `caches` matching `twin`'s structure."""
    return {pos: {k: caches[pos][k] for k in sub}
            for pos, sub in twin.items()}


class TierSubstrate:
    """Two-tier physical placement for the paged KV pool.

    mode       — "physical" (pinned_host twin) or "emulated" (default
                 memory, identical program shapes); resolve it with
                 `runtime.capability.substrate_mode` first — this class
                 does not probe.
    pool_pspec — optional PartitionSpec tree matching the PAGED subset
                 of the cache tree (`runtime.sharding.paged_cache_pspec`
                 output restricted to k/v/k_sz/v_sz). The twin carries
                 the same partitioning as the device pool so per-shard
                 transfers never reshard. Default: replicated.
    host_memory_kind — the jax memory kind the physical twin lands in;
                 the engine feeds `TierTopology.pool.memory_kind`
                 (`core.tiers.TierSpec`), so the pool tier the virtual
                 clock prices is the tier the bytes physically occupy.
    """

    def __init__(self, caches, mesh, mode: str, *,
                 pool_pspec=None, host_memory_kind: str = "pinned_host"):
        if mode not in ("physical", "emulated"):
            raise ValueError(
                f"mode={mode!r}; resolve 'auto'/'off' via "
                "runtime.capability.substrate_mode before constructing")
        self.mode = mode
        # fault-injection wiring (serving.faults): the fleet router sets
        # `faults`/`engine_id` after construction; when unset every
        # transfer succeeds on the first attempt and no retry state is
        # touched — the fault-free path is byte-identical to pre-fault
        # builds of this class.
        self.faults = None
        self.engine_id = 0
        self.retries = 0
        self.retry_bytes = 0.0
        self._backoff_pending_s = 0.0
        twin = blocks.init_pool_twin(caches)
        self.enabled = bool(twin)
        if not self.enabled:        # SSM-only stack: no paged KV leaves
            self.twin = None
            self.ledger = SubstrateLedger(0.0, mode)
            return
        if mesh is None:
            mesh = jax.sharding.Mesh(
                np.asarray(jax.devices()[:1]), ("_substrate",))
        if pool_pspec is None:
            pool_pspec = jax.tree.map(lambda _: P(), twin)
        host_kind = host_memory_kind if mode == "physical" else None
        self._host_sh = shd.named(mesh, pool_pspec, memory_kind=host_kind)
        self._dev_sh = shd.named(mesh, pool_pspec)
        self.twin = jax.device_put(twin, self._host_sh)
        first = jax.tree.leaves(self.twin)[0]
        self.n_phys = int(first.shape[1])
        # MEASURED page bytes: real array nbytes over the page axis, not
        # the closed-form kv-byte walk (they agree to float rounding)
        self.page_bytes = float(sum(
            leaf.nbytes / leaf.shape[1]
            for leaf in jax.tree.leaves(self.twin)))
        self.ledger = SubstrateLedger(self.page_bytes, mode)
        self._resident: set = set()

        def page_out(twin, pool, ids):
            return jax.tree.map(
                lambda t, p: t.at[:, ids].set(p[:, ids]), twin, pool)

        def page_in(twin, ids):
            return jax.tree.map(lambda t: t[:, ids], twin)

        # out_shardings pin the stream direction: page_out lands in the
        # twin's (host) placement, page_in lands in device memory. The
        # gathered page_in result keeps each leaf's rank, so the pool
        # pspec applies unchanged.
        self._page_out_fn = jax.jit(
            page_out, out_shardings=self._host_sh, donate_argnums=0)
        self._page_in_fn = jax.jit(
            page_in, out_shardings=self._dev_sh)

    # ----------------------------------------------------------- streams
    def _attempt_transfer(self, site: str, n_pages: int, step: int) -> None:
        """Consult the fault injector before issuing a stream: each
        injected failure logs a `retry` event (wasted link bytes, zero
        placement delta) and accrues exponential backoff on the pending
        virtual-time bill (`take_backoff`). Bounded: after
        `plan.max_retries` failed attempts the fault is fatal — an
        unreachable tier must surface, not spin."""
        if self.faults is None:
            return
        attempt = 1
        while self.faults.transfer_fails(f"substrate/{site}"):
            self.ledger.record("retry", n_pages, step=step)
            self.retries += 1
            self.retry_bytes += n_pages * self.page_bytes
            self._backoff_pending_s += self.faults.backoff_s(attempt)
            attempt += 1
            if attempt > self.faults.plan.max_retries:
                raise RuntimeError(
                    f"substrate {site} failed "
                    f"{self.faults.plan.max_retries} consecutive "
                    f"attempts (engine {self.engine_id}, step {step}) — "
                    f"tier unreachable")

    def take_backoff(self) -> float:
        """Drain the accumulated retry backoff (seconds of virtual time
        the engine must charge to its clock)."""
        dt, self._backoff_pending_s = self._backoff_pending_s, 0.0
        return dt

    def _pad_ids(self, ids) -> jnp.ndarray:
        """Pad a page-id burst to the next power of two by repeating the
        last id (duplicate scatter of identical data is a no-op) so the
        transfer cells compile O(log n_phys) distinct shapes."""
        n = len(ids)
        m = 1 << max(0, n - 1).bit_length() if n else 1
        arr = np.full(m, ids[-1], dtype=np.int32)
        arr[:n] = ids
        return jnp.asarray(arr)

    def drain(self, pager, caches, *, step: int = 0) -> dict:
        """Reconcile host placement against the pager's tier map: issue
        the page_out / page_in / drop streams for every page whose
        placement changed since the last drain. Async — call `sync()`
        to wait on the issued transfers. Returns the per-kind page
        counts of this drain."""
        if not self.enabled:
            return {}
        target = set(pager.pool_page_ids().tolist())
        outs = sorted(target - self._resident)
        gone = self._resident - target
        promoted = sorted(p for p in gone if pager.ref[p] > 0)
        freed = sorted(p for p in gone if pager.ref[p] <= 0)
        if freed:
            self.ledger.record("drop", len(freed), step=step)
        if promoted:
            self._attempt_transfer("page_in", len(promoted), step)
            # gather BEFORE page_out donates (and thus invalidates) the
            # current twin buffer
            got = self._page_in_fn(self.twin, self._pad_ids(promoted))
            self.ledger.record("page_in", len(promoted), step=step,
                               payload=tuple(jax.tree.leaves(got)))
        if outs:
            self._attempt_transfer("page_out", len(outs), step)
            self.twin = self._page_out_fn(
                self.twin, _pool_leaves(caches, self.twin),
                self._pad_ids(outs))
            self.ledger.record("page_out", len(outs), step=step,
                               payload=tuple(jax.tree.leaves(self.twin)))
        self._resident = target
        return {"page_out": len(outs), "page_in": len(promoted),
                "drop": len(freed)}

    def record_handoff(self, n_pages: int, *, step: int = 0) -> None:
        """Account a fleet prefill->decode handoff copy (roles.py runs
        the page copy along the physical axis; the substrate prices it
        at measured page bytes). No placement change: the source pages
        stay wherever their tier map says."""
        if self.enabled and n_pages:
            self.ledger.record("handoff", int(n_pages), step=step)

    # -------------------------------------------------------- accounting
    def sync(self) -> int:
        """Complete every in-flight stream (block_until_ready)."""
        return self.ledger.sync()

    def counters(self) -> dict:
        return self.ledger.counters()
