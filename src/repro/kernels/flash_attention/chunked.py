"""Memory-bounded flash attention in pure jnp (the everywhere-path).

Same tiling/online-softmax algorithm as the Pallas TPU kernel, expressed with
lax.scan so activation memory is O(block_q x block_k) instead of O(S^2); a
custom_vjp implements the standard flash backward (recompute P from the
saved logsumexp), so training never materializes the score matrix either.

This is the hardware adaptation demanded by long sequences: prefill_32k and
train_4k would otherwise need hundreds of GB of scratch per device (measured:
smollm train_4k = 298 GB/device with naive attention on a 4x4 mesh).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blocks(x, n, axis):
    """(B, S, ...) -> (n, B, S/n, ...) along `axis`."""
    shape = x.shape
    bs = shape[axis] // n
    new = shape[:axis] + (n, bs) + shape[axis + 1 :]
    x = x.reshape(new)
    return jnp.moveaxis(x, axis, 0)


def _unblocks(x, axis):
    """(n, B, bs, ...) -> (B, n*bs, ...)."""
    x = jnp.moveaxis(x, 0, axis)
    shape = x.shape
    return x.reshape(shape[:axis] + (shape[axis] * shape[axis + 1],)
                     + shape[axis + 2 :])


def _scores(qb, kb, scale):
    """qb (B,bq,G,R,D), kb (B,bk,G,D) -> (B,G,R,bq,bk) fp32."""
    return jnp.einsum(
        "bqgrd,bkgd->bgrqk",
        qb.astype(jnp.float32),
        kb.astype(jnp.float32),
    ) * scale


def _pick_block(s: int, target: int) -> int:
    b = min(s, target)
    while s % b:
        b //= 2
    return max(b, 1)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def mha_chunked(q, k, v, causal=True, scale=None, kv_offset=0,
                block_q=512, block_k=512):
    out, _ = _fwd(q, k, v, causal, scale, kv_offset, block_q, block_k)
    return out


def _fwd(q, k, v, causal, scale, kv_offset, block_q, block_k):
    with jax.named_scope("flash_vmem"):
        if (causal and kv_offset == 0 and q.shape[1] == k.shape[1]
                and q.shape[1] // _pick_block(q.shape[1], block_q) >= 4):
            return _fwd_triangular(q, k, v, scale, block_q, block_k)
        return _fwd_inner(q, k, v, causal, scale, kv_offset, block_q,
                          block_k)


def _tri_indices(nq: int):
    """Row-major lower-triangle tile order: (0,0),(1,0),(1,1),(2,0)..."""
    qi = [i for i in range(nq) for _ in range(i + 1)]
    ki = [j for i in range(nq) for j in range(i + 1)]
    return jnp.array(qi, jnp.int32), jnp.array(ki, jnp.int32)


def _fwd_triangular(q, k, v, scale, block_q, block_k):
    """Causal flash forward that only visits lower-triangle tiles — the jnp
    expression of the Pallas kernel's causal block skipping. Halves the
    attention flops of the full kv sweep (measured 2815 -> 1407 Tflop/device
    on qwen prefill_32k)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G, R = KV, H // KV
    scale = scale if scale is not None else D ** -0.5
    bq = _pick_block(Sq, block_q)
    bk = bq  # row-major flush requires aligned tiles
    nq = Sq // bq

    qs = _blocks(q.reshape(B, Sq, G, R, D), nq, 1)      # (nq,B,bq,G,R,D)
    ks = _blocks(k, nq, 1)                              # (nq,B,bk,G,D)
    vs = _blocks(v, nq, 1)
    qidx, kidx = _tri_indices(nq)

    pos_q = jnp.arange(bq)
    pos_k = jnp.arange(bk)

    def step(carry, t):
        out_buf, lse_buf, acc, m, l = carry
        qi = qidx[t]
        ki = kidx[t]
        new_row = ki == 0
        acc = jnp.where(new_row, 0.0, acc)
        m = jnp.where(new_row, NEG_INF, m)
        l = jnp.where(new_row, 0.0, l)

        qb = jax.lax.dynamic_index_in_dim(qs, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(ks, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vs, ki, 0, keepdims=False)

        s = _scores(qb, kb, scale)                      # (B,G,R,bq,bk)
        qpos = qi * bq + pos_q
        kpos = ki * bk + pos_k
        mask = qpos[:, None] >= kpos[None, :]           # all-true off-diag
        s = jnp.where(mask[None, None, None], s, NEG_INF)

        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        m = m_new
        l = l * alpha + p.sum(-1)
        pv = jnp.einsum("bgrqk,bkgd->bgrqd", p, vb.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv

        done = ki == qi
        l_safe = jnp.maximum(l, 1e-30)
        ob = (acc / l_safe[..., None]).astype(q.dtype)
        lse_row = m + jnp.log(l_safe)
        prev_o = jax.lax.dynamic_index_in_dim(out_buf, qi, 0,
                                              keepdims=False)
        prev_l = jax.lax.dynamic_index_in_dim(lse_buf, qi, 0,
                                              keepdims=False)
        out_buf = jax.lax.dynamic_update_index_in_dim(
            out_buf, jnp.where(done, ob, prev_o), qi, 0
        )
        lse_buf = jax.lax.dynamic_update_index_in_dim(
            lse_buf, jnp.where(done, lse_row, prev_l), qi, 0
        )
        return (out_buf, lse_buf, acc, m, l), None

    out0 = jnp.zeros((nq, B, G, R, bq, D), q.dtype)
    lse0 = jnp.zeros((nq, B, G, R, bq), jnp.float32)
    acc0 = jnp.zeros((B, G, R, bq, D), jnp.float32)
    m0 = jnp.full((B, G, R, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, G, R, bq), jnp.float32)
    (out_buf, lse_buf, *_), _ = jax.lax.scan(
        step, (out0, lse0, acc0, m0, l0), jnp.arange(qidx.shape[0])
    )
    out = jnp.moveaxis(out_buf, 0, 3)                   # (B,G,R,nq,bq,D)
    out = out.reshape(B, G, R, Sq, D).transpose(0, 3, 1, 2, 4)
    out = out.reshape(B, Sq, H, D)
    lse = jnp.moveaxis(lse_buf, 0, 3).reshape(B, G, R, Sq)
    return out, lse


def _fwd_inner(q, k, v, causal, scale, kv_offset, block_q, block_k):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G, R = KV, H // KV
    scale = scale if scale is not None else D ** -0.5
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Skv, block_k)
    nq, nk = Sq // bq, Skv // bk

    qs = _blocks(q.reshape(B, Sq, G, R, D), nq, 1)      # (nq,B,bq,G,R,D)
    ks = _blocks(k, nk, 1)                              # (nk,B,bk,G,D)
    vs = _blocks(v, nk, 1)

    def q_step(_, qi_qb):
        qi, qb = qi_qb

        def kv_step(carry, ki_kv):
            acc, m, l = carry
            ki, kb, vb = ki_kv
            s = _scores(qb, kb, scale)                  # (B,G,R,bq,bk)
            if causal:
                qpos = kv_offset + qi * bq + jnp.arange(bq)
                kpos = ki * bk + jnp.arange(bk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p,
                            vb.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, G, R, bq, D), jnp.float32)
        m0 = jnp.full((B, G, R, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, R, bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (jnp.arange(nk), ks, vs)
        )
        l_safe = jnp.maximum(l, 1e-30)
        ob = (acc / l_safe[..., None]).astype(q.dtype)  # (B,G,R,bq,D)
        lse = m + jnp.log(l_safe)                       # logsumexp rows
        return None, (ob, lse)

    _, (obs, lses) = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # obs (nq,B,G,R,bq,D) -> (B,Sq,H,D)
    out = jnp.moveaxis(obs, 0, 3)                       # (B,G,R,nq,bq,D)
    out = out.reshape(B, G, R, Sq, D).transpose(0, 3, 1, 2, 4)
    out = out.reshape(B, Sq, H, D)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, G, R, Sq)  # (B,G,R,Sq)
    return out, lse


def _fwd_vjp(q, k, v, causal, scale, kv_offset, block_q, block_k):
    out, lse = _fwd(q, k, v, causal, scale, kv_offset, block_q, block_k)
    return out, (q, k, v, out, lse)


def _bwd_vjp(causal, scale, kv_offset, block_q, block_k, res, dout):
    with jax.named_scope("flash_vmem"):
        return _bwd_inner(causal, scale, kv_offset, block_q, block_k, res,
                          dout)


def _bwd_inner(causal, scale, kv_offset, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    if (causal and kv_offset == 0 and q.shape[1] == k.shape[1]
            and q.shape[1] // _pick_block(q.shape[1], block_q) >= 4):
        return _bwd_triangular(scale, block_q, res, dout)
    return _bwd_rect(causal, scale, kv_offset, block_q, block_k, res, dout)


def _bwd_triangular(scale, block_q, res, dout):
    """Causal flash backward visiting only lower-triangle tiles (dq pass
    row-major, dk/dv pass column-major)."""
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G, R = KV, H // KV
    sc = scale if scale is not None else D ** -0.5
    bq = _pick_block(Sq, block_q)
    nq = Sq // bq

    q5 = q.reshape(B, Sq, G, R, D)
    do5 = dout.reshape(B, Sq, G, R, D).astype(jnp.float32)
    o5 = out.reshape(B, Sq, G, R, D).astype(jnp.float32)
    delta = jnp.einsum("bsgrd,bsgrd->bgrs", do5, o5)

    qs = _blocks(q5, nq, 1)
    dos = _blocks(do5, nq, 1)
    lses = jnp.moveaxis(lse.reshape(B, G, R, nq, bq), 3, 0)
    deltas = jnp.moveaxis(delta.reshape(B, G, R, nq, bq), 3, 0)
    ks = _blocks(k, nq, 1)
    vs = _blocks(v, nq, 1)
    pos = jnp.arange(bq)

    def tile(qi, ki):
        qb = jax.lax.dynamic_index_in_dim(qs, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(ks, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vs, ki, 0, keepdims=False)
        dob = jax.lax.dynamic_index_in_dim(dos, qi, 0, keepdims=False)
        lseb = jax.lax.dynamic_index_in_dim(lses, qi, 0, keepdims=False)
        deltab = jax.lax.dynamic_index_in_dim(deltas, qi, 0,
                                              keepdims=False)
        s = _scores(qb, kb, sc)
        mask = (qi * bq + pos)[:, None] >= (ki * bq + pos)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lseb[..., None])
        dp = jnp.einsum("bqgrd,bkgd->bgrqk", dob, vb.astype(jnp.float32))
        ds = p * (dp - deltab[..., None]) * sc
        return p, ds, qb, kb, vb, dob

    # pass 1: dq — row-major triangle
    qidx, kidx = _tri_indices(nq)

    def dq_step(carry, t):
        dq_buf, dq_acc = carry
        qi, ki = qidx[t], kidx[t]
        dq_acc = jnp.where(ki == 0, 0.0, dq_acc)
        _, ds, qb, kb, _, _ = tile(qi, ki)
        dq_acc = dq_acc + jnp.einsum(
            "bgrqk,bkgd->bqgrd", ds, kb.astype(jnp.float32)
        )
        prev = jax.lax.dynamic_index_in_dim(dq_buf, qi, 0, keepdims=False)
        dq_buf = jax.lax.dynamic_update_index_in_dim(
            dq_buf, jnp.where(ki == qi, dq_acc, prev), qi, 0
        )
        return (dq_buf, dq_acc), None

    dq0 = jnp.zeros((nq, B, bq, G, R, D), jnp.float32)
    (dq_buf, _), _ = jax.lax.scan(
        dq_step, (dq0, jnp.zeros((B, bq, G, R, D), jnp.float32)),
        jnp.arange(qidx.shape[0]),
    )
    dq = jnp.moveaxis(dq_buf, 0, 1).reshape(B, Sq, G, R, D)
    dq = dq.reshape(B, Sq, H, D).astype(q.dtype)

    # pass 2: dk/dv — column-major triangle
    cki = jnp.array([kj for kj in range(nq) for _ in range(nq - kj)],
                    jnp.int32)
    cqi = jnp.array([qi for kj in range(nq) for qi in range(kj, nq)],
                    jnp.int32)

    def dkv_step(carry, t):
        dk_buf, dv_buf, dkb, dvb = carry
        ki, qi = cki[t], cqi[t]
        first = qi == ki
        dkb = jnp.where(first, 0.0, dkb)
        dvb = jnp.where(first, 0.0, dvb)
        p, ds, qb, _, _, dob = tile(qi, ki)
        dvb = dvb + jnp.einsum("bgrqk,bqgrd->bkgd", p, dob)
        dkb = dkb + jnp.einsum(
            "bgrqk,bqgrd->bkgd", ds, qb.astype(jnp.float32)
        )
        done = qi == nq - 1
        pk = jax.lax.dynamic_index_in_dim(dk_buf, ki, 0, keepdims=False)
        pv_ = jax.lax.dynamic_index_in_dim(dv_buf, ki, 0, keepdims=False)
        dk_buf = jax.lax.dynamic_update_index_in_dim(
            dk_buf, jnp.where(done, dkb, pk), ki, 0
        )
        dv_buf = jax.lax.dynamic_update_index_in_dim(
            dv_buf, jnp.where(done, dvb, pv_), ki, 0
        )
        return (dk_buf, dv_buf, dkb, dvb), None

    zb = jnp.zeros((nq, B, bq, G, D), jnp.float32)
    zk = jnp.zeros((B, bq, G, D), jnp.float32)
    (dk_buf, dv_buf, _, _), _ = jax.lax.scan(
        dkv_step, (zb, zb, zk, zk), jnp.arange(cki.shape[0])
    )
    dk = _unblocks(dk_buf, 1).astype(k.dtype)
    dv = _unblocks(dv_buf, 1).astype(v.dtype)
    return dq, dk, dv


def _bwd_rect(causal, scale, kv_offset, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G, R = KV, H // KV
    sc = scale if scale is not None else D ** -0.5
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Skv, block_k)
    nq, nk = Sq // bq, Skv // bk

    q5 = q.reshape(B, Sq, G, R, D)
    do5 = dout.reshape(B, Sq, G, R, D).astype(jnp.float32)
    o5 = out.reshape(B, Sq, G, R, D).astype(jnp.float32)
    # delta_i = rowsum(dO * O)
    delta = jnp.einsum("bsgrd,bsgrd->bgrs", do5, o5)     # (B,G,R,Sq)

    qs = _blocks(q5, nq, 1)
    dos = _blocks(do5, nq, 1)
    lses = jnp.moveaxis(lse.reshape(B, G, R, nq, bq), 3, 0)
    deltas = jnp.moveaxis(delta.reshape(B, G, R, nq, bq), 3, 0)
    ks = _blocks(k, nk, 1)
    vs = _blocks(v, nk, 1)

    def _block_ds(qi, ki, qb, kb, vb, dob, lseb, deltab):
        """Recompute p and ds for one (q-block, kv-block) tile."""
        s = _scores(qb, kb, sc)                          # (B,G,R,bq,bk)
        if causal:
            qpos = kv_offset + qi * bq + jnp.arange(bq)
            kpos = ki * bk + jnp.arange(bk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lseb[..., None])                 # (B,G,R,bq,bk)
        dp = jnp.einsum("bqgrd,bkgd->bgrqk", dob, vb.astype(jnp.float32))
        ds = p * (dp - deltab[..., None]) * sc
        return p, ds

    # pass 1: dq — outer over q blocks, inner accumulates over kv blocks
    def dq_outer(_, qi_all):
        qi, qb, dob, lseb, deltab = qi_all

        def kv_inner(dq_acc, ki_kv):
            ki, kb, vb = ki_kv
            _, ds = _block_ds(qi, ki, qb, kb, vb, dob, lseb, deltab)
            dq_acc = dq_acc + jnp.einsum(
                "bgrqk,bkgd->bqgrd", ds, kb.astype(jnp.float32)
            )
            return dq_acc, None

        z = jnp.zeros((B, bq, G, R, D), jnp.float32)
        dq_b, _ = jax.lax.scan(kv_inner, z, (jnp.arange(nk), ks, vs))
        return None, dq_b

    _, dq_blocks = jax.lax.scan(
        dq_outer, None, (jnp.arange(nq), qs, dos, lses, deltas)
    )                                                    # (nq,B,bq,G,R,D)
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(B, Sq, G, R, D)
    dq = dq.reshape(B, Sq, H, D).astype(q.dtype)

    # pass 2: dk/dv — outer over kv blocks, inner accumulates over q blocks
    def dkv_outer(_, ki_kv):
        ki, kb, vb = ki_kv

        def q_inner(carry, qi_all):
            dkb, dvb = carry
            qi, qb, dob, lseb, deltab = qi_all
            p, ds = _block_ds(qi, ki, qb, kb, vb, dob, lseb, deltab)
            dvb = dvb + jnp.einsum("bgrqk,bqgrd->bkgd", p, dob)
            dkb = dkb + jnp.einsum(
                "bgrqk,bqgrd->bkgd", ds, qb.astype(jnp.float32)
            )
            return (dkb, dvb), None

        zk = jnp.zeros((B, bk, G, D), jnp.float32)
        (dkb, dvb), _ = jax.lax.scan(
            q_inner, (zk, zk), (jnp.arange(nq), qs, dos, lses, deltas)
        )
        return None, (dkb, dvb)

    _, (dks, dvs) = jax.lax.scan(
        dkv_outer, None, (jnp.arange(nk), ks, vs)
    )
    dk = _unblocks(dks, 1).astype(k.dtype)
    dv = _unblocks(dvs, 1).astype(v.dtype)
    return dq, dk, dv


mha_chunked.defvjp(_fwd_vjp, _bwd_vjp)
