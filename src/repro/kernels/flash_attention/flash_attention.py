"""Flash attention forward as a Pallas TPU kernel.

Grid (B, H, nq, nk) with the kv dimension sequential; online-softmax
accumulators (acc, m, l) live in VMEM scratch across kv iterations. Blocks
are MXU-aligned (block_q x D) / (block_k x D); fully-masked causal tiles are
skipped (`pl.when`), which is the 2x causal-waste saving the jnp chunked
path cannot express. Backward reuses the chunked-jnp flash backward via
custom_vjp (recompute-from-lse; the standard pairing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import chunked

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m_sc, l_sc, *,
            block_q: int, block_k: int, nk: int, rep: int, scale: float,
            causal: bool, kv_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    qpos0 = kv_offset + qi * block_q
    needed = (not causal) or (ki * block_k <= qpos0 + block_q - 1)

    @pl.when(needed)
    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # (bq, bk)
        if causal:
            qpos = qpos0 + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1, keepdims=True)
        m_sc[...] = m_new
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == nk - 1)
    def _done():
        l_safe = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, :, 0, :] = (acc[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0, :] = (m_sc[...] + jnp.log(l_safe))[:, 0]


def _fwd_pallas(q, k, v, causal, scale, kv_offset, block_q, block_k,
                interpret):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    bq = chunked._pick_block(Sq, block_q)
    bk = chunked._pick_block(Skv, block_k)
    nq, nk = Sq // bq, Skv // bk

    grid = (B, H, nq, nk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary"
            ),
        )
    out, lse = pl.pallas_call(
        functools.partial(
            _kernel, block_q=bq, block_k=bk, nk=nk, rep=rep, scale=scale,
            causal=causal, kv_offset=kv_offset,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec(
                (1, bk, 1, D),
                lambda b, h, qi, ki, rep=rep: (b, ki, h // rep, 0),
            ),
            pl.BlockSpec(
                (1, bk, 1, D),
                lambda b, h, qi, ki, rep=rep: (b, ki, h // rep, 0),
            ),
        ],
        out_specs=(
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
    return out, lse


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def flash_mha(q, k, v, causal=True, scale=None, kv_offset=0,
              block_q=512, block_k=512, interpret=False):
    out, _ = _fwd_pallas(q, k, v, causal, scale, kv_offset, block_q,
                         block_k, interpret)
    return out


def _flash_fwd(q, k, v, causal, scale, kv_offset, block_q, block_k,
               interpret):
    out, lse = _fwd_pallas(q, k, v, causal, scale, kv_offset, block_q,
                           block_k, interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, scale, kv_offset, block_q, block_k, interpret, res,
               dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G, R = KV, H // KV
    lse4 = lse.reshape(B, G, R, Sq)
    return chunked._bwd_inner(
        causal, scale, kv_offset, block_q, block_k,
        (q, k, v, out, lse4), dout,
    )


flash_mha.defvjp(_flash_fwd, _flash_bwd)
