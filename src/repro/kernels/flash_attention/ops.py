"""Public attention op. Dispatches pallas / interpret / reference."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro import kernels
from repro.kernels.flash_attention import ref


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "kv_offset", "impl")
)
def mha(
    q,
    k,
    v,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_offset: int = 0,
    impl: Optional[str] = None,
):
    """Multi-head (GQA) attention: q (B,Sq,H,D), k/v (B,Skv,KV,D)."""
    impl = impl or kernels.backend()
    if impl == "reference":
        if q.shape[1] * k.shape[1] <= 256 * 256:
            return ref.mha(
                q, k, v, causal=causal, scale=scale, kv_offset=kv_offset
            )
        from repro.kernels.flash_attention import chunked

        return chunked.mha_chunked(
            q, k, v, causal, scale, kv_offset
        )
    from repro.kernels.flash_attention import flash_attention as fa

    return fa.flash_mha(
        q,
        k,
        v,
        causal=causal,
        scale=scale,
        kv_offset=kv_offset,
        interpret=(impl == "interpret"),
    )
