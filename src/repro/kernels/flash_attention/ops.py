"""Public attention ops (dense prefill + paged chunked prefill).
Dispatches pallas / interpret / reference via `kernels.select_impl`.

The paged chunked-prefill surface has two tiers:

* `paged_prefill_mha` — gather-only attention over a pool whose chunk
  K/V was already scattered (the PR-4 contract; the parity oracle).
* `paged_prefill_insert_mha` / `paged_prefill_insert_mha_q8` — the FUSED
  ops: the chunk's K/V (int8: pre-quantized payload + (scale, zero)
  rows) goes in as an operand and comes back inside the updated pool
  arrays, written by the kernel through `input_output_aliases`. On the
  reference backend the same ops run the unfused scatter-then-attend
  oracle, so either dispatch target satisfies the one-call contract the
  serving chunk cell is built on.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import select_impl
from repro.kernels.decode_attention.ops import clamp_dead_entries
from repro.kernels.flash_attention import ref


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "kv_offset", "impl")
)
def mha(
    q,
    k,
    v,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_offset: int = 0,
    impl: Optional[str] = None,
):
    """Multi-head (GQA) attention: q (B,Sq,H,D), k/v (B,Skv,KV,D)."""
    kind, interpret = select_impl(impl)
    if kind == "reference":
        if q.shape[1] * k.shape[1] <= 256 * 256:
            return ref.mha(
                q, k, v, causal=causal, scale=scale, kv_offset=kv_offset
            )
        from repro.kernels.flash_attention import chunked

        return chunked.mha_chunked(
            q, k, v, causal, scale, kv_offset
        )
    from repro.kernels.flash_attention import flash_attention as fa

    return fa.flash_mha(
        q,
        k,
        v,
        causal=causal,
        scale=scale,
        kv_offset=kv_offset,
        interpret=interpret,
    )


def _clamp_frontier(block_tables, n_pages, page, c0, C):
    """Clamp block-table entries above the causal frontier c0+C to
    physical page 0 (shared in-bounds-gather invariant:
    `decode_attention.ops.clamp_dead_entries`); the causal mask keeps
    them out of the math."""
    return clamp_dead_entries(block_tables, n_pages, page, c0 + C)


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def paged_prefill_mha(
    q,
    k_pages,
    v_pages,
    block_tables,
    c0,
    *,
    k_sz=None,
    v_sz=None,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """Chunked prefill vs a PAGED cache: q (B, C, H, D) — C prompt tokens
    at absolute positions [c0, c0+C) — against k/v (P_phys, page, KV, D)
    physical page pool + (B, n_logical) block tables (`KVPager.
    block_table` layout), causal. The chunk's own K/V must already be
    written into the pool (see `paged_prefill_insert_mha` for the fused
    write+attend op). `c0` (B,) may be traced. `k_sz`/`v_sz`
    (P_phys, KV, 2) float32 switch the pool to int8 block quantization
    with the dequant epilogue on the gather side."""
    B, C = q.shape[0], q.shape[1]
    n_pages = block_tables.shape[1]
    page = k_pages.shape[1]
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    block_tables = _clamp_frontier(block_tables, n_pages, page, c0, C)
    kind, interpret = select_impl(impl)
    if kind == "reference":
        return ref.paged_prefill_mha(q, k_pages, v_pages, block_tables,
                                     c0, k_sz=k_sz, v_sz=v_sz, scale=scale)
    from repro.kernels.flash_attention import paged_prefill as pp

    return pp.paged_prefill_flash(
        q, k_pages, v_pages, block_tables, c0, k_sz=k_sz, v_sz=v_sz,
        scale=scale, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def paged_prefill_insert_mha(
    q,
    k_pages,
    v_pages,
    k_new,
    v_new,
    block_tables,
    c0,
    *,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """FUSED chunk insert + attention (fp pools): write the chunk's K/V
    (B, C, KV, D) into the pool at the block table's pages AND flash-
    attend the chunk queries in one pass. Returns (o, k_pages, v_pages).
    On the pallas/interpret backends the write happens inside the kernel
    via `input_output_aliases` (zero standalone scatters); the reference
    backend runs the unfused scatter-then-attend oracle. C and c0 must be
    page-aligned and the chunk's block-table entries live."""
    B, C = q.shape[0], q.shape[1]
    n_pages = block_tables.shape[1]
    page = k_pages.shape[1]
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    block_tables = _clamp_frontier(block_tables, n_pages, page, c0, C)
    # pre-cast so the in-chunk attention sees exactly the stored values
    k_new = k_new.astype(k_pages.dtype)
    v_new = v_new.astype(v_pages.dtype)
    kind, interpret = select_impl(impl)
    if kind == "reference":
        return ref.paged_prefill_insert_mha(
            q, k_pages, v_pages, k_new, v_new, block_tables, c0,
            scale=scale)
    from repro.kernels.flash_attention import paged_prefill as pp

    return pp.paged_prefill_insert_flash(
        q, k_pages, v_pages, k_new, v_new, block_tables, c0,
        scale=scale, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def paged_prefill_insert_mha_q8(
    q,
    k_pages,
    v_pages,
    k_sz,
    v_sz,
    k8_new,
    v8_new,
    ksz_new,
    vsz_new,
    block_tables,
    c0,
    *,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """FUSED chunk insert + attention for int8 pools: the pre-quantized
    chunk payload (B, C, KV, D) int8 and its per-page (scale, zero) rows
    (B, C//page, KV, 2) land in the pool while the chunk attends —
    previous pages dequantize through `k_sz`/`v_sz`, the chunk's own
    pages through the fresh rows. Returns
    (o, k_pages, v_pages, k_sz, v_sz)."""
    B, C = q.shape[0], q.shape[1]
    n_pages = block_tables.shape[1]
    page = k_pages.shape[1]
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    block_tables = _clamp_frontier(block_tables, n_pages, page, c0, C)
    kind, interpret = select_impl(impl)
    if kind == "reference":
        return ref.paged_prefill_insert_mha_q8(
            q, k_pages, v_pages, k_sz, v_sz, k8_new, v8_new, ksz_new,
            vsz_new, block_tables, c0, scale=scale)
    from repro.kernels.flash_attention import paged_prefill as pp

    return pp.paged_prefill_insert_flash_q8(
        q, k_pages, v_pages, k_sz, v_sz, k8_new, v8_new, ksz_new, vsz_new,
        block_tables, c0, scale=scale, interpret=interpret,
    )
