"""Public attention ops (dense prefill + paged chunked prefill).
Dispatches pallas / interpret / reference."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import kernels
from repro.kernels.flash_attention import ref


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "kv_offset", "impl")
)
def mha(
    q,
    k,
    v,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_offset: int = 0,
    impl: Optional[str] = None,
):
    """Multi-head (GQA) attention: q (B,Sq,H,D), k/v (B,Skv,KV,D)."""
    impl = impl or kernels.backend()
    if impl == "reference":
        if q.shape[1] * k.shape[1] <= 256 * 256:
            return ref.mha(
                q, k, v, causal=causal, scale=scale, kv_offset=kv_offset
            )
        from repro.kernels.flash_attention import chunked

        return chunked.mha_chunked(
            q, k, v, causal, scale, kv_offset
        )
    from repro.kernels.flash_attention import flash_attention as fa

    return fa.flash_mha(
        q,
        k,
        v,
        causal=causal,
        scale=scale,
        kv_offset=kv_offset,
        interpret=(impl == "interpret"),
    )


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def paged_prefill_mha(
    q,
    k_pages,
    v_pages,
    block_tables,
    c0,
    *,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """Chunked prefill vs a PAGED cache: q (B, C, H, D) — C prompt tokens
    at absolute positions [c0, c0+C) — against k/v (P_phys, page, KV, D)
    physical page pool + (B, n_logical) block tables (`KVPager.
    block_table` layout), causal. The chunk's own K/V must already be
    written into the pool (see `models.attention.paged_chunk_insert`).
    `c0` (B,) may be traced. Block-table entries above the causal
    frontier are clamped to physical page 0 so the gather stays in
    bounds on every backend; the causal mask keeps them out of the
    math."""
    B, C = q.shape[0], q.shape[1]
    n_pages = block_tables.shape[1]
    page = k_pages.shape[1]
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    live = (
        jnp.arange(n_pages, dtype=jnp.int32)[None, :] * page
        < (c0 + C)[:, None]
    )
    block_tables = jnp.where(live, jnp.asarray(block_tables, jnp.int32), 0)
    impl = impl or kernels.backend()
    if impl == "reference":
        return ref.paged_prefill_mha(q, k_pages, v_pages, block_tables,
                                     c0, scale=scale)
    from repro.kernels.flash_attention import paged_prefill as pp

    return pp.paged_prefill_flash(
        q, k_pages, v_pages, block_tables, c0, scale=scale,
        interpret=(impl == "interpret"),
    )
