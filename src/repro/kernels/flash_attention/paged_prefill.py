"""Paged chunked-prefill Pallas kernel: a fixed-size chunk of C prompt
tokens attends causally to everything already written to a KV cache laid
out as a physical page pool, gathered per logical page through a
per-sequence block table — the prefill-side twin of
`kernels/decode_attention/paged.py`, and the kernel the serving engine's
chunked prefill rides so a long prompt never serializes against in-flight
decode for more than one chunk.

The block tables and the chunk's start position ride the scalar-prefetch
channel (`pltpu.PrefetchScalarGridSpec`): both are resident in SMEM before
the body runs, so the K/V BlockSpec index maps chase `bt[b, pi]` to DMA
each NON-CONTIGUOUS physical page while the previous page's flash update
is still computing. The chunk offset `c0` is a runtime scalar, not a
Python constant, so every chunk of every request reuses ONE compiled
kernel — the engine's no-recompile contract extends to chunked prefill.

Grid (B, H, n_logical_pages); the page dimension is sequential
("arbitrary") so the (C, D) online-softmax accumulators live in VMEM
scratch across pages. Pages entirely above the causal frontier
(`page_start > c0 + C - 1`) are skipped via `pl.when` — the same
fully-masked-tile elision the dense flash kernel does for the causal
upper triangle. The chunk's own K/V must already be in the pool (the
paged cache-write path in `models/attention.py` scatters it through the
block table before calling this), so queries attend to their own chunk
through the same gather as the prefix — one code path, no concat.
Block-table entries past the frontier must still name a real physical
page (ops.py clamps them to 0); the causal mask keeps them out of the
math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(bt_ref, c0_ref, q_ref, k_ref, v_ref, o_ref, acc, m_sc, l_sc, *,
            page: int, chunk: int, scale: float, n_pages: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    c0 = c0_ref[b]
    needed = pi * page <= c0 + chunk - 1        # page below causal frontier

    @pl.when(needed)
    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # (C, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                      # (C, page)
        qpos = c0 + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 0)
        kpos = pi * page + jax.lax.broadcasted_iota(
            jnp.int32, (chunk, page), 1
        )
        s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1, keepdims=True)
        m_sc[...] = m_new
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(pi == n_pages - 1)
    def _done():
        o_ref[0, :, 0, :] = (
            acc[...] / jnp.maximum(l_sc[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_flash(q, k_pages, v_pages, block_tables, c0, *,
                        scale=None, interpret: bool = False):
    """q (B, C, H, D) — chunk of C prompt tokens at absolute positions
    [c0[b], c0[b]+C) — vs paged cache k/v (P_phys, page, KV, D) through
    block_tables (B, n_logical_pages) int32 physical-page ids; `c0` (B,)
    int32 chunk starts. Causal: query i attends to positions <= c0+i.
    The chunk's own K/V must already be written into the pool. Entries
    past the causal frontier must be in [0, P_phys) — use
    ops.paged_prefill_mha, which clamps."""
    from jax.experimental.pallas import tpu as pltpu

    B, C, H, D = q.shape
    _, page, KV, _ = k_pages.shape
    n_pages = block_tables.shape[1]
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    block_tables = jnp.asarray(block_tables, jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block tables + c0
        grid=(B, H, n_pages),
        in_specs=[
            pl.BlockSpec((1, C, 1, D), lambda b, h, pi, bt, c0: (b, 0, h, 0)),
            pl.BlockSpec(
                (1, page, 1, D),
                lambda b, h, pi, bt, c0, rep=rep: (bt[b, pi], 0, h // rep,
                                                   0),
            ),
            pl.BlockSpec(
                (1, page, 1, D),
                lambda b, h, pi, bt, c0, rep=rep: (bt[b, pi], 0, h // rep,
                                                   0),
            ),
        ],
        out_specs=pl.BlockSpec((1, C, 1, D),
                               lambda b, h, pi, bt, c0: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, D), jnp.float32),
            pltpu.VMEM((C, 1), jnp.float32),
            pltpu.VMEM((C, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page=page, chunk=C, scale=scale,
                          n_pages=n_pages),
        out_shape=jax.ShapeDtypeStruct((B, C, H, D), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(block_tables, c0, q, k_pages, v_pages)
