"""Paged chunked-prefill Pallas kernels: a fixed-size chunk of C prompt
tokens attends causally to everything already written to a KV cache laid
out as a physical page pool, gathered per logical page through a
per-sequence block table — the prefill-side twin of
`kernels/decode_attention/paged.py`, and the kernel the serving engine's
chunked prefill rides so a long prompt never serializes against in-flight
decode for more than one chunk.

Two entry points:

* `paged_prefill_flash` — gather-only attention: the chunk's K/V must
  already be in the pool (the PR-4 contract; kept as the parity oracle
  and for callers that scatter separately).
* `paged_prefill_insert_flash` — the FUSED fast path: the chunk's K/V
  tiles are INPUTS, the pool arrays are aliased input->output
  (`input_output_aliases`), and the kernel writes each chunk page into
  the pool while computing the chunk's attention in the same pass. The
  separate jnp page-scatter op — one full extra read+write of the
  chunk's K/V through HBM — disappears; non-chunk pages survive
  untouched because the output buffer IS the input buffer. Grid steps
  below the chunk re-write the chunk's first page with identical data
  (index maps clamp into the chunk's page range), so the write is
  idempotent; the H grid dimension is sequential ("arbitrary") in the
  fused kernels because GQA query heads of one KV head target the same
  output page block.

Block-quantized pools (`repro.kernels.quant`): int8 page payloads with
per-page float32 (scale, zero) pairs. On the gather side the previous
pages' (scale, zero) arrays ride the scalar-prefetch channel next to the
block table and the dequant epilogue runs right after each page's DMA; on
the insert side the fused kernel writes the chunk's pre-quantized int8
tiles AND their (scale, zero) rows through the same aliasing, so a
quantized chunked prefill also issues zero standalone scatters.

The block tables and the chunk's start position ride the scalar-prefetch
channel (`pltpu.PrefetchScalarGridSpec`): both are resident in SMEM before
the body runs, so the K/V BlockSpec index maps chase `bt[b, pi]` to DMA
each NON-CONTIGUOUS physical page while the previous page's flash update
is still computing. The chunk offset `c0` is a runtime scalar, not a
Python constant, so every chunk of every request reuses ONE compiled
kernel — the engine's no-recompile contract extends to chunked prefill.

Grid (B, H, n_logical_pages); the page dimension is sequential
("arbitrary") so the (C, D) online-softmax accumulators live in VMEM
scratch across pages. Pages entirely above the causal frontier
(`page_start > c0 + C - 1`) are skipped via `pl.when` — the same
fully-masked-tile elision the dense flash kernel does for the causal
upper triangle. Block-table entries past the frontier must still name a
real physical page (ops.py clamps them to 0); the causal mask keeps them
out of the math. `c0` and C must be page-aligned in the fused kernels
(the engine enforces `prefill_chunk % page_tokens == 0`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _tile_update(q, k, v, c0, pi, *, page: int, chunk: int, scale: float,
                 acc, m_sc, l_sc):
    """One page's causal online-softmax update of the (C, D) accumulator.
    q: (C, D), k/v: (page, D), all float32."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                      # (C, page)
    qpos = c0 + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 0)
    kpos = pi * page + jax.lax.broadcasted_iota(
        jnp.int32, (chunk, page), 1
    )
    s = jnp.where(qpos >= kpos, s, NEG_INF)
    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * alpha + p.sum(axis=1, keepdims=True)
    m_sc[...] = m_new
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _finalize(o_ref, acc, l_sc, pi, n_pages):
    @pl.when(pi == n_pages - 1)
    def _done():
        o_ref[0, :, 0, :] = (
            acc[...] / jnp.maximum(l_sc[...], 1e-30)
        ).astype(o_ref.dtype)


def _gather_kernel(*refs, page: int, chunk: int, scale: float,
                   n_pages: int, rep: int, sz_mode: str):
    """Attention only; the chunk's K/V is already in the pool."""
    if sz_mode == "page":
        (bt_ref, c0_ref, ksz_ref, vsz_ref, q_ref, k_ref, v_ref, o_ref,
         acc, m_sc, l_sc) = refs
    elif sz_mode == "token":
        (bt_ref, c0_ref, q_ref, k_ref, v_ref, ksz_ref, vsz_ref, o_ref,
         acc, m_sc, l_sc) = refs
    else:
        (bt_ref, c0_ref, q_ref, k_ref, v_ref, o_ref,
         acc, m_sc, l_sc) = refs
    b = pl.program_id(0)
    # program_id must be read at body top level (pl.when bodies lower
    # through lax.cond, outside the interpreter's grid context)
    kvh = pl.program_id(1) // rep
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    c0 = c0_ref[b]
    needed = pi * page <= c0 + chunk - 1        # page below causal frontier

    @pl.when(needed)
    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # (C, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if sz_mode == "page":
            pid = bt_ref[b, pi]
            k = k * ksz_ref[pid, kvh, 0] + ksz_ref[pid, kvh, 1]
            v = v * vsz_ref[pid, kvh, 0] + vsz_ref[pid, kvh, 1]
        elif sz_mode == "token":
            # per-token sub-scales: a (page, 2) VMEM tile per grid step,
            # fetched through the same block-table chase as the payload
            k = (k * ksz_ref[0, :, 0, 0][:, None]
                 + ksz_ref[0, :, 0, 1][:, None])
            v = (v * vsz_ref[0, :, 0, 0][:, None]
                 + vsz_ref[0, :, 0, 1][:, None])
        _tile_update(q, k, v, c0, pi, page=page, chunk=chunk, scale=scale,
                     acc=acc, m_sc=m_sc, l_sc=l_sc)

    _finalize(o_ref, acc, l_sc, pi, n_pages)


def _fused_kernel(*refs, page: int, chunk: int, scale: float,
                  n_pages: int, rep: int, quantized: bool):
    """Attention + aliased chunk write: pool outputs alias pool inputs,
    and every grid step writes its (clamped) chunk page tile — identical
    data on re-visits, so the write is idempotent and the chunk's pages
    hold exactly the chunk K/V when the kernel completes."""
    if quantized:
        (bt_ref, c0_ref, ksz_ref, vsz_ref, q_ref, kn_ref, vn_ref,
         kszn_ref, vszn_ref, kp_ref, vp_ref, _kszal, _vszal,
         o_ref, ko_ref, vo_ref, kszo_ref, vszo_ref,
         acc, m_sc, l_sc) = refs
    else:
        (bt_ref, c0_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref,
         o_ref, ko_ref, vo_ref, acc, m_sc, l_sc) = refs
    b = pl.program_id(0)
    kvh = pl.program_id(1) // rep
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    c0 = c0_ref[b]
    p0 = c0 // page
    # the fused scatter: kn/vn blocks and ko/vo blocks both chase the
    # clamped chunk page for this grid step (see the index maps), so this
    # plain copy lands each chunk tile at its block-table page
    ko_ref[...] = kn_ref[...]
    vo_ref[...] = vn_ref[...]
    if quantized:
        kszo_ref[...] = kszn_ref[0]
        vszo_ref[...] = vszn_ref[0]

    needed = pi * page <= c0 + chunk - 1

    @pl.when(needed)
    def _tile():
        in_chunk = pi >= p0
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        # the chunk's own pages attend to the tile being written (the
        # pool block holds stale data until this kernel's write lands);
        # earlier chunks' pages gather from the pool as usual
        k = jnp.where(in_chunk, kn_ref[...], kp_ref[...])
        k = k[0, :, 0, :].astype(jnp.float32)
        v = jnp.where(in_chunk, vn_ref[...], vp_ref[...])
        v = v[0, :, 0, :].astype(jnp.float32)
        if quantized:
            pid = bt_ref[b, pi]
            ks = jnp.where(in_chunk, kszn_ref[0, 0, 0, 0],
                           ksz_ref[pid, kvh, 0])
            kz = jnp.where(in_chunk, kszn_ref[0, 0, 0, 1],
                           ksz_ref[pid, kvh, 1])
            vs = jnp.where(in_chunk, vszn_ref[0, 0, 0, 0],
                           vsz_ref[pid, kvh, 0])
            vz = jnp.where(in_chunk, vszn_ref[0, 0, 0, 1],
                           vsz_ref[pid, kvh, 1])
            k = k * ks + kz
            v = v * vs + vz
        _tile_update(q, k, v, c0, pi, page=page, chunk=chunk, scale=scale,
                     acc=acc, m_sc=m_sc, l_sc=l_sc)

    _finalize(o_ref, acc, l_sc, pi, n_pages)


def _scratch(C, D):
    from jax.experimental.pallas import tpu as pltpu

    return [
        pltpu.VMEM((C, D), jnp.float32),
        pltpu.VMEM((C, 1), jnp.float32),
        pltpu.VMEM((C, 1), jnp.float32),
    ]


def _fused_specs(page: int, C: int, D: int, rep: int):
    """BlockSpecs shared by the fp and int8 fused insert+attend kernels
    (the `*sz` tail absorbs the int8 variant's two extra scalar-prefetch
    operands). `rel` maps a grid page to its tile inside the chunk and
    `wpage` to the pool page the aliased write targets — both clamped
    into the chunk's page range, which is what makes out-of-chunk grid
    steps idempotent re-writes of a chunk tile."""
    n_wp = C // page

    def rel(pi, c0b):
        return jnp.clip(pi - c0b // page, 0, n_wp - 1)

    def wpage(pi, btb, c0b):
        p0 = c0b // page
        return btb[jnp.clip(pi, p0, p0 + n_wp - 1)]

    return {
        "q": pl.BlockSpec(
            (1, C, 1, D),
            lambda b, h, pi, bt, c0, *sz: (b, 0, h, 0)),
        "chunk": pl.BlockSpec(
            (1, page, 1, D),
            lambda b, h, pi, bt, c0, *sz: (b, rel(pi, c0[b]), h // rep, 0)),
        "chunk_sz": pl.BlockSpec(
            (1, 1, 1, 2),
            lambda b, h, pi, bt, c0, *sz: (b, rel(pi, c0[b]), h // rep, 0)),
        "pool_in": pl.BlockSpec(
            (1, page, 1, D),
            lambda b, h, pi, bt, c0, *sz: (bt[b, pi], 0, h // rep, 0)),
        "pool_out": pl.BlockSpec(
            (1, page, 1, D),
            lambda b, h, pi, bt, c0, *sz:
            (wpage(pi, bt[b], c0[b]), 0, h // rep, 0)),
        "pool_sz": pl.BlockSpec(
            (1, 1, 2),
            lambda b, h, pi, bt, c0, *sz:
            (wpage(pi, bt[b], c0[b]), h // rep, 0)),
    }


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_flash(q, k_pages, v_pages, block_tables, c0, *,
                        k_sz=None, v_sz=None, scale=None,
                        interpret: bool = False):
    """q (B, C, H, D) — chunk of C prompt tokens at absolute positions
    [c0[b], c0[b]+C) — vs paged cache k/v (P_phys, page, KV, D) through
    block_tables (B, n_logical_pages) int32 physical-page ids; `c0` (B,)
    int32 chunk starts. Causal: query i attends to positions <= c0+i.
    The chunk's own K/V must already be written into the pool. Entries
    past the causal frontier must be in [0, P_phys) — use
    ops.paged_prefill_mha, which clamps. `k_sz`/`v_sz` float32 switch on
    the int8 dequant epilogue; their grain dispatches on rank: per-page
    (P_phys, KV, 2) rides the scalar-prefetch channel, per-token
    (P_phys, page, KV, 2) travels as tensor operands block-indexed
    through the same table chase as the payload."""
    from jax.experimental.pallas import tpu as pltpu

    B, C, H, D = q.shape
    _, page, KV, _ = k_pages.shape
    n_pages = block_tables.shape[1]
    rep = H // KV
    if k_sz is None:
        sz_mode = "none"
    elif jnp.ndim(k_sz) == k_pages.ndim:
        sz_mode = "token"
    else:
        sz_mode = "page"
    scale = scale if scale is not None else D ** -0.5
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    block_tables = jnp.asarray(block_tables, jnp.int32)

    page_spec = pl.BlockSpec(
        (1, page, 1, D),
        (lambda b, h, pi, bt, c0, *sz, rep=rep:
         (bt[b, pi], 0, h // rep, 0)),
    )
    in_specs = [
        pl.BlockSpec((1, C, 1, D),
                     lambda b, h, pi, bt, c0, *sz: (b, 0, h, 0)),
        page_spec,
        page_spec,
    ]
    operands = (q, k_pages, v_pages)
    if sz_mode == "token":
        sz_spec = pl.BlockSpec(
            (1, page, 1, 2),
            (lambda b, h, pi, bt, c0, rep=rep:
             (bt[b, pi], 0, h // rep, 0)),
        )
        in_specs += [sz_spec, sz_spec]
        operands += (jnp.asarray(k_sz, jnp.float32),
                     jnp.asarray(v_sz, jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        # block tables + c0 (+ per-page k/v (scale, zero) when int8)
        num_scalar_prefetch=4 if sz_mode == "page" else 2,
        grid=(B, H, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C, 1, D),
                               lambda b, h, pi, bt, c0, *sz: (b, 0, h, 0)),
        scratch_shapes=_scratch(C, D),
    )
    scalars = (block_tables, c0)
    if sz_mode == "page":
        scalars += (jnp.asarray(k_sz, jnp.float32),
                    jnp.asarray(v_sz, jnp.float32))
    return pl.pallas_call(
        functools.partial(_gather_kernel, page=page, chunk=C, scale=scale,
                          n_pages=n_pages, rep=rep, sz_mode=sz_mode),
        out_shape=jax.ShapeDtypeStruct((B, C, H, D), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            # MEGACORE partitioning: batch and head dims "parallel";
            # only the page walk is sequential (online-softmax carry)
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(*scalars, *operands)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_insert_flash(q, k_pages, v_pages, k_new, v_new,
                               block_tables, c0, *, scale=None,
                               interpret: bool = False):
    """FUSED fp chunk insert + attention. k_new/v_new (B, C, KV, D) in the
    POOL dtype (pre-cast by the caller so the in-chunk attention reads
    exactly the values the pool will hold). Returns (o, k_pages, v_pages)
    with the pool arrays updated in place via input_output_aliases —
    zero standalone scatter ops. C and c0 must be page-aligned, and the
    chunk's block-table entries must be live."""
    from jax.experimental.pallas import tpu as pltpu

    B, C, H, D = q.shape
    P_phys, page, KV, _ = k_pages.shape
    n_pages = block_tables.shape[1]
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    block_tables = jnp.asarray(block_tables, jnp.int32)

    sp = _fused_specs(page, C, D, rep)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block tables + c0
        grid=(B, H, n_pages),
        in_specs=[
            sp["q"],
            sp["chunk"],                         # k_new
            sp["chunk"],                         # v_new
            sp["pool_in"],                       # k_pages
            sp["pool_in"],                       # v_pages
        ],
        out_specs=[
            sp["q"],
            sp["pool_out"],                      # k_pages (aliased)
            sp["pool_out"],                      # v_pages (aliased)
        ],
        scratch_shapes=_scratch(C, D),
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, page=page, chunk=C, scale=scale,
                          n_pages=n_pages, rep=rep, quantized=False),
        out_shape=[
            jax.ShapeDtypeStruct((B, C, H, D), q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        grid_spec=grid_spec,
        # inputs count the scalar-prefetch operands: bt(0) c0(1) q(2)
        # k_new(3) v_new(4) k_pages(5) v_pages(6)
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            # H sequential: GQA query heads of one KV head re-write the
            # same output page block (identical data)
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ) if not interpret else None,
    )(block_tables, c0, q, k_new, v_new, k_pages, v_pages)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_insert_flash_q8(q, k_pages, v_pages, k_sz, v_sz,
                                  k8_new, v8_new, ksz_new, vsz_new,
                                  block_tables, c0, *, scale=None,
                                  interpret: bool = False):
    """FUSED int8 chunk insert + attention. The chunk arrives
    pre-quantized (`repro.kernels.quant.quantize_pages` — elementwise, no
    scatter): k8/v8_new (B, C, KV, D) int8 payload, ksz/vsz_new
    (B, C//page, KV, 2) float32 per-page (scale, zero) rows. Previous
    pages dequantize through the scalar-prefetch `k_sz`/`v_sz`
    (P_phys, KV, 2); the chunk's pages dequantize from their own fresh
    rows, so attention sees exactly what a later gather of the written
    pool would see. Returns (o, k_pages, v_pages, k_sz, v_sz) — payload
    AND (scale, zero) arrays updated through input_output_aliases."""
    from jax.experimental.pallas import tpu as pltpu

    B, C, H, D = q.shape
    P_phys, page, KV, _ = k_pages.shape
    n_pages = block_tables.shape[1]
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    block_tables = jnp.asarray(block_tables, jnp.int32)
    k_sz = jnp.asarray(k_sz, jnp.float32)
    v_sz = jnp.asarray(v_sz, jnp.float32)

    sp = _fused_specs(page, C, D, rep)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,      # block tables, c0, k_sz, v_sz (read)
        grid=(B, H, n_pages),
        in_specs=[
            sp["q"],
            sp["chunk"],                         # k8_new
            sp["chunk"],                         # v8_new
            sp["chunk_sz"],                      # ksz_new
            sp["chunk_sz"],                      # vsz_new
            sp["pool_in"],                       # k_pages
            sp["pool_in"],                       # v_pages
            sp["pool_sz"],                       # k_sz (alias carrier)
            sp["pool_sz"],                       # v_sz (alias carrier)
        ],
        out_specs=[
            sp["q"],
            sp["pool_out"],                      # k_pages (aliased)
            sp["pool_out"],                      # v_pages (aliased)
            sp["pool_sz"],                       # k_sz (aliased)
            sp["pool_sz"],                       # v_sz (aliased)
        ],
        scratch_shapes=_scratch(C, D),
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, page=page, chunk=C, scale=scale,
                          n_pages=n_pages, rep=rep, quantized=True),
        out_shape=[
            jax.ShapeDtypeStruct((B, C, H, D), q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
            jax.ShapeDtypeStruct(k_sz.shape, jnp.float32),
            jax.ShapeDtypeStruct(v_sz.shape, jnp.float32),
        ],
        grid_spec=grid_spec,
        # inputs count the scalar-prefetch operands: bt(0) c0(1) ksz(2)
        # vsz(3) q(4) k8(5) v8(6) kszn(7) vszn(8) kp(9) vp(10)
        # ksz_alias(11) vsz_alias(12)
        input_output_aliases={9: 1, 10: 2, 11: 3, 12: 4},
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ) if not interpret else None,
    )(block_tables, c0, k_sz, v_sz, q, k8_new, v8_new, ksz_new, vsz_new,
      k_pages, v_pages, k_sz, v_sz)
