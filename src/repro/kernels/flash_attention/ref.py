"""Pure-jnp oracle for blocked causal GQA attention (dense and paged)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import gather_pages


def mha(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Skv, KV, D)
    v: jnp.ndarray,          # (B, Skv, KV, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_offset: int = 0,      # absolute position of q[0] relative to k[0]
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5

    # broadcast kv heads to q heads (GQA)
    kq = jnp.repeat(k, rep, axis=2)
    vq = jnp.repeat(v, rep, axis=2)

    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + kv_offset
        kpos = jnp.arange(Skv)[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_prefill_mha(q, k_pages, v_pages, block_tables, c0, *,
                      scale: Optional[float] = None) -> jnp.ndarray:
    """Paged chunked-prefill oracle: gather the page pool to a dense
    cache, then causal attention of the chunk q (B, C, H, D) at absolute
    positions [c0[b], c0[b]+C) against it. `c0` may be traced (the chunk
    offset is a runtime scalar in the serving engine), so the causal mask
    is built per batch row instead of through `mha`'s static kv_offset."""
    B, C, H, D = q.shape
    k = gather_pages(k_pages, block_tables)        # (B, Skv, KV, D)
    v = gather_pages(v_pages, block_tables)
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))

    kq = jnp.repeat(k, rep, axis=2)
    vq = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) * scale
    qpos = c0[:, None] + jnp.arange(C)[None, :]            # (B, C)
    mask = qpos[:, :, None] >= jnp.arange(Skv)[None, None, :]
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)
