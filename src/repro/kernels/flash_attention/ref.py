"""Pure-jnp oracle for blocked causal GQA attention."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def mha(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Skv, KV, D)
    v: jnp.ndarray,          # (B, Skv, KV, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_offset: int = 0,      # absolute position of q[0] relative to k[0]
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5

    # broadcast kv heads to q heads (GQA)
    kq = jnp.repeat(k, rep, axis=2)
    vq = jnp.repeat(v, rep, axis=2)

    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + kv_offset
        kpos = jnp.arange(Skv)[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)
