"""Pure-jnp oracle for blocked causal GQA attention (dense and paged),
including the UNFUSED insert-then-attend reference for the fused
chunk-scatter kernels (`paged_prefill.paged_prefill_insert_flash*`): the
oracle scatters the chunk's pages with a plain jnp `.at[].set` and then
runs the gather-only attention — exactly the two-op sequence the fused
kernel collapses, so fused-vs-reference parity is the acceptance check
for the aliased write."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import gather_pages, gather_pages_q8


def mha(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Skv, KV, D)
    v: jnp.ndarray,          # (B, Skv, KV, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_offset: int = 0,      # absolute position of q[0] relative to k[0]
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5

    # broadcast kv heads to q heads (GQA)
    kq = jnp.repeat(k, rep, axis=2)
    vq = jnp.repeat(v, rep, axis=2)

    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + kv_offset
        kpos = jnp.arange(Skv)[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def scatter_chunk_pages(pool: jnp.ndarray, new: jnp.ndarray, block_tables,
                        c0, page_tokens: int) -> jnp.ndarray:
    """Write a page-aligned chunk `new` (B, C, ...) into the physical pool
    (P_phys, page_tokens, ...) at the pages `block_tables` (B, n_pages)
    assigns to [c0, c0+C) — the standalone jnp page scatter the fused
    kernel eliminates (kept as the parity oracle). `c0` (B,) page-aligned
    chunk starts; the chunks' physical pages must be uniquely owned."""
    B, C = new.shape[:2]
    n_wp = C // page_tokens
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    pages = c0[:, None] // page_tokens + jnp.arange(n_wp)[None, :]
    phys = jnp.take_along_axis(
        jnp.asarray(block_tables, jnp.int32), pages, axis=1
    )                                              # (B, n_wp)
    tiles = new.reshape((B, n_wp, page_tokens) + new.shape[2:])
    return pool.at[phys].set(tiles.astype(pool.dtype))


def scatter_chunk_sz(pool_sz: jnp.ndarray, sz_new: jnp.ndarray,
                     block_tables, c0, page_tokens: int) -> jnp.ndarray:
    """Scatter the chunk's per-page (scale, zero) rows (B, n_wp, KV, 2)
    into the pool-wide array (P_phys, KV, 2)."""
    B, n_wp = sz_new.shape[:2]
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))
    pages = c0[:, None] // page_tokens + jnp.arange(n_wp)[None, :]
    phys = jnp.take_along_axis(
        jnp.asarray(block_tables, jnp.int32), pages, axis=1
    )
    return pool_sz.at[phys].set(sz_new.astype(pool_sz.dtype))


def paged_prefill_insert_mha(q, k_pages, v_pages, k_new, v_new,
                             block_tables, c0, *,
                             scale: Optional[float] = None):
    """UNFUSED reference for the fused fp insert+attend kernel: scatter,
    then gather-attend. Returns (o, k_pages, v_pages)."""
    page = k_pages.shape[1]
    k_pages = scatter_chunk_pages(k_pages, k_new, block_tables, c0, page)
    v_pages = scatter_chunk_pages(v_pages, v_new, block_tables, c0, page)
    o = paged_prefill_mha(q, k_pages, v_pages, block_tables, c0,
                          scale=scale)
    return o, k_pages, v_pages


def paged_prefill_insert_mha_q8(q, k_pages, v_pages, k_sz, v_sz,
                                k8_new, v8_new, ksz_new, vsz_new,
                                block_tables, c0, *,
                                scale: Optional[float] = None):
    """UNFUSED reference for the fused int8 insert+attend kernel: scatter
    payload + (scale, zero) rows, then dequant-gather-attend. Returns
    (o, k_pages, v_pages, k_sz, v_sz)."""
    page = k_pages.shape[1]
    k_pages = scatter_chunk_pages(k_pages, k8_new, block_tables, c0, page)
    v_pages = scatter_chunk_pages(v_pages, v8_new, block_tables, c0, page)
    k_sz = scatter_chunk_sz(k_sz, ksz_new, block_tables, c0, page)
    v_sz = scatter_chunk_sz(v_sz, vsz_new, block_tables, c0, page)
    o = paged_prefill_mha(q, k_pages, v_pages, block_tables, c0,
                          k_sz=k_sz, v_sz=v_sz, scale=scale)
    return o, k_pages, v_pages, k_sz, v_sz


def paged_prefill_mha(q, k_pages, v_pages, block_tables, c0, *,
                      k_sz=None, v_sz=None,
                      scale: Optional[float] = None) -> jnp.ndarray:
    """Paged chunked-prefill oracle: gather the page pool to a dense
    cache (dequantizing int8 pools through `k_sz`/`v_sz` when given),
    then causal attention of the chunk q (B, C, H, D) at absolute
    positions [c0[b], c0[b]+C) against it. `c0` may be traced (the chunk
    offset is a runtime scalar in the serving engine), so the causal mask
    is built per batch row instead of through `mha`'s static kv_offset."""
    B, C, H, D = q.shape
    if k_sz is not None:
        k = gather_pages_q8(k_pages, k_sz, block_tables, dtype=q.dtype)
        v = gather_pages_q8(v_pages, v_sz, block_tables, dtype=q.dtype)
    else:
        k = gather_pages(k_pages, block_tables)    # (B, Skv, KV, D)
        v = gather_pages(v_pages, block_tables)
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    c0 = jnp.broadcast_to(jnp.asarray(c0, jnp.int32), (B,))

    kq = jnp.repeat(k, rep, axis=2)
    vq = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) * scale
    qpos = c0[:, None] + jnp.arange(C)[None, :]            # (B, C)
    mask = qpos[:, :, None] >= jnp.arange(Skv)[None, None, :]
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)
