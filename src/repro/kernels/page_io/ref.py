"""Pure-jnp oracle for the aliased page writer: the standalone page
scatter the fused kernel eliminates."""

from __future__ import annotations

import jax.numpy as jnp


def write_pages(pool: jnp.ndarray, tiles: jnp.ndarray,
                phys) -> jnp.ndarray:
    """pool (nb, P_phys, ...), tiles (nb, n_wp, ...), phys (n_wp,) int32:
    `pool[:, phys[j]] = tiles[:, j]`."""
    return pool.at[:, jnp.asarray(phys, jnp.int32)].set(
        tiles.astype(pool.dtype)
    )
