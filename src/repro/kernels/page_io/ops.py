"""Public page-writer op: land page tiles in the physical pool.

Reference backend scatters with jnp (`ref.write_pages`); pallas/interpret
run the aliased in-place kernel, so the serving prefill-insert cell
issues zero standalone page-scatter ops on the kernel backends. Arbitrary
trailing dims are flattened to one lane dim around the kernel (the
reshapes are layout no-ops on contiguous pools)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import select_impl
from repro.kernels.page_io import ref


@functools.partial(jax.jit, static_argnames=("impl",))
def write_pages(pool, tiles, phys, *, impl: Optional[str] = None):
    """pool (nb, P_phys, *page_dims), tiles (nb, n_wp, *page_dims), phys
    (n_wp,) int32 unique physical page ids (live block-table entries).
    Returns the pool with the tiles landed at their physical pages."""
    tiles = tiles.astype(pool.dtype)
    kind, interpret = select_impl(impl)
    if kind == "reference":
        return ref.write_pages(pool, tiles, phys)
    from repro.kernels.page_io import page_io

    nb, P = pool.shape[:2]
    n_wp = tiles.shape[1]
    out = page_io.write_pages_pallas(
        pool.reshape(nb, P, -1), tiles.reshape(nb, n_wp, -1), phys,
        interpret=interpret,
    )
    return out.reshape(pool.shape)
