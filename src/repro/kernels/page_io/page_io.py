"""Aliased page-writer Pallas kernel: land whole page tiles in a physical
page pool IN PLACE via `input_output_aliases` — the kernel-level
replacement for the standalone jnp page scatter (`pool.at[:, phys].set`)
the prefill-insert cell used to issue per K/V leaf, which costs one full
extra read+write of the pool through HBM on every admission.

The physical page ids ride the scalar-prefetch channel, so the output
BlockSpec index map chases `phys[j]` exactly like the paged attention
kernels chase the block table. Grid (nb, n_wp): one step per (stack
level, written page); each step copies its tile into the aliased pool
block, and every block the grid never names keeps the input pool's bytes
— aliasing turns "rewrite the whole pool" into "DMA just the chunk's
pages". Pages must be uniquely owned (the pager's free-list contract),
so no two grid steps target the same block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(phys_ref, t_ref, pool_ref, o_ref):
    del phys_ref, pool_ref          # phys is chased by the index maps;
    # the pool input exists only to alias the output buffer
    o_ref[...] = t_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def write_pages_pallas(pool3, tiles3, phys, *, interpret: bool = False):
    """pool3 (nb, P_phys, M), tiles3 (nb, n_wp, M) in the pool dtype,
    phys (n_wp,) int32 unique physical page ids. Returns the pool with
    `pool3[:, phys[j]] = tiles3[:, j]` applied in place (aliased)."""
    from jax.experimental.pallas import tpu as pltpu

    nb, _, M = pool3.shape
    n_wp = tiles3.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                   # the physical page ids
        grid=(nb, n_wp),
        in_specs=[
            pl.BlockSpec((1, 1, M), lambda i, j, phys: (i, j, 0)),
            pl.BlockSpec((1, 1, M), lambda i, j, phys: (i, phys[j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, M),
                               lambda i, j, phys: (i, phys[j], 0)),
    )
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(pool3.shape, pool3.dtype),
        grid_spec=grid_spec,
        # inputs count the scalar-prefetch operand: phys(0) tiles(1) pool(2)
        input_output_aliases={2: 0},
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
    )(jnp.asarray(phys, jnp.int32), tiles3, pool3)
