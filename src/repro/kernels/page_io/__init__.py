from repro.kernels.page_io.ops import write_pages  # noqa: F401
