"""Public SSD op (Mamba2 inner scan)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import select_impl
from repro.kernels.ssd_scan import ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd(
    x,
    dt,
    A,
    Bmat,
    Cmat,
    D=None,
    init_state=None,
    *,
    chunk: int = 128,
    impl: Optional[str] = None,
):
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    kind, interpret = select_impl(impl)
    if kind == "reference":
        if x.shape[1] <= 64:
            return ref.ssd(x, dt, A, Bmat, Cmat, D, init_state)
        from repro.kernels.ssd_scan import chunked

        return chunked.ssd_chunked_jnp(
            x, dt, A, Bmat, Cmat, D, init_state, chunk
        )
    from repro.kernels.ssd_scan import ssd_scan as ks

    return ks.ssd_pallas(
        x, dt, A, Bmat, Cmat, D, init_state,
        chunk=chunk, interpret=interpret,
    )


ssd_decode = jax.jit(ref.ssd_decode)  # O(1)-per-token update; jnp is optimal
