"""Chunked SSD (state-space duality) in matmul form — the everywhere-path.

Replaces the O(S) sequential recurrence with the Mamba2 chunked algorithm:
intra-chunk quadratic form (Q x Q matmuls that map to the MXU) + an
inter-chunk state recurrence over S/Q steps. This is the same tiling the
Pallas TPU kernel uses; the `ssd_vmem` named scope tells the HLO cost model
that the intra-chunk L/S tiles are VMEM-resident on the TPU target.

All decay exponentials are of non-positive arguments (A < 0, dt > 0, i >= j)
so the computation is numerically safe without max-subtraction.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def ssd_chunked_jnp(
    x,                      # (B,S,H,P)
    dt,                     # (B,S,H)
    A,                      # (H,) negative
    Bmat,                   # (B,S,G,N)
    Cmat,                   # (B,S,G,N)
    D=None,                 # (H,)
    init_state=None,        # (B,H,P,N)
    chunk: int = 128,
):
    with jax.named_scope("ssd_vmem"):
        return _ssd_chunked(x, dt, A, Bmat, Cmat, D, init_state, chunk)


def _pick_chunk(S: int, target: int) -> int:
    c = min(S, target)
    while S % c:
        c //= 2
    return max(c, 1)


def _ssd_chunked(x, dt, A, Bmat, Cmat, D, init_state, chunk):
    Bz, S, H, P = x.shape
    _, _, G, N = Bmat.shape
    rep = H // G
    Q = _pick_chunk(S, chunk)
    nc = S // Q
    f32 = jnp.float32

    xq = x.astype(f32).reshape(Bz, nc, Q, H, P)
    dtq = dt.astype(f32).reshape(Bz, nc, Q, H)
    Bq = jnp.repeat(Bmat.astype(f32), rep, axis=2).reshape(Bz, nc, Q, H, N)
    Cq = jnp.repeat(Cmat.astype(f32), rep, axis=2).reshape(Bz, nc, Q, H, N)

    a = A.astype(f32)[None, None, None, :] * dtq        # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(a, axis=2)                          # inclusive cumsum
    total = cum[:, :, -1, :]                             # (B,nc,H)

    # intra-chunk: Y[i] = sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) dt_j x_j
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cq, Bq)    # (B,nc,H,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H) i,j
    decay = jnp.moveaxis(decay, -1, 2)                   # (B,nc,H,Q,Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, None], jnp.exp(decay), 0.0)
    dx = dtq[..., None] * xq                             # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores * L, dx)

    # chunk states: h_c = sum_j exp(total_c - cum_j) dt_j B_j (x) x_j
    w = jnp.exp(total[:, :, None, :] - cum)              # (B,nc,Q,H)
    hc = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w, Bq, dx)

    # inter-chunk recurrence (small scan over nc)
    h0 = (
        jnp.zeros((Bz, H, P, N), f32)
        if init_state is None
        else init_state.astype(f32)
    )

    def step(hprev, inp):
        tot_c, hc_c = inp                                # (B,H), (B,H,P,N)
        hnew = jnp.exp(tot_c)[..., None, None] * hprev + hc_c
        return hnew, hprev                               # emit state BEFORE c

    (hT, hprevs) = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(hc, 1, 0)),
    )
    hprevs = jnp.moveaxis(hprevs, 0, 1)                  # (B,nc,H,P,N)

    # inter-chunk contribution: Y[i] += exp(cum_i) C_i . H_{c-1}
    y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
        "bcqhn,bchpn->bcqhp", Cq, hprevs
    )

    y = (y_intra + y_inter).reshape(Bz, S, H, P)
    if D is not None:
        y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), hT
