"""Pure-jnp oracle for the Mamba2 SSD recurrence (exact sequential scan).

State update (per batch b, head h):
    h_t = exp(A_h * dt_t) * h_{t-1} + dt_t * (B_t outer x_t)
    y_t = C_t . h_t + D_h * x_t
Shapes: x (B,S,H,P), dt (B,S,H), A (H,) <= 0, B/C (B,S,G,N), state h (H,P,N).
G groups share B/C across H//G heads.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def ssd(
    x: jnp.ndarray,        # (B,S,H,P)
    dt: jnp.ndarray,       # (B,S,H) positive
    A: jnp.ndarray,        # (H,) negative
    Bmat: jnp.ndarray,     # (B,S,G,N)
    Cmat: jnp.ndarray,     # (B,S,G,N)
    D: Optional[jnp.ndarray] = None,   # (H,)
    init_state: Optional[jnp.ndarray] = None,  # (B,H,P,N)
):
    Bsz, S, H, P = x.shape
    _, _, G, N = Bmat.shape
    rep = H // G
    f32 = jnp.float32
    x32, dt32 = x.astype(f32), dt.astype(f32)
    B32, C32 = Bmat.astype(f32), Cmat.astype(f32)
    A32 = A.astype(f32)

    h0 = (
        jnp.zeros((Bsz, H, P, N), f32)
        if init_state is None
        else init_state.astype(f32)
    )

    def step(h, inputs):
        xt, dtt, Bt, Ct = inputs             # (B,H,P), (B,H), (B,G,N), (B,G,N)
        Bh = jnp.repeat(Bt, rep, axis=1)     # (B,H,N)
        Ch = jnp.repeat(Ct, rep, axis=1)
        decay = jnp.exp(A32[None, :] * dtt)  # (B,H)
        upd = (dtt[..., None] * xt)[..., None] * Bh[:, :, None, :]  # (B,H,P,N)
        h = decay[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch)
        return h, y

    xs = (
        x32.transpose(1, 0, 2, 3),
        dt32.transpose(1, 0, 2),
        B32.transpose(1, 0, 2, 3),
        C32.transpose(1, 0, 2, 3),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)             # (B,S,H,P)
    if D is not None:
        y = y + D.astype(f32)[None, None, :, None] * x32
    return y.astype(x.dtype), hT.astype(f32)


def ssd_decode(x, dt, A, Bt, Ct, D, state):
    """One decode step. x (B,H,P), dt (B,H), Bt/Ct (B,G,N), state (B,H,P,N)."""
    B, H, P = x.shape
    G = Bt.shape[1]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(Bt.astype(f32), rep, axis=1)
    Ch = jnp.repeat(Ct.astype(f32), rep, axis=1)
    decay = jnp.exp(A.astype(f32)[None, :] * dt.astype(f32))
    upd = (dt.astype(f32)[..., None] * x.astype(f32))[..., None] * Bh[:, :, None, :]
    new_state = decay[..., None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    if D is not None:
        y = y + D.astype(f32)[None, :, None] * x.astype(f32)
    return y.astype(x.dtype), new_state
