"""Chunked SSD as a Pallas TPU kernel.

Grid (B, H, n_chunks) with the chunk dimension sequential; the recurrent
state (P x N) persists in VMEM scratch across chunk iterations. Each chunk
does three MXU matmuls — the C.B^T quadratic form, the (L o S) @ dX intra
term, and the dX^T @ (w o B) state update — so the sequential component is
only the O(n_chunks) scalar-decay recurrence, exactly the SSD decomposition
(arXiv:2405.21060) mapped onto the TPU memory hierarchy.

Backward: custom_vjp differentiates the (numerically identical) chunked-jnp
implementation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ssd_scan import chunked as cj


def _kernel(A_ref, D_ref, x_ref, dt_ref, B_ref, C_ref, h0_ref,
            y_ref, hT_ref, state, *, Q: int, nc: int, has_D: bool):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # (Q,)
    Bm = B_ref[0, :, 0, :].astype(jnp.float32)          # (Q, N)
    Cm = C_ref[0, :, 0, :].astype(jnp.float32)          # (Q, N)
    A = A_ref[0]

    a = A * dt                                          # (Q,) <= 0
    cum = jnp.cumsum(a)                                 # (Q,)
    total = cum[-1]

    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # (Q, Q)  i x j
    decay = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(decay), 0.0)
    dx = dt[:, None] * x                                # (Q, P)
    y = jax.lax.dot_general(
        scores * L, dx, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # intra (Q, P)

    # inter-chunk: y += exp(cum) * C @ state^T
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if has_D:
        y = y + D_ref[0] * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: h = exp(total) h + dx^T @ (w o B)
    w = jnp.exp(total - cum)                            # (Q,)
    hc = jax.lax.dot_general(
        dx, w[:, None] * Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # (P, N)
    state[...] = jnp.exp(total) * state[...] + hc

    @pl.when(c == nc - 1)
    def _done():
        hT_ref[0, 0] = state[...]


def _ssd_fwd_pallas(x, dt, A, Bmat, Cmat, D, init_state, chunk, interpret):
    B, S, H, P = x.shape
    _, _, G, N = Bmat.shape
    rep = H // G
    Q = cj._pick_chunk(S, chunk)
    nc = S // Q
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)
    has_D = D is not None
    D_arr = D.astype(jnp.float32) if has_D else jnp.zeros((H,), jnp.float32)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    y, hT = pl.pallas_call(
        functools.partial(_kernel, Q=Q, nc=nc, has_D=has_D),
        out_shape=(
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, c: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, Q, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(A.astype(jnp.float32), D_arr, x, dt, Bmat, Cmat, init_state)
    return y, hT


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8))
def ssd_pallas(x, dt, A, Bmat, Cmat, D=None, init_state=None,
               chunk: int = 128, interpret: bool = False):
    return _ssd_fwd_pallas(x, dt, A, Bmat, Cmat, D, init_state, chunk,
                           interpret)


def _ssd_fwd(x, dt, A, Bmat, Cmat, D, init_state, chunk, interpret):
    y, hT = _ssd_fwd_pallas(x, dt, A, Bmat, Cmat, D, init_state, chunk,
                            interpret)
    return (y, hT), (x, dt, A, Bmat, Cmat, D, init_state)


def _ssd_bwd(chunk, interpret, res, cts):
    x, dt, A, Bmat, Cmat, D, init_state = res
    has_D = D is not None
    has_init = init_state is not None

    def f(x, dt, A, Bmat, Cmat, D, init_state):
        return cj.ssd_chunked_jnp(
            x, dt, A, Bmat, Cmat,
            D if has_D else None,
            init_state if has_init else None,
            chunk,
        )

    D_in = D if has_D else jnp.zeros((x.shape[2],), jnp.float32)
    init_in = (
        init_state if has_init
        else jnp.zeros(
            (x.shape[0], x.shape[2], x.shape[3], Bmat.shape[3]), jnp.float32
        )
    )
    _, vjp = jax.vjp(f, x, dt, A, Bmat, Cmat, D_in, init_in)
    dx, ddt, dA, dB, dC, dD, dh0 = vjp(cts)
    return (dx, ddt, dA, dB, dC,
            dD if has_D else None,
            dh0 if has_init else None)


ssd_pallas.defvjp(_ssd_fwd, _ssd_bwd)
