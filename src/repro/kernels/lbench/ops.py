"""Public LBench op."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import select_impl
from repro.kernels.lbench import ref


@functools.partial(jax.jit, static_argnames=("nflop", "alpha", "impl"))
def lbench(a, nflop: int, alpha: float = 0.5, *, impl: Optional[str] = None):
    kind, interpret = select_impl(impl)
    if kind == "reference":
        return ref.lbench(a, nflop, alpha)
    from repro.kernels.lbench import lbench as kl

    return kl.lbench_pallas(a, nflop, alpha, interpret=interpret)


flops = ref.flops
bytes_moved = ref.bytes_moved
