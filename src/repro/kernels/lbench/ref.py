"""Pure-jnp oracle for LBench, the paper's interference kernel (Sec 3.2).

Per element:
    if NFLOP % 2 == 1: beta = A[i] + alpha
    else:              beta = A[i]            (read, no flop consumed)
    repeat NFLOP//2 times: beta = beta * A[i] + alpha
    A[i] = beta

NFLOP controls arithmetic intensity: flops/element = NFLOP (one add if odd,
then 2 flops per FMA iteration), bytes/element = 8 (one read + one write of
f32) so AI = NFLOP/8 flop/B — sweeping NFLOP sweeps the roofline x-axis,
which is how the paper dials the Level-of-Interference.
"""

from __future__ import annotations

import jax.numpy as jnp


def lbench(a: jnp.ndarray, nflop: int, alpha: float = 0.5) -> jnp.ndarray:
    f32 = jnp.float32
    a32 = a.astype(f32)
    beta = a32 + alpha if (nflop % 2 == 1) else a32
    for _ in range(nflop // 2):
        beta = beta * a32 + alpha
    return beta.astype(a.dtype)


def flops(n_elements: int, nflop: int) -> int:
    per = (nflop % 2) + 2 * (nflop // 2)
    return n_elements * per


def bytes_moved(n_elements: int, itemsize: int = 4) -> int:
    return 2 * n_elements * itemsize
