"""LBench as a Pallas TPU kernel — the paper's interference/roofline probe.

The FMA chain (`beta = beta * A[i] + alpha`, NFLOP//2 times) is unrolled at
trace time exactly like the paper's `#pragma GCC unroll 16`; NFLOP selects
the arithmetic intensity (NFLOP/8 flop/B for f32 read+write), which is how
LoI is dialed. BlockSpec tiles the array through VMEM in (block_rows, 128)
tiles — 128 matches the VPU lane width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _kernel(a_ref, o_ref, *, nflop: int, alpha: float):
    x = a_ref[...]
    beta = x + alpha if (nflop % 2 == 1) else x
    for _ in range(nflop // 2):
        beta = beta * x + alpha
    o_ref[...] = beta


@functools.partial(
    jax.jit, static_argnames=("nflop", "alpha", "interpret", "block_rows")
)
def lbench_pallas(a, nflop: int, alpha: float = 0.5, *,
                  interpret: bool = False, block_rows: int = 512):
    orig_shape = a.shape
    n = a.size
    assert n % LANES == 0, f"size {n} must be a multiple of {LANES}"
    rows = n // LANES
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    br = max(br, 1)
    grid = (rows // br,)
    a2 = a.reshape(rows, LANES)
    out = pl.pallas_call(
        functools.partial(_kernel, nflop=nflop, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), a.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        interpret=interpret,
    )(a2)
    return out.reshape(orig_shape)
