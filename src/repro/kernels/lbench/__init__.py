from repro.kernels.lbench.ops import lbench  # noqa: F401
