"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package ships three modules:
  <name>.py  - the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py     - the jit'd public wrapper; dispatches pallas / interpret /
               reference by backend (TPU -> pallas, CPU -> reference,
               tests -> interpret)
  ref.py     - the pure-jnp oracle the tests assert against

Kernels: lbench (the paper's interference/roofline kernel), flash_attention
(prefill), decode_attention (single-token vs long KV; `paged.py` adds the
block-index-map variant over non-contiguous KV pages, fed by
`serving.kv_pager.KVPager.block_table`), ssd_scan (Mamba2 SSD),
matmul_w8a8 (megacore-partitioned int8 W8A8 matmul matching the int8
pool default).
"""

from __future__ import annotations

import jax

_FORCED: str | None = None


def force_backend(name: str | None) -> None:
    """Force 'pallas' | 'interpret' | 'reference' | None (auto)."""
    global _FORCED
    _FORCED = name


def backend() -> str:
    if _FORCED is not None:
        return _FORCED
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "reference"


def select_impl(impl: str | None = None) -> tuple[str, bool]:
    """Resolve an op's implementation request to ``(kind, interpret)``.

    ``kind`` is ``"reference"`` (run the pure-jnp oracle) or ``"pallas"``
    (run the kernel, with ``interpret=True`` when the resolved backend is
    ``"interpret"``). Every ops.py dispatcher shares this one helper so a
    new kernel variant never re-copies the backend/interpret boilerplate.
    """
    impl = impl or backend()
    if impl == "reference":
        return "reference", False
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown kernel impl {impl!r}")
    return "pallas", impl == "interpret"
