"""Per-page int8 block quantization for the paged KV pool.

The pool payload is stored as int8 with one float32 (scale, zero) pair per
(physical page, KV head) — the ``*_sz`` arrays that ride next to every
quantized ``k``/``v`` pool leaf, laid out ``(..., n_phys_pages, KV, 2)``
with ``[..., 0] = scale`` and ``[..., 1] = zero``. Quantization is
affine mid-range: for a page-head tile ``x``

    zero  = (max(x) + min(x)) / 2
    scale = max((max(x) - min(x)) / (2 * 127), MIN_SCALE)
    q     = round((x - zero) / scale)            # always in [-127, 127]
    x_hat = q * scale + zero                     # |x_hat - x| <= scale/2

The mid-range zero point centres the int8 grid on the tile's actual range,
so no value ever clips and the round-trip error is bounded by half a
quantization step — including the adversarial cases (an all-zero page
dequantizes exactly; a single-outlier page widens ``scale`` but stays
within the bound). These helpers are the single source of the quantization
math: the insert paths quantize with them, the kernels' oracles dequantize
with them, and the pallas kernels inline the same ``q * scale + zero``
epilogue on the gather side.
"""

from __future__ import annotations

import jax.numpy as jnp

INT8_QMAX = 127
# floor keeps constant pages exact: (x - zero) == 0 -> q == 0 -> zero
MIN_SCALE = 1e-8
SZ_CHANNELS = 2                      # [scale, zero]


def page_sz(x: jnp.ndarray, axis) -> jnp.ndarray:
    """(scale, zero) over the reduction ``axis`` of ``x``, stacked on a
    trailing size-2 channel: returns ``x.shape`` minus ``axis`` plus
    ``(2,)`` in float32."""
    x = x.astype(jnp.float32)
    hi = x.max(axis=axis)
    lo = x.min(axis=axis)
    zero = (hi + lo) * 0.5
    scale = jnp.maximum((hi - lo) / (2.0 * INT8_QMAX), MIN_SCALE)
    return jnp.stack([scale, zero], axis=-1)


def quantize(x: jnp.ndarray, sz: jnp.ndarray) -> jnp.ndarray:
    """Quantize ``x`` (float) to int8 with broadcastable ``sz`` whose
    trailing dim is the (scale, zero) channel."""
    scale, zero = sz[..., 0], sz[..., 1]
    q = jnp.round((x.astype(jnp.float32) - zero) / scale)
    return jnp.clip(q, -INT8_QMAX, INT8_QMAX).astype(jnp.int8)


def dequantize(q: jnp.ndarray, sz: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    """Dequantize int8 ``q`` with broadcastable ``sz``."""
    scale, zero = sz[..., 0], sz[..., 1]
    return (q.astype(jnp.float32) * scale + zero).astype(dtype)


def _per_page(sz: jnp.ndarray) -> jnp.ndarray:
    """(..., KV, 2) -> (..., 1, KV, 1, 2): broadcast over (page, hd)."""
    return sz[..., None, :, None, :]


def quantize_pages(pages: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize whole page tiles ``(..., page_tokens, KV, hd)`` with one
    (scale, zero) per (page, KV head). Returns ``(q8, sz)`` where ``q8``
    matches ``pages.shape`` in int8 and ``sz`` is ``(..., KV, 2)``."""
    sz = page_sz(pages, axis=(-3, -1))                  # (..., KV, 2)
    return quantize(pages, _per_page(sz)), sz


def dequantize_pages(q8: jnp.ndarray, sz: jnp.ndarray,
                     dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of `quantize_pages`: ``q8`` ``(..., page, KV, hd)``,
    ``sz`` ``(..., KV, 2)``."""
    return dequantize(q8, _per_page(sz), dtype=dtype)


# ---------------------------------------------------- per-token sub-scales
# The speculative-decoding hot-page layout: one (scale, zero) pair per
# (token row, KV head) instead of per (page, KV head). A token write is
# then a pure disjoint scatter — quantize the token over head_dim, land
# payload + sz row — with NO dequant->modify->requantize round trip over
# the page, so a verify step can land all k candidate tokens of a slot in
# one collision-free scatter. Costs page_tokens x more sz bytes per page
# (`core.access.kv_pool_token_bytes(..., sz_granularity="token")`); the
# engine selects it only when speculative decoding is on.


def token_sz(x: jnp.ndarray) -> jnp.ndarray:
    """(scale, zero) per token row: reduce over the trailing head_dim
    only. ``x`` ``(..., hd)`` -> ``(..., 2)`` float32."""
    return page_sz(x, axis=(-1,))


def quantize_tokens(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize token rows ``(..., hd)`` with one (scale, zero) per row.
    Returns ``(q8, sz)`` with ``q8`` matching ``x.shape`` in int8 and
    ``sz`` ``(..., 2)``."""
    sz = token_sz(x)
    return quantize(x, sz[..., None, :]), sz


def dequantize_tokens(q8: jnp.ndarray, sz: jnp.ndarray,
                      dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of `quantize_tokens`: ``q8`` ``(..., hd)``, ``sz``
    ``(..., 2)`` broadcasting the row's pair over head_dim."""
    return dequantize(q8, sz[..., None, :], dtype=dtype)
