"""Public W8A8 int8 matmul op. Dispatches pallas / interpret / reference
via `kernels.select_impl`; zero-pads ragged shapes to block multiples
(zero rows and columns contract to zero, so the visible (M, N) slice is
unchanged)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import select_impl
from repro.kernels.matmul_w8a8 import ref
from repro.kernels.matmul_w8a8.ref import quantize_rows  # noqa: F401


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "impl"),
)
def matmul_w8a8(
    a8,
    b8,
    sa,
    sb,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    impl: Optional[str] = None,
):
    """int8 activation/weight matmul with symmetric per-row / per-column
    scales: a8 (M, K) int8, b8 (K, N) int8, sa (M,) float32, sb (N,)
    float32 -> (M, N) float32. Quantize fp operands with
    `quantize_rows`: ``quantize_rows(a)`` reduces each activation row
    over K; ``quantize_rows(w, axis=0)`` reduces each weight column
    over K."""
    kind, interpret = select_impl(impl)
    if kind == "reference":
        return ref.matmul_w8a8(a8, b8, sa, sb)
    from repro.kernels.matmul_w8a8 import matmul_w8a8 as mm

    M, N = a8.shape[0], b8.shape[1]
    a8p = _pad_to(_pad_to(a8, block_m, 0), block_k, 1)
    b8p = _pad_to(_pad_to(b8, block_k, 0), block_n, 1)
    sap = _pad_to(jnp.asarray(sa, jnp.float32), block_m, 0)
    sbp = _pad_to(jnp.asarray(sb, jnp.float32), block_n, 0)
    out = mm.matmul_w8a8_pallas(
        a8p, b8p, sap, sbp, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )
    return out[:M, :N]
