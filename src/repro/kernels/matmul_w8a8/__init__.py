from repro.kernels.matmul_w8a8.ops import quantize_rows  # noqa: F401

# the W8A8 op itself lives in ops.py; import it from there
# (`repro.kernels.matmul_w8a8.ops.matmul_w8a8`) — re-exporting it here
# would shadow the same-named kernel submodule on the package.
