"""W8A8 int8 matmul Pallas kernel with megacore partitioning.

int8 x int8 tiles contract on the MXU into an int32 VMEM accumulator;
the dequant epilogue (rank-1 outer product of the per-row activation
scales and per-column weight scales) runs once on the final K step.
Grid (M/bm, N/bn, K/bk) with `dimension_semantics=("parallel",
"parallel", "arbitrary")`: the independent output tiles split across
the TPU's TensorCores (megacore), only the K reduction is sequential —
the matmul twin of the paged attention kernels' partitioning, and the
compute cell that matches the serving engine's int8 pool default
(per-token sub-scale pages quantize K/V rows the same symmetric way a
W8A8 activation row is quantized here).

Tile floors follow the int8 (32, 128) TPU tiling: the default 128x128
output blocks with K steps of 128 satisfy every operand's minimum tile.
The public wrapper (`ops.matmul_w8a8`) zero-pads ragged shapes up to
block multiples — zero rows/columns contract to zero, so padding never
changes the visible output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[...] = (
            acc[...].astype(jnp.float32) * sa_ref[...] * sb_ref[...]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def matmul_w8a8_pallas(a8, b8, sa, sb, *, block_m: int = 128,
                       block_n: int = 128, block_k: int = 128,
                       interpret: bool = False):
    """a8 (M, K) int8 @ b8 (K, N) int8 with per-row scales sa (M,) and
    per-column scales sb (N,) float32 -> (M, N) float32. M, N, K must be
    multiples of the block sizes (ops.py pads)."""
    from jax.experimental.pallas import tpu as pltpu

    M, K = a8.shape
    _, N = b8.shape
    nk = K // block_k
    grid = (M // block_m, N // block_n, nk)
    sa2 = jnp.asarray(sa, jnp.float32).reshape(M, 1)
    sb2 = jnp.asarray(sb, jnp.float32).reshape(1, N)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((block_k, block_n), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, ki: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, ki: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            # MEGACORE: output tiles are independent -> parallel; only
            # the K reduction carries the accumulator sequentially
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(a8, b8, sa2, sb2)
