"""Pure-jnp oracle for the W8A8 int8 matmul cell.

Symmetric per-row / per-column quantization: activations carry one
float32 scale per row (reduced over K), weights one per output column,
so the int32 accumulator dequantizes with a rank-1 outer product of
scales in the epilogue — no zero-point cross terms, which is what keeps
the whole contraction on the int8 MXU path. `MIN_SCALE` keeps all-zero
rows exact (q == 0 -> 0.0), mirroring `repro.kernels.quant`.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.quant import INT8_QMAX, MIN_SCALE


def quantize_rows(x: jnp.ndarray, axis: int = -1):
    """Symmetric int8 quantization of ``x`` with one scale per slice
    along ``axis``: scale = max|x| / 127 (floored at MIN_SCALE).
    Returns ``(q8, scale)`` with ``scale`` shaped like ``x`` minus
    ``axis``."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(x).max(axis=axis) / INT8_QMAX, MIN_SCALE)
    q = jnp.round(x / jnp.expand_dims(scale, axis))
    return jnp.clip(q, -INT8_QMAX, INT8_QMAX).astype(jnp.int8), scale


def matmul_w8a8(a8: jnp.ndarray, b8: jnp.ndarray, sa: jnp.ndarray,
                sb: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """int8 x int8 -> int32 -> scaled float: a8 (M, K), b8 (K, N),
    sa (M,) per-row activation scales, sb (N,) per-column weight
    scales. Returns (M, N) in ``dtype``."""
    acc = jnp.dot(a8.astype(jnp.int32), b8.astype(jnp.int32))
    out = acc.astype(jnp.float32) * sa[:, None] * sb[None, :]
    return out.astype(dtype)
