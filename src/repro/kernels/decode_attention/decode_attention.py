"""Flash-decode Pallas kernel: one query token vs a long KV cache.

Grid (B, H, n_kv_blocks); the kv-block dimension is sequential ("arbitrary")
so the online-softmax accumulators live in VMEM scratch across iterations.
Out-of-length positions are masked with an iota test against `length`
(supports ragged batches). Blocks are (block_k, D) — D is lane-padded by
Mosaic; block_k rides the sublane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_sc, l_sc, *,
            block_k: int, rep: int, scale: float, nk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[0, 0, :].astype(jnp.float32)            # (D,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = (k @ q) * scale                               # (bk,)
    pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_sc[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)                            # (bk,)
    alpha = jnp.exp(m_prev - m_new)
    l_sc[0] = l_sc[0] * alpha + p.sum()
    m_sc[0] = m_new
    acc[...] = acc[...] * alpha + (p[:, None] * v).sum(axis=0)[None, :]

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0, :] = (
            acc[0] / jnp.maximum(l_sc[0], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "block_k"))
def flash_decode(q, k, v, length, *, scale=None, interpret: bool = False,
                 block_k: int = 512):
    B, H, D = q.shape
    _, S, KV, _ = k.shape
    rep = H // KV
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, S)
    while S % bk:
        bk //= 2
    bk = max(bk, 1)
    nk = S // bk
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))

    grid = (B, H, nk)
    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        functools.partial(
            _kernel, block_k=bk, rep=rep, scale=scale, nk=nk
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, D), lambda b, h, ki: (b, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, ki, rep=rep: (b, ki, h // rep, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, ki, rep=rep: (b, ki, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, ki: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(length, q, k, v)
    return out
